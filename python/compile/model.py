"""Layer-2 JAX model: the tiny decoder-only transformer served by the rust
runtime, with explicit prefill / decode entry points and KV cache.

Build-time only — `aot.py` lowers the two entries to HLO text which
`rust/src/runtime` compiles and executes via PJRT; Python is never on the
request path. The compute hot-spots (attention, FFN, RMSNorm) are the
Layer-1 Pallas kernels from :mod:`compile.kernels`, so they lower into the
same HLO.

Weights are *runtime inputs* (not baked constants): the rust side uploads
`weights.bin` to device once and reuses the buffers across calls. Parameter
order is fixed by :func:`param_specs` and recorded in `manifest.json`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import decode_attention, prefill_attention, rmsnorm, swiglu_ffn


@dataclasses.dataclass(frozen=True)
class Arch:
    """Architecture + AOT shape parameters (mirrors rust `TinyDims`)."""

    layers: int = 4
    d: int = 256
    heads: int = 4
    kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 512
    #: Prefill entry's padded prompt width.
    max_prompt: int = 128
    #: Per-request KV capacity baked into the decode entry.
    kv_cap: int = 192
    #: Decode entry's static batch width.
    decode_batch: int = 4

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def params_count(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_specs(self))


TINY = Arch()


def param_specs(arch: Arch) -> list[tuple[str, tuple[int, ...]]]:
    """(name, shape) for every weight tensor, in manifest/weights.bin order."""
    d, kvd, dff = arch.d, arch.kv_dim, arch.d_ff
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (arch.vocab, d))]
    for i in range(arch.layers):
        specs += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, kvd)),
            (f"l{i}.wv", (d, kvd)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w_gate", (d, dff)),
            (f"l{i}.w_up", (d, dff)),
            (f"l{i}.w_down", (dff, d)),
        ]
    specs += [("ln_f", (d,)), ("lm_head", (d, arch.vocab))]
    return specs


def init_params(arch: Arch, seed: int = 0) -> list[np.ndarray]:
    """Deterministic scaled-normal init, one array per spec entry."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_specs(arch):
        if name.endswith(("ln1", "ln2", "ln_f")):
            out.append(np.ones(shape, np.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            out.append(rng.standard_normal(shape).astype(np.float32) / np.sqrt(fan_in))
    return out


def _unpack(arch: Arch, flat: list[jax.Array]):
    """Split the flat weight list into (embed, layers, ln_f, lm_head)."""
    specs = param_specs(arch)
    assert len(flat) == len(specs), f"want {len(specs)} weights, got {len(flat)}"
    embed = flat[0]
    per_layer = 9
    layers = []
    for i in range(arch.layers):
        base = 1 + i * per_layer
        ln1, wq, wk, wv, wo, ln2, wg, wu, wd = flat[base : base + per_layer]
        layers.append((ln1, wq, wk, wv, wo, ln2, wg, wu, wd))
    ln_f, lm_head = flat[-2], flat[-1]
    return embed, layers, ln_f, lm_head


def prefill(arch: Arch, weights: list[jax.Array], tokens: jax.Array, length: jax.Array):
    """Prefill entry: process a padded prompt, return first-token logits + KV.

    Args:
        tokens: ``i32[max_prompt]`` (padding after ``length`` is ignored).
        length: ``i32[]`` — number of real tokens, in ``1..=max_prompt``.

    Returns:
        ``(logits f32[vocab], kv f32[layers, 2, kv_cap, kv_dim])`` where the
        KV rows past ``length`` are zero (pre-padded to decode capacity).
    """
    embed, layers, ln_f, lm_head = _unpack(arch, weights)
    p, h, dh = arch.max_prompt, arch.heads, arch.head_dim
    x = embed[tokens]  # [P, d]
    # Zero padded rows so their K/V contributions (stored, masked anyway) stay tame.
    keep = (jnp.arange(p) < length)[:, None]
    x = jnp.where(keep, x, 0.0)

    kv_all = []
    for ln1, wq, wk, wv, wo, ln2, wg, wu, wd in layers:
        hdd = rmsnorm(x, ln1)
        q = (hdd @ wq).reshape(p, h, dh)
        k = (hdd @ wk).reshape(p, arch.kv_heads, dh)
        v = (hdd @ wv).reshape(p, arch.kv_heads, dh)
        attn = prefill_attention(q, k, v, length)  # [P, H, Dh]
        x = x + attn.reshape(p, arch.d) @ wo
        x = x + swiglu_ffn(rmsnorm(x, ln2), wg, wu, wd)
        # Stash this layer's K/V, padded to decode capacity and zeroed
        # beyond `length`.
        kf = jnp.where(keep, k.reshape(p, arch.kv_dim), 0.0)
        vf = jnp.where(keep, v.reshape(p, arch.kv_dim), 0.0)
        pad = ((0, arch.kv_cap - p), (0, 0))
        kv_all.append(jnp.stack([jnp.pad(kf, pad), jnp.pad(vf, pad)]))

    xf = rmsnorm(x, ln_f)
    logits = xf[length - 1] @ lm_head  # [vocab]
    kv = jnp.stack(kv_all)  # [L, 2, C, KVD]
    return logits.astype(jnp.float32), kv.astype(jnp.float32)


def _decode_one(arch: Arch, weights, token, pos, kv):
    """One request's decode step. ``kv: [L, 2, C, KVD]`` updated at ``pos``."""
    embed, layers, ln_f, lm_head = _unpack(arch, weights)
    h, dh, c = arch.heads, arch.head_dim, arch.kv_cap
    x = embed[token]  # [d]

    new_kv = []
    for li, (ln1, wq, wk, wv, wo, ln2, wg, wu, wd) in enumerate(layers):
        hdd = rmsnorm(x[None, :], ln1)[0]
        q = (hdd @ wq).reshape(h, dh)
        k_new = hdd @ wk  # [KVD]
        v_new = hdd @ wv
        k_cache = jax.lax.dynamic_update_slice(kv[li, 0], k_new[None, :], (pos, 0))
        v_cache = jax.lax.dynamic_update_slice(kv[li, 1], v_new[None, :], (pos, 0))
        attn = decode_attention(
            q,
            k_cache.reshape(c, arch.kv_heads, dh),
            v_cache.reshape(c, arch.kv_heads, dh),
            pos,
            # One KV sweep per head: C=192 fits VMEM comfortably (§Perf) and
            # collapses the interpret-mode fori_loop to a single step.
            block_c=c,
        )  # [H, Dh]
        x = x + attn.reshape(arch.d) @ wo
        x = x + swiglu_ffn(rmsnorm(x[None, :], ln2), wg, wu, wd)[0]
        new_kv.append(jnp.stack([k_cache, v_cache]))

    logits = rmsnorm(x[None, :], ln_f)[0] @ lm_head
    return logits.astype(jnp.float32), jnp.stack(new_kv)


def decode(arch: Arch, weights: list[jax.Array], tokens: jax.Array, pos: jax.Array, kv: jax.Array):
    """Batched decode entry.

    Args:
        tokens: ``i32[B]`` last emitted token per slot.
        pos: ``i32[B]`` position each new token is written at.
        kv: ``f32[B, L, 2, C, KVD]`` per-slot caches.

    Returns:
        ``(logits f32[B, vocab], kv f32[B, L, 2, C, KVD])``. Inactive slots
        are the caller's concern (their outputs are simply unused).
    """
    b = arch.decode_batch
    assert tokens.shape == (b,) and pos.shape == (b,)
    outs = [_decode_one(arch, weights, tokens[i], pos[i], kv[i]) for i in range(b)]
    logits = jnp.stack([o[0] for o in outs])
    new_kv = jnp.stack([o[1] for o in outs])
    return logits, new_kv


def reference_generate(arch: Arch, weights, prompt: np.ndarray, steps: int) -> np.ndarray:
    """Greedy generation through prefill→decode — the numeric ground truth
    the rust runtime's token loop must reproduce exactly."""
    tokens = np.zeros(arch.max_prompt, np.int32)
    tokens[: len(prompt)] = prompt
    logits, kv = prefill(arch, weights, jnp.asarray(tokens), jnp.int32(len(prompt)))
    out = [int(jnp.argmax(logits))]
    # Single active slot in a batch-B decode call.
    b = arch.decode_batch
    kv_b = jnp.zeros((b, arch.layers, 2, arch.kv_cap, arch.kv_dim), jnp.float32)
    kv_b = kv_b.at[0].set(kv)
    for i in range(steps - 1):
        tok = jnp.zeros(b, jnp.int32).at[0].set(out[-1])
        p = jnp.zeros(b, jnp.int32).at[0].set(len(prompt) + i)
        logits_b, kv_b = decode(arch, weights, tok, p, kv_b)
        out.append(int(jnp.argmax(logits_b[0])))
    return np.array(out, np.int32)
