"""AOT compiler: lower the Layer-2 model (with its Layer-1 Pallas kernels)
to HLO **text** artifacts for the rust PJRT runtime.

Interchange format is HLO text, NOT ``.serialize()`` / StableHLO bytes: the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under ``--out`` (default ``../artifacts``):
    manifest.json     model dims + weight tensor table + entry files
    weights.bin       all weights, f32 little-endian, manifest order
    prefill.hlo.txt   prefill entry (weights…, tokens, length) → (logits, kv)
    decode.hlo.txt    decode entry (weights…, tokens, pos, kv) → (logits, kv)

Usage: ``cd python && python -m compile.aot [--out DIR] [--seed N]``
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import TINY, decode, init_params, param_specs, prefill, reference_generate


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the rust
    side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entries(arch):
    """Lower both entries with weights as leading runtime inputs."""
    w_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in param_specs(arch)
    ]
    n_w = len(w_specs)

    def prefill_entry(*args):
        weights = list(args[:n_w])
        tokens, length = args[n_w], args[n_w + 1]
        return prefill(arch, weights, tokens, length)

    def decode_entry(*args):
        weights = list(args[:n_w])
        tokens, pos, kv = args[n_w], args[n_w + 1], args[n_w + 2]
        return decode(arch, weights, tokens, pos, kv)

    tok_p = jax.ShapeDtypeStruct((arch.max_prompt,), jnp.int32)
    len_p = jax.ShapeDtypeStruct((), jnp.int32)
    prefill_lowered = jax.jit(prefill_entry).lower(*w_specs, tok_p, len_p)

    tok_d = jax.ShapeDtypeStruct((arch.decode_batch,), jnp.int32)
    pos_d = jax.ShapeDtypeStruct((arch.decode_batch,), jnp.int32)
    kv_d = jax.ShapeDtypeStruct(
        (arch.decode_batch, arch.layers, 2, arch.kv_cap, arch.kv_dim), jnp.float32
    )
    decode_lowered = jax.jit(decode_entry).lower(*w_specs, tok_d, pos_d, kv_d)
    return prefill_lowered, decode_lowered


def _reference_block(arch, params) -> dict:
    """Greedy-generation ground truth for the rust integration test."""
    prompt = np.array([7, 42, 300, 5, 128, 9, 77, 201], np.int32)
    steps = 12
    jp = [jnp.asarray(p) for p in params]
    tokens = reference_generate(arch, jp, prompt, steps)
    return {"prompt": prompt.tolist(), "steps": steps, "tokens": tokens.tolist()}


def build(out_dir: pathlib.Path, seed: int = 0, arch=TINY) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)

    # Weights: f32 LE, concatenated in param_specs order.
    params = init_params(arch, seed)
    flat = np.concatenate([p.ravel() for p in params]).astype("<f4")
    (out_dir / "weights.bin").write_bytes(flat.tobytes())

    print(f"lowering prefill/decode entries ({arch.params_count():,} params)...")
    prefill_lowered, decode_lowered = lower_entries(arch)
    (out_dir / "prefill.hlo.txt").write_text(to_hlo_text(prefill_lowered))
    (out_dir / "decode.hlo.txt").write_text(to_hlo_text(decode_lowered))

    manifest = {
        "model": {
            "layers": arch.layers,
            "d": arch.d,
            "heads": arch.heads,
            "kv_heads": arch.kv_heads,
            "d_ff": arch.d_ff,
            "vocab": arch.vocab,
            "max_prompt": arch.max_prompt,
            "kv_cap": arch.kv_cap,
            "decode_batch": arch.decode_batch,
        },
        "weights": {
            "file": "weights.bin",
            "seed": seed,
            "tensors": [
                {"name": n, "shape": list(s)} for n, s in param_specs(arch)
            ],
        },
        "entries": [
            {"name": "prefill", "file": "prefill.hlo.txt"},
            {"name": "decode", "file": "decode.hlo.txt"},
        ],
        # Cross-layer oracle: greedy generation computed in JAX at build
        # time; the rust runtime must reproduce these token ids exactly
        # (rust/tests/runtime_pjrt.rs).
        "reference": _reference_block(arch, params),
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    for f in ["manifest.json", "weights.bin", "prefill.hlo.txt", "decode.hlo.txt"]:
        size = (out_dir / f).stat().st_size
        print(f"  wrote {f}: {size:,} bytes")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--seed", type=int, default=0, help="weight init seed")
    args = ap.parse_args()
    build(pathlib.Path(args.out), args.seed)


if __name__ == "__main__":
    main()
