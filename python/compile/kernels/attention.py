"""Pallas attention kernels — the paper's compute hot-spots, rethought for TPU.

Hardware adaptation (DESIGN.md §5): the paper's kernels are CUDA/SM-centric;
here the same two hot-spots are expressed in TPU idiom:

* **Prefill attention** (compute-bound, §2.3): flash-style tiling. The grid
  iterates (head, q-block); each program streams the KV sequence through
  VMEM in ``block_k`` tiles, maintaining the running max / normalizer so the
  full ``[P, P]`` score matrix never materializes. Q/K tiles are sized for
  the MXU (multiples of 64/128 lanes).
* **Decode attention** (memory-bound GEMV, §2.3): a KV-streaming reduction.
  One program per head walks the cache in ``block_c`` tiles — bandwidth-
  bound by design, mirroring why decode saturates at low SM counts (Fig 5c).

Both kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpreter path is the correctness target and
real-TPU performance is *estimated* from the block shapes (EXPERIMENTS.md
§Perf). Numerics are validated against :mod:`.ref` by pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int):
    """One (head, q-block) program of flash-style causal attention."""
    qi = pl.program_id(1)
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32)  # [block_q, dh]
    block_q, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T * scale  # [block_q, block_k]
        k_idx = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = (k_idx[None, :] <= q_idx[:, None]) & (k_idx[None, :] < length)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    n_k = seq_len // block_k
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def prefill_attention(q, k, v, length, *, block_q: int = 64, block_k: int = 64):
    """Causal prompt attention. ``q, k, v: [P, H, Dh]``; ``length``: scalar.

    ``P`` must be divisible by both block sizes (callers pad — the model
    pads prompts to ``max_prompt`` anyway). Matches
    :func:`.ref.prefill_attention_ref` on the first ``length`` rows.
    """
    p, h, dh = q.shape
    assert k.shape == (p, h, dh) and v.shape == (p, h, dh), "MHA shapes"
    assert p % block_q == 0 and p % block_k == 0, f"P={p} not tileable"
    qt = jnp.transpose(q, (1, 0, 2))  # [H, P, Dh]
    kt = jnp.transpose(k, (1, 0, 2))
    vt = jnp.transpose(v, (1, 0, 2))
    len_arr = jnp.reshape(length, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_prefill_kernel, block_k=block_k, seq_len=p),
        grid=(h, p // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda hh, i: (0,)),
            pl.BlockSpec((None, block_q, dh), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((None, p, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((None, p, dh), lambda hh, i: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, p, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(len_arr, qt, kt, vt)
    return jnp.transpose(out, (1, 0, 2))


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_c: int, cap: int):
    """One head's GEMV attention, streaming the KV cache through VMEM."""
    pos = pos_ref[0]
    q = q_ref[...].astype(jnp.float32)  # [1, dh] (block keeps a dummy row dim)
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_c, block_c), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_c, block_c), slice(None)))
        s = (q @ k.astype(jnp.float32).T * scale)[0]  # [block_c]
        c_idx = j * block_c + jax.lax.iota(jnp.int32, block_c)
        s = jnp.where(c_idx <= pos, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    n_c = cap // block_c
    m0 = jnp.float32(_NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((dh,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_c, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20))[None, :].astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, block_c: int = 64):
    """Single-token attention against a padded KV cache.

    ``q: [H, Dh]``; ``k_cache, v_cache: [C, H, Dh]``; ``pos``: scalar index
    of the current token (its K/V already written at ``cache[pos]``).
    Matches :func:`.ref.decode_attention_ref`.
    """
    c, h, dh = k_cache.shape
    assert q.shape == (h, dh)
    assert c % block_c == 0, f"C={c} not tileable by {block_c}"
    kt = jnp.transpose(k_cache, (1, 0, 2))  # [H, C, Dh]
    vt = jnp.transpose(v_cache, (1, 0, 2))
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_c=block_c, cap=c),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hh: (0,)),
            pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
            pl.BlockSpec((None, c, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((None, c, dh), lambda hh: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), q.dtype),
        interpret=True,
    )(pos_arr, q, kt, vt)
    return out
