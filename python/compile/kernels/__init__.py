"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

Exports the three hot-spot kernels plus RMSNorm, all interpret-mode (CPU
PJRT), each with a pure-jnp oracle in :mod:`.ref`.
"""

from .attention import decode_attention, prefill_attention
from .ffn import rmsnorm, swiglu_ffn

__all__ = ["prefill_attention", "decode_attention", "swiglu_ffn", "rmsnorm"]
