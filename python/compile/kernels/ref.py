"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain `jax.numpy` ops only. `python/tests/test_kernel.py`
asserts `assert_allclose(kernel(...), ref(...))` across hypothesis-driven
shape/dtype sweeps.
"""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """RMSNorm over the last axis: ``x * scale / rms(x)``."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def prefill_attention_ref(q, k, v, length):
    """Causal multi-head attention over a (padded) prompt.

    Args:
        q, k, v: ``[P, H, Dh]`` — padded to ``P`` tokens.
        length: scalar int — number of real tokens; keys at index >= length
            are masked out (so padding never contributes).

    Returns:
        ``[P, H, Dh]`` attention output (rows beyond ``length`` are
        unspecified — callers slice by ``length``).
    """
    p, _h, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    s = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(p)
    causal = idx[None, :] <= idx[:, None]  # [q, k]
    valid = idx[None, :] < length  # [1, k]
    mask = (causal & valid)[None, :, :]
    s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hqk,khd->qhd", a, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Single-token GEMV attention against a KV cache.

    Args:
        q: ``[H, Dh]`` — the new token's query.
        k_cache, v_cache: ``[C, H, Dh]`` — cache padded to capacity ``C``.
        pos: scalar int — the new token's position; cache entries at index
            > pos are masked (the token's own K/V is already written at
            index ``pos``).

    Returns:
        ``[H, Dh]``.
    """
    c, _h, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    s = jnp.einsum("hd,khd->hk", q.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(c)[None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hk,khd->hd", a, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


def swiglu_ffn_ref(x, w_gate, w_up, w_down):
    """SwiGLU FFN: ``(silu(x @ Wg) * (x @ Wu)) @ Wd``.

    Args:
        x: ``[N, D]``; ``w_gate``/``w_up``: ``[D, F]``; ``w_down``: ``[F, D]``.
    """
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    silu = g * jax.nn.sigmoid(g)
    return ((silu * u) @ w_down.astype(jnp.float32)).astype(x.dtype)
