"""Fused SwiGLU FFN Pallas kernel.

The FFN is the most FLOP-intensive dense op (§2.2) and the one that keeps
scaling with SMs the longest (Fig. 5b) — on TPU it is the canonical MXU
workload. This kernel fuses ``matmul → SiLU·gate → matmul`` per token tile
so the ``[block_n, d_ff]`` intermediate stays in VMEM and is never written
to HBM. ``interpret=True`` (see attention.py for why).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [block_n, d]
    g = x @ wg_ref[...].astype(jnp.float32)  # [block_n, f] — stays in VMEM
    u = x @ wu_ref[...].astype(jnp.float32)
    act = g * jax.nn.sigmoid(g) * u
    o_ref[...] = (act @ wd_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def swiglu_ffn(x, w_gate, w_up, w_down, *, block_n: int = 32):
    """``(silu(x @ Wg) * (x @ Wu)) @ Wd`` with the intermediate fused in VMEM.

    ``x: [N, D]``; weight shapes ``[D, F]``, ``[D, F]``, ``[F, D]``. ``N``
    is padded to a multiple of ``block_n`` internally. Matches
    :func:`.ref.swiglu_ffn_ref`.
    """
    n, d = x.shape
    f = w_gate.shape[1]
    assert w_gate.shape == (d, f) and w_up.shape == (d, f) and w_down.shape == (f, d)
    # Don't pad a tiny batch (decode: n=1) up to a full tile — shrink the
    # tile instead (interpret-mode cost scales with padded rows; on TPU a
    # sub-8 tile underfills the MXU but wastes no HBM traffic).
    block_n = min(block_n, _pow2_at_least(n))
    n_pad = (n + block_n - 1) // block_n * block_n
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x

    out = pl.pallas_call(
        functools.partial(_ffn_kernel),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=True,
    )(xp, w_gate, w_up, w_down)
    return out[:n]


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_n, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm(x, scale, *, eps: float = 1e-6, block_n: int = 32):
    """RMSNorm over the last axis; ``x: [N, D]``, ``scale: [D]``.

    Matches :func:`.ref.rmsnorm_ref`.
    """
    n, d = x.shape
    assert scale.shape == (d,)
    block_n = min(block_n, _pow2_at_least(n))
    n_pad = (n + block_n - 1) // block_n * block_n
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=True,
    )(xp, scale)
    return out[:n]

def _pow2_at_least(n: int) -> int:
    """Smallest power of two ≥ n (tile-shrink helper)."""
    p = 1
    while p < n:
        p <<= 1
    return p
