"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, lengths and scale regimes;
`assert_allclose` against `compile.kernels.ref` is the core signal.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, prefill_attention, rmsnorm, swiglu_ffn
from compile.kernels.ref import (
    decode_attention_ref,
    prefill_attention_ref,
    rmsnorm_ref,
    swiglu_ffn_ref,
)

SETTINGS = dict(max_examples=12, deadline=None)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.dtype(jnp.bfloat16) else dict(
        rtol=3e-5, atol=3e-5
    )


def randn(rng, shape, dtype, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- prefill


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    p_blocks=st.integers(1, 3),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([32, 64]),
    dtype=st.sampled_from([np.float32]),
)
def test_prefill_attention_matches_ref(seed, p_blocks, heads, dh, dtype):
    rng = np.random.default_rng(seed)
    p = 64 * p_blocks
    length = int(rng.integers(1, p + 1))
    q = randn(rng, (p, heads, dh), dtype)
    k = randn(rng, (p, heads, dh), dtype)
    v = randn(rng, (p, heads, dh), dtype)
    got = np.asarray(prefill_attention(q, k, v, jnp.int32(length)))
    want = np.asarray(prefill_attention_ref(q, k, v, length))
    np.testing.assert_allclose(got[:length], want[:length], **tol(np.dtype(dtype)))


def test_prefill_attention_ignores_padding():
    """Keys past `length` must not affect the valid rows."""
    rng = np.random.default_rng(7)
    p, h, dh, length = 128, 2, 32, 50
    q = randn(rng, (p, h, dh), np.float32)
    k = randn(rng, (p, h, dh), np.float32)
    v = randn(rng, (p, h, dh), np.float32)
    base = np.asarray(prefill_attention(q, k, v, jnp.int32(length)))[:length]
    k2, v2 = k.copy(), v.copy()
    k2[length:] = 1e6  # poison the padding
    v2[length:] = -1e6
    poisoned = np.asarray(prefill_attention(q, k2, v2, jnp.int32(length)))[:length]
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_prefill_attention_is_causal():
    """Changing a later token must not change earlier rows."""
    rng = np.random.default_rng(3)
    p, h, dh = 64, 2, 32
    q = randn(rng, (p, h, dh), np.float32)
    k = randn(rng, (p, h, dh), np.float32)
    v = randn(rng, (p, h, dh), np.float32)
    a = np.asarray(prefill_attention(q, k, v, jnp.int32(p)))
    k2, v2 = k.copy(), v.copy()
    k2[40:] += 5.0
    v2[40:] -= 5.0
    b = np.asarray(prefill_attention(q, k2, v2, jnp.int32(p)))
    np.testing.assert_allclose(a[:40], b[:40], rtol=1e-6, atol=1e-6)
    assert np.abs(a[41:] - b[41:]).max() > 1e-3, "later rows should change"


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 32), (32, 64), (64, 128)])
def test_prefill_attention_block_shapes_agree(block_q, block_k):
    """The flash tiling must be invariant to block-shape choices."""
    rng = np.random.default_rng(11)
    p, h, dh = 128, 2, 32
    q = randn(rng, (p, h, dh), np.float32)
    k = randn(rng, (p, h, dh), np.float32)
    v = randn(rng, (p, h, dh), np.float32)
    a = np.asarray(
        prefill_attention(q, k, v, jnp.int32(p), block_q=block_q, block_k=block_k)
    )
    want = np.asarray(prefill_attention_ref(q, k, v, p))
    np.testing.assert_allclose(a, want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------- decode


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    c_blocks=st.integers(1, 3),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([32, 64]),
)
def test_decode_attention_matches_ref(seed, c_blocks, heads, dh):
    rng = np.random.default_rng(seed)
    c = 64 * c_blocks
    pos = int(rng.integers(0, c))
    q = randn(rng, (heads, dh), np.float32)
    kc = randn(rng, (c, heads, dh), np.float32)
    vc = randn(rng, (c, heads, dh), np.float32)
    got = np.asarray(decode_attention(q, kc, vc, jnp.int32(pos)))
    want = np.asarray(decode_attention_ref(q, kc, vc, pos))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_decode_attention_masks_future_cache():
    rng = np.random.default_rng(5)
    c, h, dh, pos = 192, 4, 64, 20
    q = randn(rng, (h, dh), np.float32)
    kc = randn(rng, (c, h, dh), np.float32)
    vc = randn(rng, (c, h, dh), np.float32)
    base = np.asarray(decode_attention(q, kc, vc, jnp.int32(pos)))
    kc2, vc2 = kc.copy(), vc.copy()
    kc2[pos + 1 :] = 1e6
    vc2[pos + 1 :] = -1e6
    poisoned = np.asarray(decode_attention(q, kc2, vc2, jnp.int32(pos)))
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_decode_attention_pos_zero_attends_self_only():
    rng = np.random.default_rng(9)
    c, h, dh = 64, 2, 32
    q = randn(rng, (h, dh), np.float32)
    kc = randn(rng, (c, h, dh), np.float32)
    vc = randn(rng, (c, h, dh), np.float32)
    got = np.asarray(decode_attention(q, kc, vc, jnp.int32(0)))
    # Softmax over one element == that element's V.
    np.testing.assert_allclose(got, vc[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- FFN / norm


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 100),
    d=st.sampled_from([64, 256]),
    f=st.sampled_from([128, 1024]),
    scale=st.sampled_from([0.02, 1.0]),
)
def test_swiglu_ffn_matches_ref(seed, n, d, f, scale):
    rng = np.random.default_rng(seed)
    x = randn(rng, (n, d), np.float32)
    wg = randn(rng, (d, f), np.float32, scale)
    wu = randn(rng, (d, f), np.float32, scale)
    wd = randn(rng, (f, d), np.float32, scale)
    got = np.asarray(swiglu_ffn(x, wg, wu, wd))
    want = np.asarray(swiglu_ffn_ref(x, wg, wu, wd))
    assert got.shape == (n, d)
    # f32 accumulation-order differences scale with the output magnitude
    # (scale=1.0 drives activations to O(1e3)); compare relative to it.
    atol = 2e-6 * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=atol)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 70),
    d=st.sampled_from([32, 256]),
)
def test_rmsnorm_matches_ref(seed, n, d):
    rng = np.random.default_rng(seed)
    x = randn(rng, (n, d), np.float32, 3.0)
    s = randn(rng, (d,), np.float32)
    got = np.asarray(rmsnorm(x, s))
    want = np.asarray(rmsnorm_ref(x, s))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_rmsnorm_unit_output_scale():
    """With scale=1, output rows must have RMS ≈ 1."""
    rng = np.random.default_rng(1)
    x = randn(rng, (8, 128), np.float32, 10.0)
    out = np.asarray(rmsnorm(x, np.ones(128, np.float32)))
    rms = np.sqrt((out**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
