"""Layer-2 correctness: model shapes, prefill↔decode consistency, AOT build.

The decisive test is `test_decode_matches_incremental_prefill`: the logits a
decode step produces from the prefill-built KV cache must equal the logits a
longer prefill produces directly — this is the invariant the rust runtime's
token loop relies on.
"""

import json
import pathlib

import numpy as np
import pytest
import jax.numpy as jnp

from compile.model import (
    Arch,
    TINY,
    decode,
    init_params,
    param_specs,
    prefill,
    reference_generate,
)

# A smaller arch for the expensive sweeps (same code paths, faster trace).
SMALL = Arch(layers=2, d=64, heads=2, kv_heads=2, d_ff=128, vocab=64,
             max_prompt=64, kv_cap=128, decode_batch=2)


@pytest.fixture(scope="module")
def small_params():
    return [jnp.asarray(p) for p in init_params(SMALL, 0)]


@pytest.fixture(scope="module")
def tiny_params():
    return [jnp.asarray(p) for p in init_params(TINY, 0)]


def test_param_specs_count_and_order():
    specs = param_specs(TINY)
    assert specs[0][0] == "embed"
    assert specs[-1][0] == "lm_head"
    assert len(specs) == 2 + 9 * TINY.layers + 1
    # ~4.5M params for the tiny config (DESIGN.md).
    assert 3e6 < TINY.params_count() < 6e6


def test_init_deterministic():
    a = init_params(SMALL, 7)
    b = init_params(SMALL, 7)
    c = init_params(SMALL, 8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(np.abs(x - y).max() > 0 for x, y in zip(a, c))


def test_prefill_shapes(small_params):
    tokens = np.zeros(SMALL.max_prompt, np.int32)
    tokens[:5] = [1, 2, 3, 4, 5]
    logits, kv = prefill(SMALL, small_params, jnp.asarray(tokens), jnp.int32(5))
    assert logits.shape == (SMALL.vocab,)
    assert kv.shape == (SMALL.layers, 2, SMALL.kv_cap, SMALL.kv_dim)
    # KV rows past `length` must be zero (decode-capacity padding).
    assert np.abs(np.asarray(kv)[:, :, 5:]).max() == 0.0


def test_prefill_padding_invariant(small_params):
    """Garbage in the padded token tail must not change the result."""
    base = np.zeros(SMALL.max_prompt, np.int32)
    base[:6] = [9, 8, 7, 6, 5, 4]
    poisoned = base.copy()
    poisoned[6:] = 63  # junk tokens past `length`
    l1, kv1 = prefill(SMALL, small_params, jnp.asarray(base), jnp.int32(6))
    l2, kv2 = prefill(SMALL, small_params, jnp.asarray(poisoned), jnp.int32(6))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=1e-6, atol=1e-6)


def test_decode_shapes(small_params):
    b = SMALL.decode_batch
    kv = jnp.zeros((b, SMALL.layers, 2, SMALL.kv_cap, SMALL.kv_dim), jnp.float32)
    logits, kv2 = decode(
        SMALL, small_params, jnp.zeros(b, jnp.int32), jnp.zeros(b, jnp.int32), kv
    )
    assert logits.shape == (b, SMALL.vocab)
    assert kv2.shape == kv.shape


def test_decode_matches_incremental_prefill(small_params):
    """decode(prefill KV, next token) == prefill(prompt + next token)."""
    prompt = np.array([3, 10, 7, 60, 45, 9, 2], np.int32)
    n = len(prompt)
    tokens = np.zeros(SMALL.max_prompt, np.int32)
    tokens[:n] = prompt
    logits, kv = prefill(SMALL, small_params, jnp.asarray(tokens), jnp.int32(n))
    nxt = int(jnp.argmax(logits))

    tokens2 = tokens.copy()
    tokens2[n] = nxt
    want, _ = prefill(SMALL, small_params, jnp.asarray(tokens2), jnp.int32(n + 1))

    b = SMALL.decode_batch
    kv_b = jnp.zeros((b, SMALL.layers, 2, SMALL.kv_cap, SMALL.kv_dim)).at[0].set(kv)
    tok = jnp.zeros(b, jnp.int32).at[0].set(nxt)
    pos = jnp.zeros(b, jnp.int32).at[0].set(n)
    got, _ = decode(SMALL, small_params, tok, pos, kv_b)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_decode_slots_are_independent(small_params):
    """One slot's tokens/KV must not leak into another's logits."""
    b = SMALL.decode_batch
    rngkv = np.random.default_rng(0).standard_normal(
        (b, SMALL.layers, 2, SMALL.kv_cap, SMALL.kv_dim)
    ).astype(np.float32) * 0.1
    tok = jnp.asarray(np.array([5, 6], np.int32))
    pos = jnp.asarray(np.array([3, 4], np.int32))
    l1, _ = decode(SMALL, small_params, tok, pos, jnp.asarray(rngkv))
    # Change slot 1's state entirely; slot 0's logits must be unchanged.
    rngkv2 = rngkv.copy()
    rngkv2[1] += 1.0
    tok2 = jnp.asarray(np.array([5, 60], np.int32))
    l2, _ = decode(SMALL, small_params, tok2, pos, jnp.asarray(rngkv2))
    np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[0]), rtol=1e-6, atol=1e-6)
    assert np.abs(np.asarray(l1[1]) - np.asarray(l2[1])).max() > 1e-4


def test_greedy_generation_deterministic(small_params):
    prompt = np.array([1, 2, 3], np.int32)
    a = reference_generate(SMALL, small_params, prompt, steps=5)
    b = reference_generate(SMALL, small_params, prompt, steps=5)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (5,)
    assert (a >= 0).all() and (a < SMALL.vocab).all()


@pytest.mark.slow
def test_aot_build_writes_consistent_artifacts(tmp_path):
    """Full AOT pass on a small arch: manifest/weights/HLO all consistent."""
    from compile import aot

    arch = SMALL
    aot.build(tmp_path, seed=0, arch=arch)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["model"]["layers"] == arch.layers
    total = sum(int(np.prod(t["shape"])) for t in manifest["weights"]["tensors"])
    assert (tmp_path / "weights.bin").stat().st_size == total * 4
    for entry in manifest["entries"]:
        text = (tmp_path / entry["file"]).read_text()
        assert text.startswith("HloModule"), f"{entry['name']} is not HLO text"
    names = {e["name"] for e in manifest["entries"]}
    assert names == {"prefill", "decode"}


def test_artifacts_dir_if_built():
    """If `make artifacts` ran, the checked artifacts must be loadable."""
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    if not (art / "manifest.json").exists():
        pytest.skip("artifacts not built")
    manifest = json.loads((art / "manifest.json").read_text())
    total = sum(int(np.prod(t["shape"])) for t in manifest["weights"]["tensors"])
    assert (art / manifest["weights"]["file"]).stat().st_size == total * 4
    for entry in manifest["entries"]:
        assert (art / entry["file"]).read_text().startswith("HloModule")
