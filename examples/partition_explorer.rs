//! Partition explorer: visualize what the cost model sees and what the
//! Algorithm-1 greedy search decides across the whole SM-split range.
//!
//! Prints (a) predicted prefill/decode latency at every quantized SM split,
//! (b) the decision the controller takes in both objective modes, and
//! (c) the hysteresis behavior across a sweep of KV usage.
//!
//! ```sh
//! cargo run --release --example partition_explorer -- --chunk 512 --batch 32
//! ```

use nexus::costmodel::calibrate;
use nexus::gpusim::GpuSpec;
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::util::cli::Args;
use nexus::util::fmt::{dur, Table};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let chunk = args.get_usize("chunk", 512);
    let batch = args.get_usize("batch", 32);
    let kv_len = args.get_f64("kv-len", 4000.0);
    let ctx = args.get_f64("ctx", 1800.0);

    let gpu = GpuSpec::l20();
    let cost = calibrate(&gpu);
    let model = ModelConfig::qwen3b();
    let pre = model.prefill_ops(chunk, chunk as f64 * kv_len, kv_len, 0);
    let dec = model.decode_ops(batch, batch as f64 * ctx);

    // (a) the latency surface over quantized splits.
    let mut t = Table::new(
        &format!("cost surface — chunk {chunk} @ kv {kv_len}, decode {batch} @ ctx {ctx}"),
        &["prefill SMs", "T_prefill", "T_decode (contended)", "max"],
    );
    let groups = 12; // ceil(92 / 8)
    for g in 1..groups {
        let r_p = g as f64 / groups as f64;
        let ph = cost.prefill(&pre, r_p);
        let td = cost.decode(&dec, 1.0 - r_p, Some(&ph.pressure));
        t.row(&[
            format!("{:>3.0}%", r_p * 100.0),
            dur(ph.total),
            dur(td),
            dur(ph.total.max(td)),
        ]);
    }
    t.print();

    // (b) the greedy decision in both modes.
    for (kv_u, label) in [(0.3, "prefill-prioritized (KV_u=0.30)"),
                          (0.9, "decode-prioritized  (KV_u=0.90)")] {
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let d = ctl.decide(
            &cost,
            &BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: kv_u },
        );
        println!(
            "{label}: prefill {:>3.0}% / decode {:>3.0}%  ({} queries)",
            d.r_p * 100.0,
            d.r_d * 100.0,
            d.queries
        );
    }

    // (c) hysteresis under a KV-usage ramp.
    let mut ctl = PartitionController::new(PartitionConfig::default());
    let mut applied = 0;
    let mut suppressed = 0;
    for i in 0..20 {
        let kv_u = 0.3 + 0.03 * i as f64;
        let d = ctl.decide(
            &cost,
            &BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: kv_u },
        );
        if d.applied {
            applied += 1;
        } else {
            suppressed += 1;
        }
    }
    println!(
        "KV ramp 0.30→0.87: {applied} repartitions applied, {suppressed} suppressed by the δ buffer"
    );
}
