//! Offline (throughput-oriented) inference: all requests submitted at t=0,
//! engines race on makespan — the paper's §6.3 scenario.
//!
//! ```sh
//! cargo run --release --example offline_batch -- --dataset ldc --n 80
//! ```

use nexus::coordinator::{offline_makespan, Experiment};
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::cli::Args;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let dataset = Dataset::by_name(&args.get_or("dataset", "ldc")).expect("dataset");
    let n = args.get_usize("n", 80);
    let model = match dataset {
        Dataset::Mixed => ModelConfig::llama8b(),
        _ => ModelConfig::qwen3b(),
    };
    let mut exp = Experiment::new(model, dataset, n, 1.0);
    exp.seed = args.get_u64("seed", 42);

    println!("offline batch: {} requests of {} on {}", n, dataset.name(), model.name);
    let mut t = Table::new(
        "offline makespan (X = timeout)",
        &["engine", "makespan", "tok/s", "recomputes", "gpus"],
    );
    for &kind in EngineKind::all() {
        eprintln!("  running {}...", kind.name());
        match offline_makespan(kind, &exp) {
            Some((mk, m)) => {
                let s = m.summary();
                t.row(&[
                    kind.name().to_string(),
                    dur(mk),
                    format!("{:.0}", s.token_throughput),
                    format!("{}", m.recomputes),
                    format!("{}", kind.gpus(&exp.model)),
                ]);
            }
            None => t.row(&[
                kind.name().to_string(),
                "X".into(),
                String::new(),
                String::new(),
                format!("{}", kind.gpus(&exp.model)),
            ]),
        }
    }
    t.print();
}
