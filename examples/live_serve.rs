//! End-to-end driver on the REAL compute path: loads the AOT-compiled tiny
//! model (Layer-1 Pallas kernels inside a Layer-2 JAX graph, lowered to HLO
//! and executed through the PJRT C API) and serves a batched Poisson
//! workload through the Layer-3 server, reporting wall-clock latency and
//! throughput. This proves all three layers compose: Python is not running
//! — only `artifacts/*.hlo.txt` + `weights.bin` are.
//!
//! ```sh
//! make artifacts   # once
//! cargo run --release --example live_serve -- --requests 24 --rate 6
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use nexus::runtime::Runtime;
use nexus::server::{ServeRequest, Server, ServerCfg};
use nexus::util::cli::Args;
use nexus::util::fmt::dur;
use nexus::util::rng::Rng;
use nexus::util::{mean, percentile};
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 6.0);
    let seed = args.get_u64("seed", 42);
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts", Runtime::default_dir().to_str().unwrap()),
    );

    // Sanity: single-request path straight through the runtime first.
    eprintln!("loading + compiling artifacts from {} ...", dir.display());
    let t_load = Instant::now();
    let rt = Runtime::load(&dir).expect("run `make artifacts` first");
    eprintln!(
        "compiled prefill+decode for tiny-{}L/d{} in {:.2}s",
        rt.dims.layers,
        rt.dims.d,
        t_load.elapsed().as_secs_f64()
    );
    let out = rt.prefill(&[1, 2, 3, 4, 5]).expect("prefill");
    eprintln!(
        "smoke prefill ok: argmax(logits[{}]) = {}",
        out.logits.len(),
        Runtime::argmax(&out.logits)
    );
    drop(rt);

    // The served workload: Poisson arrivals of random-token prompts.
    let mut server = Server::start(dir, ServerCfg::default()).expect("server");
    server.wait_ready().expect("artifact load");
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    for id in 0..n {
        let len = rng.range_usize(4, 64);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        server
            .submit(ServeRequest { id, prompt, max_tokens: rng.range_usize(8, 32) })
            .unwrap();
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut ttfts = Vec::new();
    let mut gaps = Vec::new();
    let mut e2es = Vec::new();
    let mut tokens = 0usize;
    for _ in 0..n {
        let r = server.recv().expect("response");
        assert!(!r.tokens.is_empty(), "request {} produced no tokens", r.id);
        ttfts.push(r.ttft);
        e2es.push(r.e2e);
        gaps.extend(r.gaps);
        tokens += r.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    println!("== live PJRT serving (tiny model, CPU PJRT, interpret-mode Pallas) ==");
    println!("requests      : {n}");
    println!("output tokens : {tokens}");
    println!("wall time     : {:.2}s  ({:.1} tok/s, {:.2} req/s)", wall,
             tokens as f64 / wall, n as f64 / wall);
    println!("TTFT          : mean {} | p95 {}", dur(mean(&ttfts)), dur(percentile(&ttfts, 95.0)));
    println!("TBT           : mean {} | p95 {}", dur(mean(&gaps)), dur(percentile(&gaps, 95.0)));
    println!("E2E           : mean {} | p95 {}", dur(mean(&e2es)), dur(percentile(&e2es, 95.0)));
}
