//! Quickstart: the three core Nexus mechanisms in ~60 lines of API use.
//!
//! 1. Calibrate the contention-aware cost model (one-time pass, §4.1.1).
//! 2. Ask the Algorithm-1 controller for an SM partition for a live batch.
//! 3. Run a full serving experiment and compare Nexus against vLLM.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nexus::cluster::{run_cluster, ClusterCfg, RoutingPolicy};
use nexus::coordinator::Experiment;
use nexus::costmodel::calibrate;
use nexus::engine::EngineKind;
use nexus::gpusim::GpuSpec;
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::util::fmt::dur;
use nexus::workload::Dataset;

fn main() {
    // --- 1. one-time calibration of the Eq.-7 curves on the L20 substrate.
    let gpu = GpuSpec::l20();
    let cost = calibrate(&gpu);
    let model = ModelConfig::qwen3b();
    println!(
        "calibrated cost model for {} on {} ({} SMs, {:.0} GB/s)",
        model.name,
        gpu.name,
        gpu.sm_count,
        gpu.mem_bw / 1e9
    );

    // --- 2. a per-batch partition decision (Algorithm 1).
    let prefill_ops = model.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
    let decode_ops = model.decode_ops(32, 32.0 * 1800.0);
    let mut controller = PartitionController::new(PartitionConfig::default());
    let decision = controller.decide(
        &cost,
        &BatchState { prefill_ops: &prefill_ops, decode_ops: &decode_ops, kv_usage: 0.42 },
    );
    println!(
        "partition decision: prefill {:.0}% / decode {:.0}% ({:?}, {} cost-model queries)",
        decision.r_p * 100.0,
        decision.r_d * 100.0,
        decision.mode,
        decision.queries
    );
    let t_pre = cost.prefill(&prefill_ops, decision.r_p).total;
    let t_dec = cost.decode(&decode_ops, decision.r_d, None);
    println!("predicted: prefill iter {} | decode iter {}", dur(t_pre), dur(t_dec));

    // --- 3. an end-to-end serving comparison on a ShareGPT-like trace.
    let exp = Experiment::new(model, Dataset::ShareGpt, 60, 4.0);
    for kind in [EngineKind::Vllm, EngineKind::Nexus] {
        let s = exp.run(kind).summary();
        println!(
            "{:>6}: mean TTFT {} | mean TBT {} | norm latency {}",
            kind.name(),
            dur(s.mean_ttft),
            dur(s.mean_tbt),
            dur(s.mean_norm)
        );
    }
    // --- 4. the same workload on a small replica fleet (cluster layer,
    //        event-queue co-simulation).
    let cc = ClusterCfg::new(
        EngineKind::Nexus,
        exp.cfg(),
        4,
        RoutingPolicy::JoinShortestQueue,
    );
    let fleet = run_cluster(&cc, &exp.trace());
    println!(
        "fleet 4x Nexus (JSQ): p95 TTFT {} over {} virtual events",
        dur(fleet.summary().p95_ttft),
        fleet.events
    );

    println!("done — see `nexus compare` and rust/benches/ for the full evaluation");
}
