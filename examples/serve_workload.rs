//! Online serving scenario: the paper's §6.2 single-GPU evaluation in
//! miniature — every engine on a bursty Mixed workload (60% chat / 40%
//! long-document), reporting the Fig.-9 metric set plus engine internals.
//!
//! ```sh
//! cargo run --release --example serve_workload -- --n 150 --rate 3.0
//! ```

use nexus::coordinator::Experiment;
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::cli::Args;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 120);
    let rate = args.get_f64("rate", 3.0);
    let seed = args.get_u64("seed", 42);

    let mut exp = Experiment::new(ModelConfig::llama8b(), Dataset::Mixed, n, rate);
    exp.seed = seed;
    println!(
        "Mixed workload on {} — {} requests at {} req/s (seed {})",
        exp.model.name, n, rate, seed
    );

    let mut t = Table::new(
        "online serving (single L20; vLLM-P/D uses two)",
        &["engine", "TTFT", "TTFT95", "TBT", "TBT95", "norm", "repart", "recomp", "gpus"],
    );
    for &kind in EngineKind::all() {
        eprintln!("  running {}...", kind.name());
        let m = exp.run(kind);
        let s = m.summary();
        t.row(&[
            kind.name().to_string(),
            dur(s.mean_ttft),
            dur(s.p95_ttft),
            dur(s.mean_tbt),
            dur(s.p95_tbt),
            dur(s.mean_norm),
            format!("{}", m.repartitions),
            format!("{}", m.recomputes),
            format!("{}", kind.gpus(&exp.model)),
        ]);
    }
    t.print();
    println!("(expected shape: Nexus lowest TTFT/norm on one GPU; vLLM-P/D best TBT on two)");
}
