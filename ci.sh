#!/usr/bin/env bash
# CI for the rust crate: build, tests, formatting, lints.
# Usage: ./ci.sh   (or `make ci`)
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# The sharded fleet loop's determinism guarantee (run_parallel digest ==
# run digest for any thread count / window) is the one invariant worth
# paying optimized-build time for: debug-only asserts can mask ordering
# races that only bite under release scheduling.
run cargo test --release --test golden_digest parallel -q
run cargo test --release --test prop_cluster prop_parallel -q
# Work stealing adds a second scheduling degree of freedom (migrations at
# rendezvous boundaries); pin its golden-equality suite — stealing on, off,
# and sequential across thread counts — under release scheduling too.
run cargo test --release --test golden_digest stealing -q
run cargo test --release --test golden_digest stream_arrivals -q
run cargo test --release --test golden_trace stealing -q
# Multi-tenant serving: the WFQ fairness/quota property battery plus the
# tenant golden suites (three-way digests under churn, tenant trace
# events) — the gate adds a scheduling stage, so pin it under release
# scheduling like the other fleet invariants.
run cargo test --release --test prop_tenant -q
run cargo test --release --test golden_digest wfq -q
run cargo test --release --test golden_trace tenant -q
run cargo test --release --test golden_trace wfq -q
# Fleet prefix caching: store/tier invariants plus the TTFT headline
# (prop_prefix), the prefix-aware three-way digest sweeps, and the prefix
# trace events. Prefix routing state lives in the coordinator and must stay
# digest-identical across all three loops, so pin these under release
# scheduling like the other fleet invariants.
run cargo test --release --test prop_prefix -q
run cargo test --release --test golden_digest prefix -q
run cargo test --release --test golden_trace prefix -q
# Benches are the perf harness of record (BENCH_hotpath.json); keep them
# compiling without paying their runtime in CI.
run cargo bench --no-run
# CLI smoke: the same seed through the sharded loop twice must print the
# identical fleet summary (stdout carries the metrics tables; stderr the
# progress chatter).
run_cluster_cli() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 2>/dev/null
}
echo
echo "==> cluster --threads 2 determinism smoke"
run_cluster_cli >/tmp/nexus_par_a.txt
run_cluster_cli >/tmp/nexus_par_b.txt
diff /tmp/nexus_par_a.txt /tmp/nexus_par_b.txt
echo "    identical output across runs"
# Same smoke with work stealing enabled: two runs must agree with each
# other AND with the static-sharding run above (stealing is scheduling
# metadata — the fleet summary on stdout must not move).
run_cluster_cli_steal() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 \
        --steal-threshold 1.5 --balance-interval 1.0 2>/dev/null
}
echo
echo "==> cluster --steal-threshold determinism smoke"
run_cluster_cli_steal >/tmp/nexus_steal_a.txt
run_cluster_cli_steal >/tmp/nexus_steal_b.txt
diff /tmp/nexus_steal_a.txt /tmp/nexus_steal_b.txt
diff /tmp/nexus_steal_a.txt /tmp/nexus_par_a.txt
echo "    identical output across runs and vs static sharding"
# Multi-tenant smoke on the same seed: tenant labels alone must not move a
# byte of the fleet summary; a trivial WFQ gate (uniform weights, no
# quotas) must be deterministic and only *append* the per-tenant report.
run_cluster_cli_tenants() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 --tenants 3 2>/dev/null
}
run_cluster_cli_wfq() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 --tenants 3 \
        --wfq 2>/dev/null
}
echo
echo "==> cluster --wfq on/off smoke"
run_cluster_cli_tenants >/tmp/nexus_tn_off.txt
diff /tmp/nexus_tn_off.txt /tmp/nexus_par_a.txt
run_cluster_cli_wfq >/tmp/nexus_wfq_a.txt
run_cluster_cli_wfq >/tmp/nexus_wfq_b.txt
diff /tmp/nexus_wfq_a.txt /tmp/nexus_wfq_b.txt
grep -q "per-tenant SLO" /tmp/nexus_wfq_a.txt
diff /tmp/nexus_tn_off.txt \
    <(head -n "$(wc -l < /tmp/nexus_tn_off.txt)" /tmp/nexus_wfq_a.txt)
echo "    tenant tags free; wfq deterministic; report appended only"
# Fleet prefix-cache smoke: prefix-aware routing on the same seed twice must
# print identical output (including the cache stats line); on the chat-heavy
# ShareGPT workload it must beat session affinity on mean TTFT, and on the
# low-reuse arxiv workload it must not lose (≤ 5 % tolerance). Mean TTFT is
# column 4 of the fleet-summary row, unit-suffixed by `dur()`.
run_cluster_cli_policy() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy "$1" \
        --dataset "$2" --n "$3" --rate "$4" --seed 7 --threads 2 --window 0.5 \
        2>/dev/null
}
ttft_s() {
    awk '/^nexus x/ {
        v = $4
        if (v ~ /us$/)      { sub(/us$/, "", v); v /= 1e6 }
        else if (v ~ /ms$/) { sub(/ms$/, "", v); v /= 1e3 }
        else                { sub(/s$/, "", v) }
        print v
    }' "$1"
}
echo
echo "==> cluster --policy prefix smoke (chat: must win TTFT vs affinity)"
run_cluster_cli_policy prefix sharegpt 120 12 >/tmp/nexus_pfx_a.txt
run_cluster_cli_policy prefix sharegpt 120 12 >/tmp/nexus_pfx_b.txt
diff /tmp/nexus_pfx_a.txt /tmp/nexus_pfx_b.txt
grep -q "prefix cache: hit rate" /tmp/nexus_pfx_a.txt
run_cluster_cli_policy affinity sharegpt 120 12 >/tmp/nexus_aff.txt
if grep -q "prefix cache:" /tmp/nexus_aff.txt; then
    echo "affinity run must not engage the prefix machinery"
    exit 1
fi
p=$(ttft_s /tmp/nexus_pfx_a.txt)
a=$(ttft_s /tmp/nexus_aff.txt)
awk -v a="$a" -v p="$p" 'BEGIN { exit !(p < a) }' || {
    echo "prefix TTFT ${p}s did not beat affinity ${a}s on chat"
    exit 1
}
echo "    deterministic; chat TTFT: prefix ${p}s < affinity ${a}s"
echo
echo "==> cluster --policy prefix smoke (single-turn arxiv: must not lose)"
run_cluster_cli_policy prefix arxiv 80 3 >/tmp/nexus_pfx_ax.txt
run_cluster_cli_policy affinity arxiv 80 3 >/tmp/nexus_aff_ax.txt
p=$(ttft_s /tmp/nexus_pfx_ax.txt)
a=$(ttft_s /tmp/nexus_aff_ax.txt)
awk -v a="$a" -v p="$p" 'BEGIN { exit !(p <= 1.05 * a) }' || {
    echo "prefix TTFT ${p}s lost vs affinity ${a}s on arxiv"
    exit 1
}
echo "    arxiv TTFT: prefix ${p}s <= 1.05x affinity ${a}s"
# fmt/clippy are advisory gates: present in some toolchain images, absent in
# minimal ones. Fail on findings, skip cleanly when the component is missing.
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all -- --check
else
    echo "==> cargo fmt not installed; skipping"
fi
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping"
fi

echo
echo "CI OK"
