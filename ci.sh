#!/usr/bin/env bash
# CI for the rust crate: build, tests, formatting, lints.
# Usage: ./ci.sh   (or `make ci`)
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# Benches are the perf harness of record (BENCH_hotpath.json); keep them
# compiling without paying their runtime in CI.
run cargo bench --no-run
# fmt/clippy are advisory gates: present in some toolchain images, absent in
# minimal ones. Fail on findings, skip cleanly when the component is missing.
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all -- --check
else
    echo "==> cargo fmt not installed; skipping"
fi
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping"
fi

echo
echo "CI OK"
