#!/usr/bin/env bash
# CI for the rust crate: build, tests, formatting, lints.
# Usage: ./ci.sh   (or `make ci`)
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# The sharded fleet loop's determinism guarantee (run_parallel digest ==
# run digest for any thread count / window) is the one invariant worth
# paying optimized-build time for: debug-only asserts can mask ordering
# races that only bite under release scheduling.
run cargo test --release --test golden_digest parallel -q
run cargo test --release --test prop_cluster prop_parallel -q
# Benches are the perf harness of record (BENCH_hotpath.json); keep them
# compiling without paying their runtime in CI.
run cargo bench --no-run
# CLI smoke: the same seed through the sharded loop twice must print the
# identical fleet summary (stdout carries the metrics tables; stderr the
# progress chatter).
run_cluster_cli() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 2>/dev/null
}
echo
echo "==> cluster --threads 2 determinism smoke"
run_cluster_cli >/tmp/nexus_par_a.txt
run_cluster_cli >/tmp/nexus_par_b.txt
diff /tmp/nexus_par_a.txt /tmp/nexus_par_b.txt
echo "    identical output across runs"
# fmt/clippy are advisory gates: present in some toolchain images, absent in
# minimal ones. Fail on findings, skip cleanly when the component is missing.
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all -- --check
else
    echo "==> cargo fmt not installed; skipping"
fi
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping"
fi

echo
echo "CI OK"
