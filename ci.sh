#!/usr/bin/env bash
# CI for the rust crate: build, tests, formatting, lints.
# Usage: ./ci.sh   (or `make ci`)
set -euo pipefail
cd "$(dirname "$0")/rust"

run() {
    echo
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# The sharded fleet loop's determinism guarantee (run_parallel digest ==
# run digest for any thread count / window) is the one invariant worth
# paying optimized-build time for: debug-only asserts can mask ordering
# races that only bite under release scheduling.
run cargo test --release --test golden_digest parallel -q
run cargo test --release --test prop_cluster prop_parallel -q
# Work stealing adds a second scheduling degree of freedom (migrations at
# rendezvous boundaries); pin its golden-equality suite — stealing on, off,
# and sequential across thread counts — under release scheduling too.
run cargo test --release --test golden_digest stealing -q
run cargo test --release --test golden_digest stream_arrivals -q
run cargo test --release --test golden_trace stealing -q
# Multi-tenant serving: the WFQ fairness/quota property battery plus the
# tenant golden suites (three-way digests under churn, tenant trace
# events) — the gate adds a scheduling stage, so pin it under release
# scheduling like the other fleet invariants.
run cargo test --release --test prop_tenant -q
run cargo test --release --test golden_digest wfq -q
run cargo test --release --test golden_trace tenant -q
run cargo test --release --test golden_trace wfq -q
# Benches are the perf harness of record (BENCH_hotpath.json); keep them
# compiling without paying their runtime in CI.
run cargo bench --no-run
# CLI smoke: the same seed through the sharded loop twice must print the
# identical fleet summary (stdout carries the metrics tables; stderr the
# progress chatter).
run_cluster_cli() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 2>/dev/null
}
echo
echo "==> cluster --threads 2 determinism smoke"
run_cluster_cli >/tmp/nexus_par_a.txt
run_cluster_cli >/tmp/nexus_par_b.txt
diff /tmp/nexus_par_a.txt /tmp/nexus_par_b.txt
echo "    identical output across runs"
# Same smoke with work stealing enabled: two runs must agree with each
# other AND with the static-sharding run above (stealing is scheduling
# metadata — the fleet summary on stdout must not move).
run_cluster_cli_steal() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 \
        --steal-threshold 1.5 --balance-interval 1.0 2>/dev/null
}
echo
echo "==> cluster --steal-threshold determinism smoke"
run_cluster_cli_steal >/tmp/nexus_steal_a.txt
run_cluster_cli_steal >/tmp/nexus_steal_b.txt
diff /tmp/nexus_steal_a.txt /tmp/nexus_steal_b.txt
diff /tmp/nexus_steal_a.txt /tmp/nexus_par_a.txt
echo "    identical output across runs and vs static sharding"
# Multi-tenant smoke on the same seed: tenant labels alone must not move a
# byte of the fleet summary; a trivial WFQ gate (uniform weights, no
# quotas) must be deterministic and only *append* the per-tenant report.
run_cluster_cli_tenants() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 --tenants 3 2>/dev/null
}
run_cluster_cli_wfq() {
    ./target/release/nexus cluster --engine nexus --replicas 6 --policy jsq \
        --n 120 --rate 12 --seed 7 --threads 2 --window 0.5 --tenants 3 \
        --wfq 2>/dev/null
}
echo
echo "==> cluster --wfq on/off smoke"
run_cluster_cli_tenants >/tmp/nexus_tn_off.txt
diff /tmp/nexus_tn_off.txt /tmp/nexus_par_a.txt
run_cluster_cli_wfq >/tmp/nexus_wfq_a.txt
run_cluster_cli_wfq >/tmp/nexus_wfq_b.txt
diff /tmp/nexus_wfq_a.txt /tmp/nexus_wfq_b.txt
grep -q "per-tenant SLO" /tmp/nexus_wfq_a.txt
diff /tmp/nexus_tn_off.txt \
    <(head -n "$(wc -l < /tmp/nexus_tn_off.txt)" /tmp/nexus_wfq_a.txt)
echo "    tenant tags free; wfq deterministic; report appended only"
# fmt/clippy are advisory gates: present in some toolchain images, absent in
# minimal ones. Fail on findings, skip cleanly when the component is missing.
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --all -- --check
else
    echo "==> cargo fmt not installed; skipping"
fi
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping"
fi

echo
echo "CI OK"
