//! Cross-layer integration: the rust PJRT runtime must reproduce, token for
//! token, the greedy generation that the JAX/Pallas stack computed at AOT
//! time (recorded in `manifest.json` under `"reference"`).
//!
//! Requires `make artifacts`; every test skips cleanly when they are absent
//! (e.g. in a rust-only environment). The whole file is compiled only with
//! the `pjrt` feature (the default build has no PJRT dependency).
#![cfg(feature = "pjrt")]

use nexus::runtime::{Manifest, Runtime};
use nexus::server::{ServeRequest, Server, ServerCfg};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("NEXUS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

fn reference(dir: &PathBuf) -> (Vec<i32>, usize, Vec<i32>) {
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let j = nexus::util::json::Json::parse(&text).unwrap();
    let r = j.get("reference").expect("manifest.reference (rebuild artifacts)");
    let ints = |k: &str| -> Vec<i32> {
        r.get(k)
            .and_then(|x| x.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect()
    };
    let steps = r.get("steps").and_then(|x| x.as_usize()).unwrap();
    (ints("prompt"), steps, ints("tokens"))
}

#[test]
fn manifest_loads_and_matches_dims() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.dims.vocab, 512);
    assert!(m.total_weight_elems() > 1_000_000);
    // Weight file size must match the tensor table exactly.
    let len = std::fs::metadata(dir.join(&m.weights_file)).unwrap().len();
    assert_eq!(len as usize, m.total_weight_elems() * 4);
}

#[test]
fn greedy_generation_matches_jax_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (prompt, steps, expect) = reference(&dir);
    let rt = Runtime::load(&dir).unwrap();
    let d = rt.dims;

    // Prefill → first token.
    let out = rt.prefill(&prompt).unwrap();
    let mut tokens = vec![Runtime::argmax(&out.logits)];

    // Decode loop in slot 0 of the batched entry.
    let mut kv = vec![0.0f32; d.batch_kv_elems()];
    kv[..d.kv_elems()].copy_from_slice(&out.kv);
    for i in 0..steps - 1 {
        let mut tok = vec![0i32; d.decode_batch];
        let mut pos = vec![0i32; d.decode_batch];
        tok[0] = *tokens.last().unwrap();
        pos[0] = (prompt.len() + i) as i32;
        let logits = rt.decode(&tok, &pos, &mut kv).unwrap();
        tokens.push(Runtime::argmax(&logits[..d.vocab]));
    }
    assert_eq!(
        tokens, expect,
        "rust PJRT token loop diverged from the JAX/Pallas reference"
    );
}

#[test]
fn prefill_rejects_bad_lengths() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    assert!(rt.prefill(&[]).is_err());
    let too_long = vec![1i32; rt.dims.max_prompt + 1];
    assert!(rt.prefill(&too_long).is_err());
}

#[test]
fn decode_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let d = rt.dims;
    let mut kv = vec![0.0f32; d.batch_kv_elems()];
    assert!(rt.decode(&[0], &[0], &mut kv).is_err(), "batch width must match");
    let mut short_kv = vec![0.0f32; 8];
    let tok = vec![0i32; d.decode_batch];
    assert!(rt.decode(&tok, &tok, &mut short_kv).is_err(), "kv size must match");
}

#[test]
fn server_serves_live_requests() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut server = Server::start(dir, ServerCfg::default()).unwrap();
    server.wait_ready().unwrap();
    let n = 6;
    for id in 0..n {
        server
            .submit(ServeRequest {
                id,
                prompt: vec![(id as i32 % 500) + 1; 4 + id],
                max_tokens: 5,
            })
            .unwrap();
    }
    let mut seen = Vec::new();
    for _ in 0..n {
        let r = server.recv().expect("response");
        assert_eq!(r.tokens.len(), 5);
        assert!(r.ttft >= 0.0 && r.e2e >= r.ttft);
        assert_eq!(r.gaps.len(), 4);
        seen.push(r.id);
    }
    seen.sort();
    assert_eq!(seen, (0..n).collect::<Vec<_>>());
    server.shutdown();
}

#[test]
fn server_is_deterministic_across_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run = || {
        let mut server = Server::start(dir.clone(), ServerCfg::default()).unwrap();
        server.wait_ready().unwrap();
        server
            .submit(ServeRequest { id: 0, prompt: vec![3, 1, 4, 1, 5], max_tokens: 8 })
            .unwrap();
        let r = server.recv().unwrap();
        server.shutdown();
        r.tokens
    };
    assert_eq!(run(), run(), "same prompt must generate the same tokens");
}
