//! Engine-level integration tests: cross-engine invariants, paper-shape
//! assertions, and stress scenarios over the full simulated serving stack.

use nexus::coordinator::{offline_makespan, sustainable_throughput, Experiment, SloSpec};
use nexus::engine::{run_engine, EngineCfg, EngineKind, NexusFlags};
use nexus::engine::nexus::NexusEngine;
use nexus::metrics::RunMetrics;
use nexus::model::ModelConfig;
use nexus::workload::{generate, offline, Dataset};

fn check_invariants(m: &RunMetrics, trace_len: usize, name: &str) {
    assert_eq!(m.summary().completed + m.timeouts, trace_len, "{name}: lost requests");
    for r in &m.records {
        assert!(r.first_token >= r.arrival, "{name}: TTFT < 0 for {}", r.id);
        assert!(r.finish >= r.first_token, "{name}: finish before first token");
        assert_eq!(
            r.token_gaps.len(),
            r.output_len.saturating_sub(1),
            "{name}: token count mismatch for {}",
            r.id
        );
        assert!(r.token_gaps.iter().all(|&g| g >= 0.0), "{name}: negative gap");
        assert!(r.queue_time >= 0.0 && r.exec_time > 0.0, "{name}: stage times");
    }
}

#[test]
fn all_engines_complete_all_workloads() {
    let cfg = EngineCfg::new(ModelConfig::qwen3b(), 1);
    for dataset in [Dataset::ShareGpt, Dataset::Arxiv, Dataset::Mixed] {
        let trace = generate(dataset, 30, 3.0, 17);
        for &kind in EngineKind::all() {
            let m = run_engine(kind, &cfg, &trace);
            check_invariants(&m, trace.len(), kind.name());
            assert_eq!(m.timeouts, 0, "{} timed out on {}", kind.name(), dataset.name());
        }
    }
}

#[test]
fn deterministic_given_seed() {
    let cfg = EngineCfg::new(ModelConfig::qwen3b(), 7);
    let trace = generate(Dataset::Mixed, 40, 3.0, 7);
    let a = run_engine(EngineKind::Nexus, &cfg, &trace);
    let b = run_engine(EngineKind::Nexus, &cfg, &trace);
    let (sa, sb) = (a.summary(), b.summary());
    assert_eq!(sa.completed, sb.completed);
    assert!((sa.mean_ttft - sb.mean_ttft).abs() < 1e-9);
    assert!((sa.mean_tbt - sb.mean_tbt).abs() < 1e-9);
    assert_eq!(a.repartitions, b.repartitions);
}

#[test]
fn paper_shape_nexus_beats_vllm_on_mixed() {
    // The headline single-GPU comparison (Fig. 9 row 3): under the Mixed
    // workload Nexus must beat vLLM on TTFT, TBT, and normalized latency.
    let exp = Experiment::new(ModelConfig::llama8b(), Dataset::Mixed, 80, 2.5);
    let nexus = exp.run(EngineKind::Nexus).summary();
    let vllm = exp.run(EngineKind::Vllm).summary();
    assert!(
        nexus.mean_ttft < vllm.mean_ttft,
        "TTFT: nexus {} vs vllm {}",
        nexus.mean_ttft,
        vllm.mean_ttft
    );
    assert!(
        nexus.mean_tbt < vllm.mean_tbt,
        "TBT: nexus {} vs vllm {}",
        nexus.mean_tbt,
        vllm.mean_tbt
    );
    assert!(
        nexus.mean_norm < vllm.mean_norm,
        "norm: nexus {} vs vllm {}",
        nexus.mean_norm,
        vllm.mean_norm
    );
}

#[test]
fn paper_shape_ablation_ordering() {
    // Fig. 13 shape (see EXPERIMENTS.md for the one divergence): SPF slashes
    // TTFT; dynamic SM-changing further improves TTFT and normalized
    // latency; the TBT cost of prioritizing prefill stays bounded (in our
    // substrate decode saturates at ~25–34% SMs, so a static 50/50 split is
    // already decode-optimal and the paper's −26% TBT is not reachable).
    let mut cfg = EngineCfg::new(ModelConfig::llama8b(), 42);
    cfg.kv_blocks_override = Some(6_000); // memory-pressured, as in §6.5
    let trace = generate(Dataset::Mixed, 100, 3.5, 42);
    let baseline = run_engine(EngineKind::PfDfWoSc, &cfg, &trace).summary();
    let spf_only = run_engine(EngineKind::NexusWoSc, &cfg, &trace).summary();
    let full = run_engine(EngineKind::Nexus, &cfg, &trace).summary();
    assert!(
        spf_only.mean_ttft < 0.7 * baseline.mean_ttft,
        "SPF must cut TTFT: {} vs {}",
        spf_only.mean_ttft,
        baseline.mean_ttft
    );
    assert!(
        full.mean_ttft < spf_only.mean_ttft,
        "dynamic SM must further improve TTFT: {} vs {}",
        full.mean_ttft,
        spf_only.mean_ttft
    );
    assert!(
        full.mean_norm <= spf_only.mean_norm * 1.05,
        "full Nexus must hold normalized latency: {} vs {}",
        full.mean_norm,
        spf_only.mean_norm
    );
    assert!(
        full.mean_tbt <= spf_only.mean_tbt * 1.35,
        "TBT cost of prefill priority must stay bounded: {} vs {}",
        full.mean_tbt,
        spf_only.mean_tbt
    );
}

#[test]
fn nexus_sustains_higher_throughput_than_vllm() {
    let exp = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 40, 1.0);
    let slo = SloSpec::default();
    let t_nexus = sustainable_throughput(EngineKind::Nexus, &exp, slo, 0.5, 40.0, 1.0);
    let t_vllm = sustainable_throughput(EngineKind::Vllm, &exp, slo, 0.5, 40.0, 1.0);
    assert!(
        t_nexus >= t_vllm,
        "nexus {} req/s must be ≥ vllm {} req/s",
        t_nexus,
        t_vllm
    );
}

#[test]
fn offline_makespan_all_engines_finish_sharegpt() {
    let exp = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 40, 1.0);
    for &kind in EngineKind::all() {
        let r = offline_makespan(kind, &exp);
        assert!(r.is_some(), "{} timed out offline", kind.name());
    }
}

#[test]
fn kv_pressure_forces_mode_switch_and_survives() {
    // A tiny KV cache must drive KV_u over the switch threshold; Nexus must
    // still complete (decode-prioritized mode drains memory).
    let mut cfg = EngineCfg::new(ModelConfig::qwen3b(), 5);
    cfg.kv_blocks_override = Some(4_000);
    let trace = generate(Dataset::Mixed, 40, 4.0, 23);
    let m = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace);
    check_invariants(&m, trace.len(), "nexus-tiny-kv");
    assert!(m.repartitions > 0);
}

#[test]
fn burst_of_identical_arrivals() {
    // Degenerate offline burst: everything arrives at once with identical
    // lengths — schedulers must not starve or double-serve anyone.
    let cfg = EngineCfg::new(ModelConfig::qwen3b(), 9);
    let trace = offline(Dataset::ShareGpt, 25, 3);
    for &kind in EngineKind::all() {
        let m = run_engine(kind, &cfg, &trace);
        check_invariants(&m, trace.len(), kind.name());
    }
}

#[test]
fn single_request_latency_matches_isolated_prediction() {
    // One request alone: its TTFT must be close to the cost model's
    // isolated prefill estimate (sanity link between engine and model).
    let cfg = EngineCfg::new(ModelConfig::qwen3b(), 11);
    let trace = vec![nexus::workload::Request {
        id: 0,
        arrival: 0.0,
        prompt_len: 1024,
        output_len: 4,
        tenant: 0,
        prefix: 0,
        shared_len: 0,
    }];
    let m = run_engine(EngineKind::Vllm, &cfg, &trace);
    let r = &m.records[0];
    // 1024 tokens in 512-token chunks under a 2048 budget → 2 iterations.
    let gpu = cfg.gpu;
    let ops = cfg.model.prefill_ops(1024, 1024.0 * 512.0, 1024.0, 1);
    let rough = nexus::gpusim::iteration_time_isolated(&gpu, &ops, 1.0);
    assert!(
        r.ttft() > 0.2 * rough && r.ttft() < 5.0 * rough,
        "ttft {} vs rough isolated estimate {}",
        r.ttft(),
        rough
    );
}

#[test]
fn multi_gpu_tp2_runs_all_engines() {
    // Fig.-10 configuration: Qwen14B with TP=2.
    let model = ModelConfig::qwen14b().with_tp(2);
    let cfg = EngineCfg::new(model, 3);
    let trace = generate(Dataset::Mixed, 25, 2.0, 31);
    for kind in [EngineKind::Vllm, EngineKind::Sglang, EngineKind::Nexus] {
        let m = run_engine(kind, &cfg, &trace);
        check_invariants(&m, trace.len(), kind.name());
        assert_eq!(kind.gpus(&model), 2);
    }
}
