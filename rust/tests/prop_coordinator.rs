//! Property-based tests over the coordinator's invariants: routing,
//! batching, partitioning, KV-cache accounting, and end-to-end request
//! conservation, driven by the in-repo mini property harness
//! (`nexus::testing`; proptest is not vendored).

use nexus::costmodel::calibrate;
use nexus::engine::{run_engine, EngineCfg, EngineKind};
use nexus::gpusim::GpuSpec;
use nexus::kv::KvCache;
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::sched::{fcfs_batch, mixed_batch, spf_batch, PrefillItem};
use nexus::testing::{gen, prop};
use nexus::util::rng::Rng;
use nexus::workload::{generate, Dataset};

fn random_queue(rng: &mut Rng, max_len: usize) -> Vec<PrefillItem> {
    let n = rng.range_usize(0, max_len);
    (0..n)
        .map(|id| {
            let prompt_len = gen::int_biased(rng, 1, 8000);
            PrefillItem {
                id,
                prompt_len,
                prefilled: rng.range_usize(0, prompt_len - 1),
                arrival: rng.range_f64(0.0, 100.0),
            }
        })
        .collect()
}

#[test]
fn prop_spf_batch_respects_budget_and_uniqueness() {
    prop("spf batch budget", 300, |rng| {
        let q = random_queue(rng, 40);
        let budget = gen::int_biased(rng, 1, 4096);
        let gamma = rng.range_f64(0.0, 50.0);
        let picked = spf_batch(&q, rng.range_f64(0.0, 200.0), budget, gamma);
        let mut seen = std::collections::HashSet::new();
        for &i in &picked {
            if i >= q.len() {
                return Err(format!("index {i} out of range"));
            }
            if !seen.insert(i) {
                return Err(format!("duplicate index {i}"));
            }
        }
        let total: usize = picked.iter().map(|&i| q[i].remaining()).sum();
        // Whole-fit batches respect the budget; the single chunked-head
        // exception is allowed only when nothing fits.
        if picked.len() > 1 && total > budget {
            return Err(format!("total {total} > budget {budget} with {} items", picked.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_fcfs_is_arrival_sorted_prefix() {
    prop("fcfs ordering", 300, |rng| {
        let q = random_queue(rng, 30);
        let budget = gen::int_biased(rng, 1, 4096);
        let picked = fcfs_batch(&q, budget, rng.chance(0.5));
        for w in picked.windows(2) {
            let (a, b) = (&q[w[0]], &q[w[1]]);
            if (a.arrival, a.id) > (b.arrival, b.id) {
                return Err(format!("not arrival-ordered: {:?} then {:?}", a, b));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mixed_batch_within_token_budget() {
    prop("mixed batch budget", 300, |rng| {
        let q = random_queue(rng, 30);
        let n_dec = rng.range_usize(0, 64);
        let decode_ids: Vec<usize> = (0..n_dec).collect();
        let budget = gen::int_biased(rng, 1, 4096);
        let chunk = gen::int_biased(rng, 16, 1024);
        let b = mixed_batch(&decode_ids, &q, budget, chunk);
        let tokens = b.prefill_tokens() + b.decode_ids.len();
        if b.prefill_tokens() > 0 && tokens > budget.max(n_dec) {
            return Err(format!("tokens {tokens} > budget {budget}"));
        }
        for &(idx, take) in &b.prefill_parts {
            if take == 0 || take > chunk || take > q[idx].remaining() {
                return Err(format!("bad chunk ({idx}, {take})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partition_shares_valid_and_constraint_holds() {
    let cost = calibrate(&GpuSpec::l20());
    let model = ModelConfig::qwen3b();
    prop("partition decision validity", 120, |rng| {
        let chunk = gen::int_biased(rng, 16, 2048);
        let kv_len = rng.range_f64(64.0, 12000.0);
        let batch = gen::int_biased(rng, 1, 256);
        let ctx = rng.range_f64(16.0, 4000.0);
        let pre = model.prefill_ops(chunk, chunk as f64 * kv_len, kv_len, 0);
        let dec = model.decode_ops(batch, batch as f64 * ctx);
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let d = ctl.decide(
            &cost,
            &BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: rng.f64() },
        );
        if (d.r_p + d.r_d - 1.0).abs() > 1e-9 {
            return Err(format!("shares must sum to 1: {} + {}", d.r_p, d.r_d));
        }
        if d.r_p < 0.05 - 1e-9 || d.r_d < 0.05 - 1e-9 {
            return Err(format!("share below floor: {} / {}", d.r_p, d.r_d));
        }
        if d.queries > 250 {
            return Err(format!("greedy search used {} queries", d.queries));
        }
        Ok(())
    });
}

#[test]
fn prop_kv_cache_conservation() {
    prop("kv cache accounting", 300, |rng| {
        let blocks = gen::int_biased(rng, 4, 2000);
        let mut kv = KvCache::new(blocks, 16, 100.0);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..60 {
            match rng.below(4) {
                0 => {
                    let id = step;
                    if kv.try_reserve(id, rng.range_usize(1, 600)) {
                        live.push(id);
                    }
                }
                1 => {
                    if let Some(&id) = live.get(rng.below(live.len().max(1)).min(live.len().saturating_sub(1))) {
                        kv.try_reserve(id, rng.range_usize(1, 64));
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        kv.release(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live[rng.below(live.len())];
                        if kv.tokens(id) > 0 {
                            kv.swap_out(id);
                            if kv.swap_in(id).is_none() {
                                kv.evict(id);
                                live.retain(|&x| x != id);
                            }
                        }
                    }
                }
            }
            let u = kv.usage();
            if !(0.0..=1.0 + 1e-12).contains(&u) {
                return Err(format!("usage out of range: {u}"));
            }
            if kv.free_blocks() > blocks {
                return Err("free blocks exceed capacity".into());
            }
        }
        for id in live {
            kv.release(id);
        }
        if kv.total_tokens() != 0 {
            return Err(format!("leaked tokens: {}", kv.total_tokens()));
        }
        Ok(())
    });
}

#[test]
fn prop_every_engine_conserves_requests() {
    // Random small workloads across random engines: requests are never
    // lost or duplicated, and records are internally consistent.
    prop("request conservation", 25, |rng| {
        let dataset = *[Dataset::ShareGpt, Dataset::Arxiv, Dataset::Mixed]
            .iter()
            .nth(rng.below(3))
            .unwrap();
        let kinds = EngineKind::all();
        let kind = kinds[rng.below(kinds.len())];
        let n = rng.range_usize(5, 25);
        let rate = rng.range_f64(0.5, 8.0);
        let trace = generate(dataset, n, rate, rng.next_u64());
        let mut cfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        if rng.chance(0.3) {
            cfg.kv_blocks_override = Some(rng.range_usize(2_000, 40_000));
        }
        let m = run_engine(kind, &cfg, &trace);
        if m.summary().completed + m.timeouts != n {
            return Err(format!(
                "{}: {} completed + {} timeouts != {n}",
                kind.name(),
                m.summary().completed,
                m.timeouts
            ));
        }
        let mut ids: Vec<usize> = m.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != m.records.len() {
            return Err(format!("{}: duplicate request records", kind.name()));
        }
        for r in &m.records {
            if r.finish < r.first_token || r.first_token < r.arrival {
                return Err(format!("{}: time order violated for {}", kind.name(), r.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hysteresis_never_applies_small_changes() {
    let cost = calibrate(&GpuSpec::l20());
    let model = ModelConfig::qwen3b();
    prop("hysteresis threshold", 100, |rng| {
        let delta = rng.range_f64(0.01, 0.3);
        let cfg = PartitionConfig { delta, ..PartitionConfig::default() };
        let mut ctl = PartitionController::new(cfg);
        let mut last = ctl.r_p;
        for _ in 0..10 {
            let chunk = gen::int_biased(rng, 64, 2048);
            let kv_len = rng.range_f64(64.0, 10000.0);
            let pre = model.prefill_ops(chunk, chunk as f64 * kv_len, kv_len, 0);
            let dec = model.decode_ops(gen::int_biased(rng, 1, 128), rng.range_f64(100.0, 1e5));
            let d = ctl.decide(
                &cost,
                &BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: rng.f64() },
            );
            if d.applied && (d.r_p - last).abs() < delta - 1e-9 {
                return Err(format!(
                    "applied sub-δ change: {} -> {} (δ={delta})",
                    last, d.r_p
                ));
            }
            if !d.applied && (d.r_p - last).abs() > 1e-9 {
                return Err("suppressed decision must keep the old share".into());
            }
            last = d.r_p;
        }
        Ok(())
    });
}
