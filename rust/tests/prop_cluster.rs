//! Property-based tests over the cluster layer's invariants: routing
//! conservation, drain safety, autoscaler bounds and hysteresis, and
//! single-replica equivalence with the plain engine loop — driven by the
//! in-repo mini property harness (`nexus::testing`).

use nexus::cluster::{
    run_cluster, AutoscalerCfg, Cluster, ClusterCfg, ParallelCfg, RoutingPolicy, StealCfg,
};
use nexus::engine::{run_engine, EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::testing::prop;
use nexus::util::rng::Rng;
use nexus::workload::{generate, generate_bursty, BurstyCfg, Dataset, Request};

fn random_policy(rng: &mut Rng) -> RoutingPolicy {
    let all = RoutingPolicy::all();
    all[rng.below(all.len())]
}

fn random_kind(rng: &mut Rng) -> EngineKind {
    let kinds = EngineKind::all();
    kinds[rng.below(kinds.len())]
}

fn random_trace(rng: &mut Rng, n: usize) -> Vec<Request> {
    let dataset = [Dataset::ShareGpt, Dataset::Arxiv, Dataset::Mixed][rng.below(3)];
    if rng.chance(0.5) {
        let cfg = BurstyCfg {
            base_rate: rng.range_f64(2.0, 20.0),
            burst_shape: rng.range_f64(0.3, 2.0),
            epoch: rng.range_f64(2.0, 20.0),
            diurnal_amp: rng.range_f64(0.0, 0.9),
            diurnal_period: rng.range_f64(60.0, 600.0),
        };
        generate_bursty(dataset, n, &cfg, rng.next_u64())
    } else {
        generate(dataset, n, rng.range_f64(1.0, 15.0), rng.next_u64())
    }
}

#[test]
fn prop_every_request_routed_exactly_once() {
    prop("cluster routing conservation", 20, |rng| {
        let n = rng.range_usize(10, 40);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let policy = random_policy(rng);
        let replicas = rng.range_usize(1, 5);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let cc = ClusterCfg::new(kind, ecfg, replicas, policy);
        let m = run_cluster(&cc, &trace);
        // Dispatched exactly once each...
        let routed: usize = m.replicas.iter().map(|r| r.routed).sum();
        if routed != n {
            return Err(format!(
                "{} x{} [{}]: routed {routed} != offered {n}",
                kind.name(),
                replicas,
                policy.name()
            ));
        }
        // ...and answered (or accounted as a timeout) exactly once each.
        if m.fleet.records.len() + m.fleet.timeouts != n {
            return Err(format!(
                "{} records + {} timeouts != {n}",
                m.fleet.records.len(),
                m.fleet.timeouts
            ));
        }
        let mut ids: Vec<usize> = m.fleet.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != m.fleet.records.len() {
            return Err("duplicate response records across replicas".into());
        }
        // Histogram aggregation covers every completed request.
        if m.ttft_hist.count() != m.fleet.records.len() as u64 {
            return Err(format!(
                "ttft hist {} != records {}",
                m.ttft_hist.count(),
                m.fleet.records.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_no_response_lost_across_drain() {
    // Aggressive autoscaling (tiny interval/cooldown) against spiky traffic
    // forces scale-downs while work is in flight; draining must never drop
    // or duplicate a response.
    prop("drain safety", 12, |rng| {
        let n = rng.range_usize(20, 50);
        let trace = random_trace(rng, n);
        let kind = [EngineKind::Vllm, EngineKind::Nexus, EngineKind::FastServe][rng.below(3)];
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let mut cc =
            ClusterCfg::new(kind, ecfg, rng.range_usize(2, 4), random_policy(rng));
        cc.autoscale = Some(AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 4,
            interval: rng.range_f64(0.5, 3.0),
            cooldown: rng.range_f64(1.0, 5.0),
            target_util: rng.range_f64(0.5, 0.95),
            ..AutoscalerCfg::default()
        });
        let m = run_cluster(&cc, &trace);
        if m.fleet.records.len() + m.fleet.timeouts != n {
            return Err(format!(
                "{}: {} records + {} timeouts != {n} ({} scale events)",
                kind.name(),
                m.fleet.records.len(),
                m.fleet.timeouts,
                m.scale_events.len()
            ));
        }
        let mut ids: Vec<usize> = m.fleet.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != m.fleet.records.len() {
            return Err(format!("{}: duplicated response after drain", kind.name()));
        }
        Ok(())
    });
}

#[test]
fn prop_autoscaler_bounded_and_damped() {
    prop("autoscaler bounds + hysteresis", 12, |rng| {
        let n = rng.range_usize(30, 60);
        let trace = random_trace(rng, n);
        let min_replicas = rng.range_usize(1, 2);
        let max_replicas = min_replicas + rng.range_usize(1, 4);
        let cooldown = rng.range_f64(3.0, 20.0);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let mut cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg,
            min_replicas,
            RoutingPolicy::JoinShortestQueue,
        );
        cc.autoscale = Some(AutoscalerCfg {
            min_replicas,
            max_replicas,
            interval: rng.range_f64(1.0, 4.0),
            cooldown,
            ..AutoscalerCfg::default()
        });
        let m = run_cluster(&cc, &trace);
        if m.peak_replicas > max_replicas {
            return Err(format!("peak {} > max {max_replicas}", m.peak_replicas));
        }
        for e in &m.scale_events {
            if e.to < min_replicas || e.to > max_replicas {
                return Err(format!(
                    "scale target {} outside [{min_replicas}, {max_replicas}]",
                    e.to
                ));
            }
            if e.from == e.to {
                return Err("no-op scale event recorded".into());
            }
        }
        for w in m.scale_events.windows(2) {
            if w[1].time - w[0].time < cooldown - 1e-9 {
                return Err(format!(
                    "flap: actions at {:.3} and {:.3} inside cooldown {cooldown}",
                    w[0].time, w[1].time
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_queue_fires_in_nondecreasing_time_order() {
    // The heap-based fleet loop's core invariant: processed event times
    // never regress, for any engine, fleet size, policy, or autoscaling.
    prop("event-queue monotonicity", 15, |rng| {
        let n = rng.range_usize(10, 40);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let mut cc = ClusterCfg::new(
            kind,
            EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64()),
            rng.range_usize(1, 5),
            random_policy(rng),
        );
        if rng.chance(0.4) {
            cc.autoscale = Some(AutoscalerCfg {
                min_replicas: 1,
                max_replicas: 4,
                interval: rng.range_f64(1.0, 4.0),
                cooldown: rng.range_f64(2.0, 8.0),
                ..AutoscalerCfg::default()
            });
        }
        let mut cluster = Cluster::new(cc);
        cluster.record_event_times = true;
        let m = cluster.run(&trace);
        if m.events != cluster.event_times.len() {
            return Err(format!(
                "event counter {} != recorded times {}",
                m.events,
                cluster.event_times.len()
            ));
        }
        if m.events == 0 {
            return Err("loop processed no events for a non-empty trace".into());
        }
        for w in cluster.event_times.windows(2) {
            if w[1] < w[0] {
                return Err(format!("event time regressed: {} -> {}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_loop_matches_reference_loop() {
    // Randomized differential check of the O(log R) loop against the
    // retained pre-refactor loop, at full digest strength.
    prop("event loop == reference loop", 10, |rng| {
        let n = rng.range_usize(10, 40);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let policy = random_policy(rng);
        let replicas = rng.range_usize(1, 5);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let cc = ClusterCfg::new(kind, ecfg, replicas, policy);
        let a = Cluster::new(cc.clone()).run(&trace);
        let b = Cluster::new(cc).run_reference(&trace);
        // Deviation tolerates float-associativity noise from the different
        // simulator time-slicing; None means a structural divergence.
        let dev = a.fleet.deviation(&b.fleet);
        if !matches!(dev, Some(d) if d <= 1e-9) {
            return Err(format!(
                "{} x{} [{}]: optimized loop diverged from reference \
                 (deviation {dev:?}; {} vs {} records, {} vs {} timeouts)",
                kind.name(),
                replicas,
                policy.name(),
                a.fleet.records.len(),
                b.fleet.records.len(),
                a.fleet.timeouts,
                b.fleet.timeouts
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_loop_invariant_to_threads_and_window() {
    // The sharded fleet loop (§Perf) advances every replica in exactly the
    // sequential loop's time slices, so for ANY random workload, engine,
    // policy, fleet size, autoscaler shape, thread count, and sync window,
    // the full cluster digest must be bit-equal to the sequential run.
    prop("parallel thread/window invariance", 10, |rng| {
        let n = rng.range_usize(10, 40);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let policy = random_policy(rng);
        let replicas = rng.range_usize(1, 5);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let mut cc = ClusterCfg::new(kind, ecfg, replicas, policy);
        if rng.chance(0.4) {
            cc.autoscale = Some(AutoscalerCfg {
                min_replicas: 1,
                max_replicas: 4,
                interval: rng.range_f64(1.0, 4.0),
                cooldown: rng.range_f64(2.0, 8.0),
                ..AutoscalerCfg::default()
            });
        }
        let seq = Cluster::new(cc.clone()).run(&trace).digest();
        let threads = rng.range_usize(2, 8);
        let window = if rng.chance(0.5) { rng.range_f64(0.01, 5.0) } else { 0.0 };
        let par = Cluster::new(cc).run_parallel(&trace, threads, window).digest();
        if seq != par {
            return Err(format!(
                "{} x{} [{}] @ {threads} threads, window {window:.3}: \
                 parallel digest diverged from sequential",
                kind.name(),
                replicas,
                policy.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_stealing_invariance() {
    // Work stealing migrates replicas between shards at rendezvous
    // boundaries using a virtual-time load signal, so for ANY random
    // workload, engine, policy, fleet size, autoscaler shape, thread
    // count, window, and stealing config, the digest must be bit-equal
    // to the sequential run — and to the static (steal-off) sharded run.
    prop("stealing threshold/interval invariance", 10, |rng| {
        let n = rng.range_usize(10, 40);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let policy = random_policy(rng);
        let replicas = rng.range_usize(1, 6);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let mut cc = ClusterCfg::new(kind, ecfg, replicas, policy);
        if rng.chance(0.5) {
            cc.autoscale = Some(AutoscalerCfg {
                min_replicas: 1,
                max_replicas: 5,
                interval: rng.range_f64(1.0, 4.0),
                cooldown: rng.range_f64(2.0, 8.0),
                ..AutoscalerCfg::default()
            });
        }
        let seq = Cluster::new(cc.clone()).run(&trace).digest();
        let threads = rng.range_usize(2, 8);
        let window = if rng.chance(0.5) { rng.range_f64(0.01, 5.0) } else { 0.0 };
        let steal = StealCfg {
            threshold: rng.range_f64(1.05, 4.0),
            interval: rng.range_f64(0.1, 3.0),
        };
        let stat = Cluster::new(cc.clone())
            .run_parallel_cfg(&trace, ParallelCfg { threads, window, steal: None })
            .digest();
        let stolen = Cluster::new(cc)
            .run_parallel_cfg(&trace, ParallelCfg { threads, window, steal: Some(steal) })
            .digest();
        if seq != stat || seq != stolen {
            return Err(format!(
                "{} x{} [{}] @ {threads} threads, window {window:.3}, \
                 steal {steal:?}: digest diverged (static match: {}, stealing \
                 match: {})",
                kind.name(),
                replicas,
                policy.name(),
                seq == stat,
                seq == stolen
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_single_replica_cluster_equals_engine_loop() {
    // The stepping refactor is behavior-preserving: for any engine, seed,
    // and workload, a 1-replica cluster reproduces the plain engine run.
    prop("single-replica equivalence", 10, |rng| {
        let n = rng.range_usize(8, 25);
        let trace = random_trace(rng, n);
        let kind = random_kind(rng);
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let solo = run_engine(kind, &ecfg, &trace);
        let cc = ClusterCfg::new(kind, ecfg, 1, RoutingPolicy::RoundRobin);
        let fleet = run_cluster(&cc, &trace);
        let (a, b) = (solo.summary(), fleet.summary());
        if a.completed != b.completed {
            return Err(format!("{}: completed {} vs {}", kind.name(), a.completed, b.completed));
        }
        for (x, y, what) in [
            (a.mean_ttft, b.mean_ttft, "mean ttft"),
            (a.p95_ttft, b.p95_ttft, "p95 ttft"),
            (a.mean_tbt, b.mean_tbt, "mean tbt"),
            (a.mean_norm, b.mean_norm, "mean norm"),
        ] {
            if (x - y).abs() > 1e-9 {
                return Err(format!("{}: {what} diverged: {x} vs {y}", kind.name()));
            }
        }
        if solo.recomputes != fleet.fleet.recomputes || solo.swaps != fleet.fleet.swaps {
            return Err(format!("{}: event counters diverged", kind.name()));
        }
        Ok(())
    });
}
