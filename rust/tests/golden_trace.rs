//! Golden trace tests: the tracing layer must be zero-cost when disabled
//! and purely observational when enabled.
//!
//! * A disabled (`Tracer::default()`) sink leaves `RunMetrics::digest`
//!   byte-identical to the untraced loop, for every engine kind.
//! * The optimized event-queue fleet loop and the O(R)-scan reference loop
//!   emit the *same event sequence* — compared with
//!   `TraceEvent::approx_eq` at 1 ns tolerance (the sequence analogue of
//!   `RunMetrics::deviation`; a quantized string compare would be flaky on
//!   rounding-bucket boundaries, exactly like cross-loop digests).
//! * Recording + periodic sampling perturbs neither digests nor the loop's
//!   event counter (samples are observational grid reads, not loop events).

use nexus::cluster::{
    AutoscalerCfg, Cluster, ClusterCfg, ClusterMetrics, PrefixCacheCfg, RoutingPolicy, WfqCfg,
};
use nexus::engine::{build_engine, drive, drive_traced, run_engine_traced, EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::trace::{
    attribute, canonical_order, chrome_trace, to_jsonl, EventKind, TraceEvent, Tracer, FLEET,
};
use nexus::util::json::Json;
use nexus::workload::{
    generate, generate_bursty, generate_with_prefixes, generate_with_tenants, BurstyCfg, Dataset,
    PrefixCfg, Request, TenantMix, TenantSpec,
};

fn ecfg(seed: u64) -> EngineCfg {
    EngineCfg::new(ModelConfig::qwen3b(), seed)
}

fn assert_trace_eq(a: &[TraceEvent], b: &[TraceEvent], what: &str) {
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            x.approx_eq(y, 1e-9),
            "{what}: event {i} diverges:\n  optimized: {}\n  reference: {}",
            x.canonical(),
            y.canonical()
        );
    }
    assert_eq!(a.len(), b.len(), "{what}: event counts differ");
}

fn run_fleet(cc: &ClusterCfg, trace: &[Request], reference: bool, dt: f64) -> (ClusterMetrics, Vec<TraceEvent>) {
    let tracer = Tracer::recording().with_sampling(dt);
    let mut cluster = Cluster::new(cc.clone());
    cluster.tracer = tracer.clone();
    let m = if reference { cluster.run_reference(trace) } else { cluster.run(trace) };
    (m, tracer.take())
}

#[test]
fn noop_sink_leaves_engine_digests_byte_identical() {
    let cfg = ecfg(7);
    let trace = generate(Dataset::Mixed, 40, 4.0, 11);
    for &kind in EngineKind::all() {
        let mut plain = build_engine(kind, &cfg);
        let d_plain = drive(plain.as_mut(), &trace, cfg.max_virtual_time).digest();
        let mut noop = build_engine(kind, &cfg);
        let d_noop =
            drive_traced(noop.as_mut(), &trace, cfg.max_virtual_time, &Tracer::default()).digest();
        assert_eq!(d_plain, d_noop, "{}: no-op sink changed the digest", kind.name());
    }
}

#[test]
fn recording_sink_is_observational_on_engines() {
    // A *recording* tracer (with sampling on) must not perturb the run
    // either: hooks only read state.
    let cfg = ecfg(7);
    let trace = generate(Dataset::Mixed, 40, 4.0, 11);
    for &kind in EngineKind::all() {
        let mut plain = build_engine(kind, &cfg);
        let d_plain = drive(plain.as_mut(), &trace, cfg.max_virtual_time).digest();
        let tracer = Tracer::recording().with_sampling(0.5);
        let mut traced = build_engine(kind, &cfg);
        let m_traced = drive_traced(traced.as_mut(), &trace, cfg.max_virtual_time, &tracer);
        assert_eq!(d_plain, m_traced.digest(), "{}: recording sink changed the digest", kind.name());
        let events = tracer.take();
        assert!(!events.is_empty(), "{}: no events recorded", kind.name());
        let completes = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Complete { .. }))
            .count();
        assert_eq!(
            completes,
            m_traced.records.len(),
            "{}: one Complete per finished request",
            kind.name()
        );
        assert!(
            events.iter().any(|e| matches!(e.kind, EventKind::Sample { .. })),
            "{}: sampler produced nothing",
            kind.name()
        );
    }
}

#[test]
fn fleet_loops_emit_identical_event_sequences() {
    let trace = generate(Dataset::Mixed, 60, 8.0, 23);
    for kind in [EngineKind::Nexus, EngineKind::FastServe, EngineKind::VllmPD] {
        let cc = ClusterCfg::new(kind, ecfg(13), 3, RoutingPolicy::JoinShortestQueue);
        let (_, ev_opt) = run_fleet(&cc, &trace, false, 1.0);
        let (_, ev_ref) = run_fleet(&cc, &trace, true, 1.0);
        assert!(!ev_opt.is_empty(), "{}: empty trace", kind.name());
        assert_trace_eq(&ev_opt, &ev_ref, kind.name());
    }
}

#[test]
fn autoscaled_bursty_fleet_traces_match_and_cover_fleet_events() {
    let bursty = BurstyCfg { base_rate: 10.0, ..BurstyCfg::default() };
    let trace = generate_bursty(Dataset::ShareGpt, 80, &bursty, 41);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(13), 1, RoutingPolicy::JoinShortestQueue);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 4,
        interval: 2.0,
        cooldown: 5.0,
        ..AutoscalerCfg::default()
    });
    let (m_opt, ev_opt) = run_fleet(&cc, &trace, false, 1.0);
    let (m_ref, ev_ref) = run_fleet(&cc, &trace, true, 1.0);
    assert_trace_eq(&ev_opt, &ev_ref, "autoscaled bursty");
    assert_eq!(
        m_opt.fleet.deviation(&m_ref.fleet).map(|d| d <= 1e-9),
        Some(true),
        "loops must stay metric-equivalent with tracing on"
    );

    // The trace must tie out against the run's own accounting.
    let count = |pred: fn(&EventKind) -> bool| ev_opt.iter().filter(|e| pred(&e.kind)).count();
    assert_eq!(count(|k| matches!(k, EventKind::Arrival { .. })), trace.len());
    assert_eq!(count(|k| matches!(k, EventKind::Route { .. })), trace.len());
    assert_eq!(
        count(|k| matches!(k, EventKind::Complete { .. })),
        m_opt.fleet.records.len()
    );
    assert_eq!(
        count(|k| matches!(k, EventKind::Scale { .. })),
        m_opt.scale_events.len()
    );
    assert_eq!(
        count(|k| matches!(k, EventKind::Repartition { .. })),
        m_opt.fleet.repartitions
    );
    assert!(count(|k| matches!(k, EventKind::Sample { .. })) > 0);
    assert!(count(|k| matches!(k, EventKind::ReplicaStart)) >= 1);
    // Route decisions are fleet-level; engine events carry replica ids.
    assert!(ev_opt
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Route { .. }))
        .all(|e| e.replica == FLEET));
    assert!(ev_opt
        .iter()
        .filter(|e| matches!(e.kind, EventKind::BatchEnd { .. }))
        .all(|e| e.replica != FLEET));
}

#[test]
fn parallel_fleet_emits_the_sequential_event_set() {
    // `Cluster::run_parallel` records through per-shard forked sinks merged
    // at the end of the run; the event *content* must match the sequential
    // loop exactly. The sequential loop interleaves replicas differently
    // than the merged shard streams, so both sides are put in canonical
    // `(time, replica)` order before comparing. Sampling stays off — the
    // sharded loop does not support grid sampling (see `cluster::parallel`).
    let trace = generate(Dataset::Mixed, 50, 7.0, 29);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(17), 3, RoutingPolicy::JoinShortestQueue);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 4,
        interval: 2.0,
        cooldown: 5.0,
        ..AutoscalerCfg::default()
    });
    let run = |threads: usize| {
        let tracer = Tracer::recording();
        let mut cluster = Cluster::new(cc.clone());
        cluster.tracer = tracer.clone();
        let m = if threads > 1 {
            cluster.run_parallel(&trace, threads, 0.0)
        } else {
            cluster.run(&trace)
        };
        let mut events = tracer.take();
        canonical_order(&mut events);
        (m, events)
    };
    let (m_seq, ev_seq) = run(1);
    for threads in [2usize, 4] {
        let (m_par, ev_par) = run(threads);
        assert_eq!(
            m_seq.digest(),
            m_par.digest(),
            "tracing on: parallel digest diverged @ {threads} threads"
        );
        assert_trace_eq(&ev_par, &ev_seq, &format!("parallel x{threads} vs sequential"));
    }
}

#[test]
fn stealing_fleet_emits_the_sequential_event_set_plus_rebalances() {
    // Work stealing under tracing: every migration emits a typed
    // `ShardRebalance` event, and *everything else* must be exactly the
    // sequential loop's event set — migrations are scheduling metadata,
    // not behavior. The workload is built to force migrations: a t=0
    // pinning wave maps session k to replica k (JSQ-fallback cascade),
    // then the flood hits only sessions 0 and 2, whose replicas both live
    // on shard 0 under the static `id % 2` partition at 2 threads.
    let mut trace = Vec::new();
    for k in 0..4usize {
        trace.push(Request { id: k, arrival: 0.0, prompt_len: 64, output_len: 4, tenant: 0, prefix: 0, shared_len: 0 });
    }
    for i in 0..120usize {
        trace.push(Request {
            id: 64 * (i + 1) + if i % 2 == 0 { 0 } else { 2 },
            arrival: 0.2 + 0.05 * i as f64,
            prompt_len: 512,
            output_len: 24,
            tenant: 0,
            prefix: 0,
            shared_len: 0,
        });
    }
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(19), 4, RoutingPolicy::SessionAffinity);
    let seq_tracer = Tracer::recording();
    let mut seq_cluster = Cluster::new(cc.clone());
    seq_cluster.tracer = seq_tracer.clone();
    let m_seq = seq_cluster.run(&trace);
    let mut ev_seq = seq_tracer.take();
    canonical_order(&mut ev_seq);

    let steal = nexus::cluster::StealCfg { threshold: 1.2, interval: 0.5 };
    let par_tracer = Tracer::recording();
    let mut par_cluster = Cluster::new(cc);
    par_cluster.tracer = par_tracer.clone();
    let m_par = par_cluster.run_parallel_cfg(
        &trace,
        nexus::cluster::ParallelCfg { threads: 2, window: 0.0, steal: Some(steal) },
    );
    assert_eq!(
        m_seq.digest(),
        m_par.digest(),
        "tracing + stealing: digest diverged from sequential"
    );
    let mut ev_par = par_tracer.take();
    let rebalances: Vec<TraceEvent> = ev_par
        .iter()
        .filter(|e| matches!(e.kind, EventKind::ShardRebalance { .. }))
        .cloned()
        .collect();
    assert!(
        !rebalances.is_empty(),
        "the skewed flood must force at least one migration"
    );
    assert_eq!(
        rebalances.len(),
        m_par.rebalances,
        "one ShardRebalance event per recorded migration"
    );
    for e in &rebalances {
        let EventKind::ShardRebalance { from_shard, to_shard } = &e.kind else {
            unreachable!()
        };
        assert!(*from_shard < 2 && *to_shard < 2 && from_shard != to_shard);
    }
    ev_par.retain(|e| !matches!(e.kind, EventKind::ShardRebalance { .. }));
    canonical_order(&mut ev_par);
    assert_trace_eq(&ev_par, &ev_seq, "stealing x2 vs sequential");
}

#[test]
fn recording_and_sampling_leave_fleet_run_untouched() {
    let trace = generate(Dataset::ShareGpt, 60, 8.0, 13);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(42), 3, RoutingPolicy::JoinShortestQueue);
    let plain = Cluster::new(cc.clone()).run(&trace);
    let (traced, events) = run_fleet(&cc, &trace, false, 0.5);
    assert_eq!(
        plain.fleet.digest(),
        traced.fleet.digest(),
        "recording+sampling changed the fleet digest"
    );
    assert_eq!(plain.events, traced.events, "sampling must not add loop events");
    assert!(events.iter().any(|e| matches!(e.kind, EventKind::Sample { .. })));
}

#[test]
fn attribution_phases_bound_mean_e2e() {
    let cfg = ecfg(3);
    let trace = generate(Dataset::ShareGpt, 40, 6.0, 9);
    let tracer = Tracer::recording();
    let m = run_engine_traced(EngineKind::Nexus, &cfg, &trace, &tracer);
    let events = tracer.take();
    let att = attribute(&events, &m);
    assert_eq!(att.requests, m.records.len());
    assert!(att.total() > 0.0);
    assert!(att.prefill > 0.0, "prefill chunks must attribute execution time");
    let mean_e2e = m.records.iter().map(|r| r.finish - r.arrival).sum::<f64>()
        / m.records.len().max(1) as f64;
    // Clamps only ever shrink components, so the sum is bounded by e2e.
    assert!(
        att.total() <= mean_e2e + 1e-9,
        "attribution total {} exceeds mean e2e {}",
        att.total(),
        mean_e2e
    );
}

/// A 3-tenant workload plus a deliberately tight WFQ gate (small quotas and
/// a fleet-wide cap) so that both `TenantAdmit` *and* `TenantThrottle`
/// actually fire under load.
fn tenant_fleet() -> (Vec<Request>, ClusterCfg) {
    let mix = TenantMix::new(vec![3, 2, 1]);
    let trace = generate_with_tenants(Dataset::Mixed, 60, 10.0, 31, &mix);
    let specs = vec![
        TenantSpec { weight: 3.0, admission_quota: 4, ..TenantSpec::default() },
        TenantSpec { weight: 1.0, admission_quota: 3, ..TenantSpec::default() },
        TenantSpec { weight: 1.0, admission_quota: 2, ..TenantSpec::default() },
    ];
    let mut cc = ClusterCfg::new(EngineKind::Nexus, ecfg(37), 2, RoutingPolicy::JoinShortestQueue);
    cc.wfq = Some(WfqCfg::new(specs).with_capacity(6));
    (trace, cc)
}

#[test]
fn tenant_events_match_across_sequential_loops_and_tie_out() {
    // Both sequential fleet loops must narrate the WFQ front stage
    // identically: one Arrival and (eventually) one TenantAdmit per
    // request, throttles whenever the gate holds a request back, all at
    // fleet level.
    let (trace, cc) = tenant_fleet();
    let (m_opt, ev_opt) = run_fleet(&cc, &trace, false, 1.0);
    let (m_ref, ev_ref) = run_fleet(&cc, &trace, true, 1.0);
    assert_trace_eq(&ev_opt, &ev_ref, "wfq fleet");
    assert_eq!(
        m_opt.fleet.deviation(&m_ref.fleet).map(|d| d <= 1e-9),
        Some(true),
        "loops must stay metric-equivalent with the gate on"
    );
    let count = |pred: fn(&EventKind) -> bool| ev_opt.iter().filter(|e| pred(&e.kind)).count();
    assert_eq!(count(|k| matches!(k, EventKind::Arrival { .. })), trace.len());
    assert_eq!(
        count(|k| matches!(k, EventKind::TenantAdmit { .. })),
        trace.len(),
        "every request is admitted exactly once"
    );
    assert_eq!(
        count(|k| matches!(k, EventKind::Route { .. })),
        trace.len(),
        "every admit carries a routing decision"
    );
    assert!(
        count(|k| matches!(k, EventKind::TenantThrottle { .. })) > 0,
        "the tight quotas must hold someone back"
    );
    // Gate decisions are fleet-scoped, tagged with real tenants, and every
    // throttled request is later admitted.
    for e in &ev_opt {
        match &e.kind {
            EventKind::TenantAdmit { tenant, .. } => {
                assert_eq!(e.replica, FLEET);
                assert!(*tenant < 3);
            }
            EventKind::TenantThrottle { req, tenant, queued } => {
                assert_eq!(e.replica, FLEET);
                assert!(*tenant < 3);
                assert!(*queued > 0, "a throttle implies a non-empty tenant queue");
                assert!(
                    ev_opt.iter().any(|a| matches!(
                        &a.kind,
                        EventKind::TenantAdmit { req: r, .. } if r == req
                    )),
                    "request {req} throttled but never admitted"
                );
            }
            _ => {}
        }
    }
}

#[test]
fn wfq_tracing_is_observational() {
    // Recording the tenant events must not move the gated run itself.
    let (trace, cc) = tenant_fleet();
    let plain = Cluster::new(cc.clone()).run(&trace);
    let (traced, events) = run_fleet(&cc, &trace, false, 1.0);
    assert_eq!(
        plain.digest(),
        traced.digest(),
        "recording tenant events changed the gated digest"
    );
    assert!(events.iter().any(|e| matches!(e.kind, EventKind::TenantAdmit { .. })));
}

#[test]
fn parallel_wfq_fleet_emits_the_sequential_tenant_event_set() {
    // The sharded loop runs the same gate in lockstep rounds; digest AND
    // event content (canonical order, sampling off) must match the
    // sequential loop for any thread count.
    let (trace, cc) = tenant_fleet();
    let run = |threads: usize| {
        let tracer = Tracer::recording();
        let mut cluster = Cluster::new(cc.clone());
        cluster.tracer = tracer.clone();
        let m = if threads > 1 {
            cluster.run_parallel(&trace, threads, 0.0)
        } else {
            cluster.run(&trace)
        };
        let mut events = tracer.take();
        canonical_order(&mut events);
        (m, events)
    };
    let (m_seq, ev_seq) = run(1);
    assert!(ev_seq.iter().any(|e| matches!(e.kind, EventKind::TenantThrottle { .. })));
    for threads in [2usize, 4] {
        let (m_par, ev_par) = run(threads);
        assert_eq!(
            m_seq.digest(),
            m_par.digest(),
            "tracing + wfq: parallel digest diverged @ {threads} threads"
        );
        assert_trace_eq(&ev_par, &ev_seq, &format!("wfq parallel x{threads} vs sequential"));
    }
}

/// A chat-heavy prefix-tagged workload on the prefix-aware policy, sized so
/// all hit classes show up across the fleet (40 sessions, 3 replicas).
fn prefix_fleet() -> (Vec<Request>, ClusterCfg) {
    let pcfg = PrefixCfg::for_dataset(Dataset::ShareGpt, 43);
    let trace = generate_with_prefixes(Dataset::ShareGpt, 80, 10.0, 43, &pcfg);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(29), 3, RoutingPolicy::PrefixAware);
    (trace, cc)
}

#[test]
fn prefix_events_match_across_sequential_loops_and_tie_out() {
    // Both sequential fleet loops narrate the prefix tier identically, and
    // the event stream ties out against the run's own cache accounting:
    // one typed event per non-cold lookup, saved-token args summing to the
    // counter, everything at fleet level (routing-time decisions).
    let (trace, cc) = prefix_fleet();
    let (m_opt, ev_opt) = run_fleet(&cc, &trace, false, 1.0);
    let (m_ref, ev_ref) = run_fleet(&cc, &trace, true, 1.0);
    assert_trace_eq(&ev_opt, &ev_ref, "prefix fleet");
    assert_eq!(
        m_opt.fleet.deviation(&m_ref.fleet).map(|d| d <= 1e-9),
        Some(true),
        "loops must stay metric-equivalent with the tier on"
    );
    let count = |pred: fn(&EventKind) -> bool| ev_opt.iter().filter(|e| pred(&e.kind)).count();
    let lookups = count(|k| {
        matches!(
            k,
            EventKind::PrefixHit { .. } | EventKind::PrefixFetch { .. } | EventKind::PrefixMiss { .. }
        )
    });
    assert_eq!(lookups as u64, m_opt.prefix.lookups, "one event per non-cold lookup");
    assert!(m_opt.prefix.lookups > 0, "chat workload must exercise the cache");
    assert_eq!(
        count(|k| matches!(k, EventKind::PrefixHit { .. })) as u64,
        m_opt.prefix.local_hits
    );
    assert_eq!(
        count(|k| matches!(k, EventKind::PrefixFetch { .. })) as u64,
        m_opt.prefix.tier_hits
    );
    assert_eq!(
        count(|k| matches!(k, EventKind::PrefixMiss { .. })) as u64,
        m_opt.prefix.misses
    );
    let saved: u64 = ev_opt
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::PrefixHit { saved, .. } | EventKind::PrefixFetch { saved, .. } => {
                Some(*saved as u64)
            }
            _ => None,
        })
        .sum();
    assert_eq!(saved, m_opt.prefix.tokens_saved, "saved args must sum to the counter");
    for e in &ev_opt {
        if matches!(
            e.kind,
            EventKind::PrefixHit { .. }
                | EventKind::PrefixFetch { .. }
                | EventKind::PrefixMiss { .. }
                | EventKind::PrefixEvict { .. }
        ) {
            assert_eq!(e.replica, FLEET, "prefix decisions are fleet-scoped");
        }
    }
}

#[test]
fn prefix_tracing_is_observational() {
    // Recording the prefix events must not move the run: the tracer only
    // narrates `prefix_admit`, it never feeds back into routing or stores.
    let (trace, cc) = prefix_fleet();
    let plain = Cluster::new(cc.clone()).run(&trace);
    let (traced, events) = run_fleet(&cc, &trace, false, 1.0);
    assert_eq!(
        plain.digest(),
        traced.digest(),
        "recording prefix events changed the digest"
    );
    assert!(events.iter().any(|e| matches!(e.kind, EventKind::PrefixHit { .. })));
}

#[test]
fn parallel_prefix_fleet_emits_the_sequential_event_set() {
    // The sharded loop routes (and classifies prefixes) at the coordinator;
    // digest AND event content must match the sequential loop for any
    // thread count (canonical order, sampling off).
    let (trace, cc) = prefix_fleet();
    let run = |threads: usize| {
        let tracer = Tracer::recording();
        let mut cluster = Cluster::new(cc.clone());
        cluster.tracer = tracer.clone();
        let m = if threads > 1 {
            cluster.run_parallel(&trace, threads, 0.0)
        } else {
            cluster.run(&trace)
        };
        let mut events = tracer.take();
        canonical_order(&mut events);
        (m, events)
    };
    let (m_seq, ev_seq) = run(1);
    assert!(ev_seq.iter().any(|e| matches!(e.kind, EventKind::PrefixHit { .. })));
    for threads in [2usize, 4] {
        let (m_par, ev_par) = run(threads);
        assert_eq!(
            m_seq.digest(),
            m_par.digest(),
            "tracing + prefix: parallel digest diverged @ {threads} threads"
        );
        assert_trace_eq(&ev_par, &ev_seq, &format!("prefix parallel x{threads} vs sequential"));
    }
}

#[test]
fn prefix_events_round_trip_through_exports() {
    // Cover all four prefix event kinds across three cache configs — the
    // default tier never misses (RDMA beats recompute for any shared len),
    // so misses need the tier off, and evictions need a starved store —
    // then push the union through both serializers.
    let pcfg = PrefixCfg::for_dataset(Dataset::ShareGpt, 43);
    let trace = generate_with_prefixes(Dataset::ShareGpt, 80, 10.0, 43, &pcfg);
    let mut events = Vec::new();
    for cache in [
        PrefixCacheCfg::default(),
        PrefixCacheCfg { tier: None, ..PrefixCacheCfg::default() },
        PrefixCacheCfg { capacity: 2048, ..PrefixCacheCfg::default() },
    ] {
        let mut cc =
            ClusterCfg::new(EngineKind::Nexus, ecfg(29), 3, RoutingPolicy::JoinShortestQueue);
        cc.prefix = Some(cache);
        let (_, ev) = run_fleet(&cc, &trace, false, 1.0);
        events.extend(ev);
    }
    for (name, pred) in [
        ("prefix-hit", (|k| matches!(k, EventKind::PrefixHit { .. })) as fn(&EventKind) -> bool),
        ("prefix-fetch", |k| matches!(k, EventKind::PrefixFetch { .. })),
        ("prefix-miss", |k| matches!(k, EventKind::PrefixMiss { .. })),
        ("prefix-evict", |k| matches!(k, EventKind::PrefixEvict { .. })),
    ] {
        assert!(events.iter().any(|e| pred(&e.kind)), "no {name} event recorded");
    }
    let chrome = chrome_trace(&events).to_string();
    let parsed = Json::parse(&chrome).expect("chrome trace must be valid JSON");
    let rows = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array missing");
    assert!(!rows.is_empty(), "no trace rows");
    let jsonl = to_jsonl(&events);
    assert!(jsonl.contains("prefix-hit") && jsonl.contains("prefix-miss"));
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), events.len());
    for line in lines {
        Json::parse(line).expect("every JSONL line must parse");
    }
}

#[test]
fn tenant_events_round_trip_through_exports() {
    // Chrome and JSONL serializations of a gated run — including the new
    // TenantAdmit/TenantThrottle variants — must survive the in-repo JSON
    // parser.
    let (trace, cc) = tenant_fleet();
    let (_, events) = run_fleet(&cc, &trace, false, 1.0);
    assert!(events.iter().any(|e| matches!(e.kind, EventKind::TenantAdmit { .. })));
    assert!(events.iter().any(|e| matches!(e.kind, EventKind::TenantThrottle { .. })));
    let chrome = chrome_trace(&events).to_string();
    let parsed = Json::parse(&chrome).expect("chrome trace must be valid JSON");
    let rows = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array missing");
    assert!(!rows.is_empty(), "no trace rows");
    let jsonl = to_jsonl(&events);
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), events.len());
    for line in lines {
        Json::parse(line).expect("every JSONL line must parse");
    }
}

#[test]
fn exports_round_trip_through_the_json_parser() {
    let trace = generate(Dataset::ShareGpt, 30, 6.0, 5);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(1), 2, RoutingPolicy::RoundRobin);
    let (_, events) = run_fleet(&cc, &trace, false, 1.0);
    let chrome = chrome_trace(&events).to_string();
    let parsed = Json::parse(&chrome).expect("chrome trace must be valid JSON");
    let rows = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array missing");
    assert!(!rows.is_empty(), "no trace rows");
    let jsonl = to_jsonl(&events);
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), events.len());
    for line in lines {
        Json::parse(line).expect("every JSONL line must parse");
    }
}
