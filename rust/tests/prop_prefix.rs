//! Property battery for the fleet prefix-cache tier: store invariants
//! (residency monotonicity, capacity bounds), the transfer-cost ordering
//! (local hit < tier fetch < miss), agreement between the deterministic
//! lineage tagger and SGLang's probabilistic `RadixCache`, and the headline
//! perf claim — prefix-aware routing plus the cache tier cuts mean TTFT by
//! ≥ 1.5× vs session affinity on a chat-heavy multi-turn workload at equal
//! offered load.

use nexus::cluster::{
    run_cluster, ClusterCfg, PrefixCacheCfg, PrefixState, PrefixStore, RoutingPolicy, TierCfg,
};
use nexus::engine::{EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::sched::RadixCache;
use nexus::testing::prop;
use nexus::workload::{generate, generate_with_prefixes, Dataset, PrefixCfg, Request};

fn preq(id: usize, plen: u32, prefix: u32, shared: u16) -> Request {
    Request {
        id,
        arrival: 0.0,
        prompt_len: plen,
        output_len: 4,
        tenant: 0,
        prefix,
        shared_len: shared,
    }
}

fn ecfg(seed: u64) -> EngineCfg {
    EngineCfg::new(ModelConfig::qwen3b(), seed)
}

#[test]
fn prop_store_residency_is_monotone_and_capacity_bounded() {
    prop("prefix store invariants", 30, |rng| {
        let capacity = rng.range_usize(128, 4096);
        let chains = rng.range_usize(1, 12) as u32;
        let mut store = PrefixStore::default();
        let mut resident_seen = vec![0usize; chains as usize + 1];
        for step in 0..rng.range_usize(50, 300) {
            let chain = rng.below(chains as usize) as u32 + 1;
            let len = rng.range_usize(16, 1024);
            store.admit(chain, len, capacity);
            if store.total_tokens() > capacity {
                return Err(format!(
                    "step {step}: total {} exceeds capacity {capacity}",
                    store.total_tokens()
                ));
            }
            let now = store.resident(chain);
            // Residency after an admit covers min(len, capacity) unless a
            // later admit evicts the chain; within one admit it can only
            // shrink below `len` via the lone-chain trim.
            if now < len.min(capacity) && store.chains() > 1 {
                return Err(format!(
                    "step {step}: chain {chain} resident {now} < admitted {len}"
                ));
            }
            // Per-chain residency is monotone between admits: any chain may
            // only grow (its own admit), stay, or drop to 0 (whole-chain
            // eviction by someone else's admit). A *partial* decay is a bug —
            // except for the lone-chain trim, which shrinks the only
            // resident chain in place to fit capacity.
            for c in 1..=chains {
                let now_c = store.resident(c);
                let prev_c = resident_seen[c as usize];
                if now_c != 0 && now_c < prev_c && store.chains() > 1 {
                    return Err(format!(
                        "step {step}: chain {c} decayed {prev_c} -> {now_c} without eviction"
                    ));
                }
                resident_seen[c as usize] = now_c;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tier_cost_sits_between_local_hit_and_miss() {
    prop("tier cost ordering", 30, |rng| {
        let cfg = PrefixCacheCfg {
            tier: Some(match rng.below(3) {
                0 => TierCfg::nvlink(),
                1 => TierCfg::rdma(),
                _ => TierCfg::tcp(),
            }),
            ..PrefixCacheCfg::default()
        };
        let mut st = PrefixState::new(cfg);
        let plen = rng.range_usize(512, 8192) as u32;
        let shared = (plen as f64 * rng.range_f64(0.3, 0.9)) as u16;
        // Replica 0 computes the chain head; replica 1 never sees it.
        st.admit(0, &preq(0, plen, 9, 0), 0.0);
        let warm = preq(1, plen, 9, shared);
        let (eff_local, _) = st.effective_prompt(0, &warm);
        let (eff_remote, _) = st.effective_prompt(1, &warm);
        let miss = plen as usize;
        if eff_local >= eff_remote {
            return Err(format!("local {eff_local} must beat remote {eff_remote}"));
        }
        if eff_remote > miss {
            return Err(format!("remote {eff_remote} must never exceed a miss {miss}"));
        }
        // Whenever the link is faster than recompute the tier path engages
        // and the ordering is strict on both sides.
        let xfer = st.cfg.xfer_tokens(&st.cfg.tier.unwrap(), shared as usize);
        if xfer < shared as usize && eff_remote >= miss {
            return Err(format!(
                "link beats recompute (xfer {xfer} < shared {shared}) but remote {eff_remote} \
                 is not strictly under miss {miss}"
            ));
        }
        Ok(())
    });
}

/// The deterministic lineage tagger and SGLang's probabilistic radix draw
/// implement one prefix model: over many requests their mean saved-prefill
/// fraction must agree (both ≈ hit_prob · mean_frac on steady-state turns).
#[test]
fn tagger_and_radix_cache_agree_in_expectation() {
    let (hit_prob, mean_frac) = (0.5, 0.5);
    let n = 4000usize;
    let plen = 1000usize;

    let mut radix = RadixCache::new(hit_prob, mean_frac, 0xFEED);
    let radix_saved: usize = (0..n).map(|_| plen - radix.effective_prefill(plen)).sum();
    let radix_frac = radix_saved as f64 / (n * plen) as f64;

    let pcfg = PrefixCfg { sessions: 8, hit_prob, mean_frac, seed: 0xBEEF };
    let mut tagger = nexus::workload::PrefixTagger::new(&pcfg);
    let tagger_saved: usize = (0..n).map(|id| tagger.tag(id, plen).1 as usize).sum();
    let tagger_frac = tagger_saved as f64 / (n * plen) as f64;

    let expect = hit_prob * mean_frac;
    assert!(
        (radix_frac - expect).abs() < 0.05,
        "radix mean saved fraction {radix_frac:.3} vs model {expect:.3}"
    );
    assert!(
        (tagger_frac - expect).abs() < 0.05,
        "tagger mean saved fraction {tagger_frac:.3} vs model {expect:.3}"
    );
    assert!(
        (radix_frac - tagger_frac).abs() < 0.05,
        "the two prefix models diverge: radix {radix_frac:.3} vs tagger {tagger_frac:.3}"
    );
}

/// Untagged traffic through the prefix-aware policy must degenerate to JSQ
/// exactly — same digest, zero prefix counters.
#[test]
fn prefix_policy_on_untagged_trace_is_jsq() {
    let trace = generate(Dataset::ShareGpt, 80, 8.0, 11);
    let jsq = run_cluster(
        &ClusterCfg::new(EngineKind::Nexus, ecfg(5), 3, RoutingPolicy::JoinShortestQueue),
        &trace,
    );
    let pfx = run_cluster(
        &ClusterCfg::new(EngineKind::Nexus, ecfg(5), 3, RoutingPolicy::PrefixAware),
        &trace,
    );
    assert_eq!(jsq.digest(), pfx.digest(), "cold prefix-aware must be JSQ");
    assert_eq!(pfx.prefix.lookups, 0);
    assert_eq!(pfx.prefix.tokens_saved, 0);
}

/// Headline: on a chat-heavy multi-turn workload (high prefix reuse),
/// prefix-aware routing with the fleet tier cuts mean TTFT by at least 1.5×
/// against session-affinity routing at the *same* offered load — affinity
/// hashes sessions blindly, so consecutive turns of a chain recompute
/// prefixes the fleet already holds.
#[test]
fn prefix_aware_beats_session_affinity_ttft_on_chat() {
    // Chat-heavy reuse: long sessions, 95% warm turns sharing ~3/4 of the
    // prompt. Arrival times and lengths are identical to the untagged
    // generator; only the lineage labels differ.
    let pcfg = PrefixCfg { sessions: 12, hit_prob: 0.95, mean_frac: 0.75, seed: 0x51C2 };
    let trace = generate_with_prefixes(Dataset::ShareGpt, 300, 10.0, 23, &pcfg);

    let affinity = run_cluster(
        &ClusterCfg::new(EngineKind::Nexus, ecfg(7), 4, RoutingPolicy::SessionAffinity),
        &trace,
    );
    let prefix = run_cluster(
        &ClusterCfg::new(EngineKind::Nexus, ecfg(7), 4, RoutingPolicy::PrefixAware),
        &trace,
    );

    let a = affinity.summary();
    let p = prefix.summary();
    assert_eq!(a.completed + affinity.fleet.timeouts, 300);
    assert_eq!(p.completed + prefix.fleet.timeouts, 300);
    assert!(
        prefix.prefix.hit_rate() > 0.5,
        "chat workload must mostly hit: rate {:.2}",
        prefix.prefix.hit_rate()
    );
    assert!(prefix.prefix.tokens_saved > 0);
    assert!(
        a.mean_ttft >= 1.5 * p.mean_ttft,
        "prefix-aware must cut mean TTFT ≥ 1.5x: affinity {:.4}s vs prefix {:.4}s ({:.2}x)",
        a.mean_ttft,
        p.mean_ttft,
        a.mean_ttft / p.mean_ttft
    );
}
