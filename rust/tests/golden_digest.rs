//! Golden-digest behavior-preservation tests for the event-queue hot-path
//! overhaul (§Perf).
//!
//! The optimized paths — the O(log R) heap-based fleet loop, the
//! indexed-slot-set engine bookkeeping, and the allocation-free batch
//! assembly — must be *observationally identical* to the historical
//! implementations. The pre-refactor fleet loop is retained verbatim as
//! `Cluster::run_reference`. Two comparison instruments, chosen by
//! slicing:
//!
//! * `RunMetrics::digest` — an FNV-1a hash over the full per-request
//!   record set (times quantized to 1 ns) plus every event counter. Used
//!   where both sides advance the simulators in identical time slices
//!   (re-runs; 1-replica cluster vs. plain drive), where times are
//!   bit-identical.
//! * `RunMetrics::deviation` — structural identity plus a ≤ 1 ns bound on
//!   every virtual-time field. Used for heap loop vs. reference loop,
//!   whose different slicing leaves float-associativity noise that would
//!   make quantized hashing flaky at rounding-bucket boundaries.
//!
//! Either way, any scheduling, preemption, ordering, or accounting change
//! shows up as a failure.
//!
//! The sharded parallel fleet loop (`Cluster::run_parallel`, §Perf) is held
//! to the *stronger* standard: it advances each replica in exactly the same
//! time slices as the sequential loop, so its `ClusterMetrics::digest` must
//! equal the sequential loop's for every thread count and window size.

use nexus::cluster::{
    run_cluster, AutoscalerCfg, Cluster, ClusterCfg, ParallelCfg, PrefixCacheCfg, RoutingPolicy,
    StealCfg, TierCfg, WfqCfg,
};
use nexus::engine::{build_engine, drive, run_engine, EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::workload::{
    generate, generate_bursty, generate_with_prefixes, generate_with_tenants, BurstyCfg, Dataset,
    PrefixCfg, PrefixTagger, Request, TenantMix, TenantSpec,
};

fn ecfg(seed: u64) -> EngineCfg {
    EngineCfg::new(ModelConfig::qwen3b(), seed)
}

#[test]
fn engine_digests_are_seed_deterministic() {
    // Two independent runs of the same (engine, seed, trace) must agree
    // exactly — no wall-clock or iteration-order leakage into virtual time.
    for &kind in EngineKind::all() {
        let cfg = ecfg(11);
        let trace = generate(Dataset::Mixed, 25, 3.0, 17);
        let a = run_engine(kind, &cfg, &trace).digest();
        let mut eng = build_engine(kind, &cfg);
        let b = drive(eng.as_mut(), &trace, cfg.max_virtual_time).digest();
        assert_eq!(a, b, "{} digest unstable across runs", kind.name());
    }
}

#[test]
fn single_replica_cluster_digest_equals_engine_digest() {
    // The event-queue cluster loop at R=1 must reproduce the plain engine
    // drive bit-for-bit (at ns quantization), per engine kind and seed.
    for &kind in EngineKind::all() {
        for seed in [3u64, 29] {
            let cfg = ecfg(seed);
            let trace = generate(Dataset::ShareGpt, 30, 4.0, seed ^ 0xA5);
            let solo = run_engine(kind, &cfg, &trace);
            let cc = ClusterCfg::new(kind, cfg, 1, RoutingPolicy::RoundRobin);
            let fleet = run_cluster(&cc, &trace);
            assert_eq!(
                solo.digest(),
                fleet.fleet.digest(),
                "{} seed {seed}: 1-replica cluster diverged from run_engine",
                kind.name()
            );
        }
    }
}

#[test]
fn fleet_event_loop_matches_reference_per_kind() {
    // N-replica clusters: the heap loop vs. the pre-refactor O(R)-scan
    // loop, across every engine kind and two fleet sizes. The two loops
    // advance the GPU simulators in different time slices, so virtual
    // times may carry float-associativity noise: compare structurally
    // with a 1 ns deviation bound instead of quantized digest equality.
    let trace = generate(Dataset::Mixed, 60, 8.0, 23);
    for &kind in EngineKind::all() {
        for &replicas in &[2usize, 5] {
            let cc =
                ClusterCfg::new(kind, ecfg(7), replicas, RoutingPolicy::JoinShortestQueue);
            let a = Cluster::new(cc.clone()).run(&trace);
            let b = Cluster::new(cc).run_reference(&trace);
            let dev = a.fleet.deviation(&b.fleet);
            assert!(
                matches!(dev, Some(d) if d <= 1e-9),
                "{} x{replicas}: event loop diverged from reference (deviation {dev:?})",
                kind.name()
            );
            // Time-weighted trajectory means are excluded from the digest
            // (float-associativity drift); pin them with tolerances.
            assert!((a.fleet.mean_rp - b.fleet.mean_rp).abs() < 1e-9);
            assert!((a.fleet.mean_kv_usage - b.fleet.mean_kv_usage).abs() < 1e-9);
            assert!((a.fleet.decode_mode_frac - b.fleet.decode_mode_frac).abs() < 1e-9);
            assert!((a.replica_seconds - b.replica_seconds).abs() < 1e-6);
            assert_eq!(a.peak_replicas, b.peak_replicas);
            assert_eq!(a.ttft_hist.count(), b.ttft_hist.count());
            assert_eq!(a.tbt_hist.count(), b.tbt_hist.count());
            assert_eq!(a.replicas.len(), b.replicas.len());
            for (x, y) in a.replicas.iter().zip(&b.replicas) {
                assert_eq!(
                    (x.id, x.routed, x.completed),
                    (y.id, y.routed, y.completed),
                    "{} x{replicas}: per-replica accounting diverged",
                    kind.name()
                );
            }
        }
    }
}

#[test]
fn fleet_event_loop_matches_reference_per_policy() {
    // Routing policies see per-arrival view snapshots; the reused view
    // buffer must not change any routing decision.
    let trace = generate(Dataset::ShareGpt, 70, 9.0, 37);
    for &policy in RoutingPolicy::all() {
        let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(19), 3, policy);
        let a = Cluster::new(cc.clone()).run(&trace);
        let b = Cluster::new(cc).run_reference(&trace);
        let dev = a.fleet.deviation(&b.fleet);
        assert!(
            matches!(dev, Some(d) if d <= 1e-9),
            "{}: event loop diverged from reference (deviation {dev:?})",
            policy.name()
        );
        let ra: Vec<usize> = a.replicas.iter().map(|r| r.routed).collect();
        let rb: Vec<usize> = b.replicas.iter().map(|r| r.routed).collect();
        assert_eq!(ra, rb, "{}: routing decisions diverged", policy.name());
    }
}

#[test]
fn autoscaled_fleet_matches_reference() {
    // Autoscaler ticks are loop events too: decisions, scale times, and
    // hysteresis suppression must be identical under the heap loop.
    let bursty = BurstyCfg { base_rate: 10.0, ..BurstyCfg::default() };
    let trace = generate_bursty(Dataset::ShareGpt, 80, &bursty, 41);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(13), 1, RoutingPolicy::JoinShortestQueue);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 4,
        interval: 2.0,
        cooldown: 5.0,
        ..AutoscalerCfg::default()
    });
    let a = Cluster::new(cc.clone()).run(&trace);
    let b = Cluster::new(cc).run_reference(&trace);
    let dev = a.fleet.deviation(&b.fleet);
    assert!(
        matches!(dev, Some(d) if d <= 1e-9),
        "autoscaled fleet diverged (deviation {dev:?})"
    );
    assert_eq!(a.scale_events.len(), b.scale_events.len());
    for (ea, eb) in a.scale_events.iter().zip(&b.scale_events) {
        assert!((ea.time - eb.time).abs() < 1e-9, "scale time diverged");
        assert_eq!((ea.from, ea.to), (eb.from, eb.to), "scale decision diverged");
    }
    assert_eq!(a.suppressed_scales, b.suppressed_scales);
    assert_eq!(a.peak_replicas, b.peak_replicas);
    assert!((a.replica_seconds - b.replica_seconds).abs() < 1e-6);
}

#[test]
fn parallel_fleet_matches_sequential_digest_per_kind() {
    // The sharded loop steps every replica at the same virtual times as the
    // sequential loop, so the full cluster digest (records at ns
    // quantization, per-replica accounting, scale history, histogram
    // counts) must be *equal* — not merely within tolerance — for every
    // engine kind and thread count, including thread counts exceeding the
    // replica count.
    let trace = generate(Dataset::Mixed, 50, 7.0, 61);
    for &kind in EngineKind::all() {
        let cc = ClusterCfg::new(kind, ecfg(5), 4, RoutingPolicy::JoinShortestQueue);
        let seq = Cluster::new(cc.clone()).run(&trace).digest();
        for threads in [1usize, 2, 4, 8] {
            let par = Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0).digest();
            assert_eq!(
                seq,
                par,
                "{} x4 @ {threads} threads: parallel loop diverged from sequential",
                kind.name()
            );
        }
    }
}

#[test]
fn parallel_fleet_matches_sequential_digest_per_policy() {
    // Routing state (round-robin cursor, session table, dispatch counter)
    // lives on the coordinator and sees the same merged view snapshots, so
    // every policy must make identical decisions under sharding.
    let trace = generate(Dataset::ShareGpt, 60, 9.0, 71);
    for &policy in RoutingPolicy::all() {
        let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(9), 3, policy);
        let seq = Cluster::new(cc.clone()).run(&trace).digest();
        for threads in [2usize, 5] {
            let par = Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0).digest();
            assert_eq!(
                seq,
                par,
                "{} @ {threads} threads: routing diverged under sharding",
                policy.name()
            );
        }
    }
}

#[test]
fn parallel_autoscaled_fleet_matches_sequential_digest() {
    // Autoscaler ticks are coordinator rendezvous points in the sharded
    // loop: fleet observations, scale decisions, spawn priming, and
    // drain/retire timing must all land on identical virtual times.
    let bursty = BurstyCfg { base_rate: 12.0, ..BurstyCfg::default() };
    let trace = generate_bursty(Dataset::ShareGpt, 80, &bursty, 43);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(21), 1, RoutingPolicy::JoinShortestQueue);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 4,
        interval: 2.0,
        cooldown: 5.0,
        ..AutoscalerCfg::default()
    });
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    for threads in [2usize, 4, 8] {
        let par = Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0).digest();
        assert_eq!(seq, par, "autoscaled fleet diverged @ {threads} threads");
    }
}

#[test]
fn parallel_fleet_window_size_is_output_invariant() {
    // The synchronization window only caps how far workers free-run between
    // rendezvous; window-capped rounds do no routing, stepping, or ticking,
    // so any window must produce the identical digest.
    let trace = generate(Dataset::Mixed, 60, 8.0, 83);
    let mut cc =
        ClusterCfg::new(EngineKind::VllmPD, ecfg(31), 3, RoutingPolicy::LeastKvPressure);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 5,
        interval: 3.0,
        cooldown: 6.0,
        ..AutoscalerCfg::default()
    });
    let base = Cluster::new(cc.clone()).run_parallel(&trace, 4, 0.0).digest();
    for window in [0.01f64, 0.25, 2.0, 1e6] {
        let d = Cluster::new(cc.clone()).run_parallel(&trace, 4, window).digest();
        assert_eq!(base, d, "window {window} changed the parallel digest");
    }
    let seq = Cluster::new(cc).run(&trace).digest();
    assert_eq!(base, seq, "windowed parallel loop diverged from sequential");
}

/// A session-affinity hot spot: 64 simultaneous t=0 arrivals pin sessions
/// 0..63 to replicas 0..(r-1) via the JSQ-fallback cascade, then the body
/// floods a handful of hot sessions so the static `id % threads` partition
/// piles their replicas onto few shards — the workload work stealing
/// exists for.
fn skewed_affinity_trace(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    let base = generate(Dataset::ShareGpt, n, rate, seed);
    let mut trace = Vec::with_capacity(n + 64);
    for k in 0..64usize {
        trace.push(Request { id: k, arrival: 0.0, prompt_len: 64, output_len: 4, tenant: 0, prefix: 0, shared_len: 0 });
    }
    for (i, r) in base.iter().enumerate() {
        // 90 % of traffic on sessions {0, 8, .., 56}; the rest never ≡ 0
        // (mod 8), so the hot set is exact.
        let session = if i % 10 < 9 { 8 * (i % 8) } else { 8 * (i % 8) + 1 + i % 7 };
        trace.push(Request { id: (i + 1) * 64 + session, ..*r });
    }
    trace
}

#[test]
fn parallel_stealing_fleet_matches_sequential_digest() {
    // Work stealing migrates replicas between shards, but *where* a replica
    // is stepped is scheduling metadata: the served output must be digest-
    // equal to the sequential loop for every thread count and stealing
    // config, on the adversarially skewed workload.
    let trace = skewed_affinity_trace(120, 20.0, 131);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(7), 8, RoutingPolicy::SessionAffinity);
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    for threads in [1usize, 2, 4, 8] {
        for steal in [
            None,
            Some(StealCfg { threshold: 1.2, interval: 0.5 }),
            Some(StealCfg { threshold: 3.0, interval: 2.0 }),
        ] {
            let par = Cluster::new(cc.clone())
                .run_parallel_cfg(&trace, ParallelCfg { threads, window: 0.0, steal })
                .digest();
            assert_eq!(
                seq, par,
                "stealing fleet diverged @ {threads} threads, steal {steal:?}"
            );
        }
    }
}

#[test]
fn parallel_stealing_autoscaled_fleet_matches_sequential_digest() {
    // Stealing + autoscale churn: spawns are routed to the lightest shard
    // and drained replicas may migrate mid-drain; none of it may show in
    // the digest.
    let trace = skewed_affinity_trace(100, 16.0, 211);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(13), 4, RoutingPolicy::SessionAffinity);
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 2,
        max_replicas: 8,
        interval: 2.0,
        cooldown: 4.0,
        ..AutoscalerCfg::default()
    });
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    for threads in [2usize, 4, 8] {
        for steal in [None, Some(StealCfg { threshold: 1.2, interval: 0.5 })] {
            let par = Cluster::new(cc.clone())
                .run_parallel_cfg(&trace, ParallelCfg { threads, window: 0.0, steal })
                .digest();
            assert_eq!(
                seq, par,
                "autoscaled stealing fleet diverged @ {threads} threads, steal {steal:?}"
            );
        }
    }
}

#[test]
fn parallel_stealing_window_is_output_invariant() {
    // Windowed rounds interleave with balance checks; the combination must
    // still be output-invariant.
    let trace = skewed_affinity_trace(80, 14.0, 307);
    let cc = ClusterCfg::new(EngineKind::Vllm, ecfg(17), 6, RoutingPolicy::SessionAffinity);
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    for window in [0.0f64, 0.1, 1.0, 1e6] {
        let par = Cluster::new(cc.clone())
            .run_parallel_cfg(
                &trace,
                ParallelCfg {
                    threads: 4,
                    window,
                    steal: Some(StealCfg { threshold: 1.1, interval: 0.25 }),
                },
            )
            .digest();
        assert_eq!(seq, par, "stealing + window {window} changed the digest");
    }
}

/// Run one trace through all three fronts — sequential, sharded slice,
/// sharded stream — under a given config and assert digest equality.
fn assert_three_way_digest(cc: &ClusterCfg, trace: &[Request], label: &str) {
    let seq = Cluster::new(cc.clone()).run(trace).digest();
    let steal = Some(StealCfg { threshold: 1.2, interval: 0.5 });
    for threads in [1usize, 3] {
        let slice = Cluster::new(cc.clone()).run_parallel(trace, threads, 0.0).digest();
        assert_eq!(seq, slice, "{label}: slice diverged @ {threads} threads");
        let stream = Cluster::new(cc.clone())
            .run_parallel_stream(trace.iter().copied(), None, threads, 0.0)
            .digest();
        assert_eq!(seq, stream, "{label}: stream diverged @ {threads} threads");
        // With stealing on, simultaneous groups take the rendezvous-batching
        // fast path (blind-routable policies) — same digest required.
        let stolen = Cluster::new(cc.clone())
            .run_parallel_cfg(trace, ParallelCfg { threads, window: 0.0, steal })
            .digest();
        assert_eq!(seq, stolen, "{label}: stealing slice diverged @ {threads} threads");
        let stolen_stream = Cluster::new(cc.clone())
            .run_parallel_stream_cfg(
                trace.iter().copied(),
                None,
                ParallelCfg { threads, window: 0.0, steal },
            )
            .digest();
        assert_eq!(
            seq, stolen_stream,
            "{label}: stealing stream diverged @ {threads} threads"
        );
    }
}

#[test]
fn stream_arrivals_edge_cases_match_all_fronts() {
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(23), 2, RoutingPolicy::RoundRobin);

    // Empty workload: the loops must terminate immediately with an empty
    // digest, not deadlock waiting for a first arrival.
    assert_three_way_digest(&cc, &[], "empty trace");

    // Single request.
    let one = [Request { id: 0, arrival: 0.5, prompt_len: 128, output_len: 8, tenant: 0, prefix: 0, shared_len: 0 }];
    assert_three_way_digest(&cc, &one, "single request");

    // Simultaneous ties: several arrivals at *exactly* the same instant
    // must be routed in id order by every front (the stream pops ties in
    // push order, the sharded loop batches the whole group into one round).
    let mut ties = Vec::new();
    for id in 0..12usize {
        ties.push(Request {
            id,
            arrival: if id < 6 { 0.0 } else { 1.25 },
            prompt_len: 64 + 32 * (id as u32 % 3),
            output_len: 6,
            tenant: 0,
            prefix: 0,
            shared_len: 0,
        });
    }
    assert_three_way_digest(&cc, &ties, "simultaneous ties");

    // Ties under a policy whose decisions depend on earlier ties' pending
    // bumps (JSQ) — the blind-batch fast path must not engage and the
    // cascade must match the sequential order.
    let cc_jsq = ClusterCfg::new(EngineKind::Vllm, ecfg(29), 3, RoutingPolicy::JoinShortestQueue);
    let mut ties = Vec::new();
    for id in 0..9usize {
        ties.push(Request { id, arrival: 2.0, prompt_len: 96, output_len: 5, tenant: 0, prefix: 0, shared_len: 0 });
    }
    assert_three_way_digest(&cc_jsq, &ties, "jsq simultaneous ties");
}

/// Chat-heavy prefix-tagged trace: the per-dataset lineage model the
/// coordinator applies for prefix-enabled fleet runs.
fn prefix_trace(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    generate_with_prefixes(
        Dataset::ShareGpt,
        n,
        rate,
        seed,
        &PrefixCfg::for_dataset(Dataset::ShareGpt, seed),
    )
}

#[test]
fn prefix_aware_fleet_matches_all_fronts() {
    // Prefix-aware routing mutates coordinator-side state (stores, tier,
    // counters) at every routing commit — the adversarial case for the
    // sharded loop, whose rendezvous batches may only blind-route prefix
    // arrivals that are provably pure LRU touches. Every front must agree,
    // and the digest covers the prefix counters.
    let trace = prefix_trace(100, 12.0, 61);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(9), 4, RoutingPolicy::PrefixAware);
    assert_three_way_digest(&cc, &trace, "prefix-aware fleet");

    // Tiny stores over a slow tier: evictions and tier fetches on every
    // front (the blind fast path disengages once stores lose headroom).
    let mut small = cc.clone();
    small.prefix = Some(PrefixCacheCfg {
        capacity: 2048,
        tier: Some(TierCfg::tcp()),
        ..PrefixCacheCfg::default()
    });
    assert_three_way_digest(&small, &trace, "prefix-aware tiny stores");

    // Local stores only — remote replicas pay full recompute.
    let mut local_only = cc.clone();
    local_only.prefix = Some(PrefixCacheCfg { tier: None, ..PrefixCacheCfg::default() });
    assert_three_way_digest(&local_only, &trace, "prefix-aware no tier");

    // The machinery under a non-prefix policy: affinity routing with the
    // tier shortening prefills behind its back.
    let mut aff =
        ClusterCfg::new(EngineKind::Nexus, ecfg(9), 4, RoutingPolicy::SessionAffinity);
    aff.prefix = Some(PrefixCacheCfg::default());
    assert_three_way_digest(&aff, &trace, "affinity + prefix tier");
}

#[test]
fn prefix_aware_thread_sweep_matches_sequential_digest() {
    // Wider thread sweep with stealing and windows engaged — the exact
    // config space the rendezvous-batching pure-touch rule must survive.
    let trace = prefix_trace(120, 16.0, 91);
    let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(21), 6, RoutingPolicy::PrefixAware);
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    let reference = Cluster::new(cc.clone()).run_reference(&trace).digest();
    assert_eq!(seq, reference, "prefix-aware heap loop diverged from reference");
    for threads in [1usize, 4, 8] {
        for steal in [None, Some(StealCfg { threshold: 1.2, interval: 0.5 })] {
            for window in [0.0f64, 0.5] {
                let par = Cluster::new(cc.clone())
                    .run_parallel_cfg(&trace, ParallelCfg { threads, window, steal })
                    .digest();
                assert_eq!(
                    seq, par,
                    "prefix-aware fleet diverged @ {threads} threads, window {window}, \
                     steal {steal:?}"
                );
            }
        }
    }
}

#[test]
fn prefix_aware_wfq_fleet_matches_all_fronts() {
    // Prefix routing behind the saturating WFQ gate: dispatches flow
    // through the gated arm of every loop, and gated rounds never take the
    // blind-batching fast path.
    let mut trace = tenant_trace(90, 14.0, 71);
    PrefixTagger::new(&PrefixCfg::for_dataset(Dataset::ShareGpt, 71)).apply(&mut trace);
    let mut cc = ClusterCfg::new(EngineKind::Nexus, ecfg(3), 3, RoutingPolicy::PrefixAware);
    cc.wfq = Some(wfq_cfg());
    assert_three_way_digest(&cc, &trace, "prefix-aware wfq");
}

/// Tenant-labeled trace: 3:2:1 traffic shares over three tenants, arrival
/// times identical to the untagged generator (tagging is id-residue only).
fn tenant_trace(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    generate_with_tenants(Dataset::ShareGpt, n, rate, seed, &TenantMix::new(vec![3, 2, 1]))
}

/// Saturating WFQ config: skewed weights, tight per-tenant quotas, and a
/// fleet-wide capacity cap, so the gate actually holds requests back and
/// the completion-triggered re-dispatch path is exercised.
fn wfq_cfg() -> WfqCfg {
    WfqCfg::new(vec![
        TenantSpec { weight: 3.0, admission_quota: 6, ..TenantSpec::default() },
        TenantSpec { weight: 1.0, admission_quota: 4, ..TenantSpec::default() },
        TenantSpec { weight: 1.0, admission_quota: 2, ..TenantSpec::default() },
    ])
    .with_capacity(10)
}

#[test]
fn wfq_quota_fleet_three_way_digest() {
    // The tenant gate is virtual-time state like everything else: the heap
    // loop, the reference loop, and the sharded loop must drive it to
    // identical admission decisions — any thread count, stealing on or off.
    let trace = tenant_trace(80, 14.0, 53);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(5), 3, RoutingPolicy::JoinShortestQueue);
    cc.wfq = Some(wfq_cfg());
    let a = Cluster::new(cc.clone()).run(&trace);
    let b = Cluster::new(cc.clone()).run_reference(&trace);
    let dev = a.fleet.deviation(&b.fleet);
    assert!(
        matches!(dev, Some(d) if d <= 1e-9),
        "WFQ fleet: event loop diverged from reference (deviation {dev:?})"
    );
    let seq = a.digest();
    for threads in [1usize, 4, 8] {
        for steal in [None, Some(StealCfg { threshold: 1.2, interval: 0.5 })] {
            let par = Cluster::new(cc.clone())
                .run_parallel_cfg(&trace, ParallelCfg { threads, window: 0.0, steal })
                .digest();
            assert_eq!(
                seq, par,
                "WFQ fleet diverged @ {threads} threads, steal {steal:?}"
            );
        }
    }
}

#[test]
fn wfq_quota_autoscaled_fleet_three_way_digest() {
    // Autoscale churn under the gate: spawned replicas must prime at the
    // gate's same-instant re-dispatch iterations exactly like the
    // sequential loop, and drains must not strand gated requests.
    let trace = tenant_trace(90, 16.0, 67);
    let mut cc =
        ClusterCfg::new(EngineKind::Nexus, ecfg(11), 2, RoutingPolicy::JoinShortestQueue);
    cc.wfq = Some(wfq_cfg());
    cc.autoscale = Some(AutoscalerCfg {
        min_replicas: 1,
        max_replicas: 5,
        interval: 2.0,
        cooldown: 4.0,
        ..AutoscalerCfg::default()
    });
    let a = Cluster::new(cc.clone()).run(&trace);
    let b = Cluster::new(cc.clone()).run_reference(&trace);
    let dev = a.fleet.deviation(&b.fleet);
    assert!(
        matches!(dev, Some(d) if d <= 1e-9),
        "autoscaled WFQ fleet diverged from reference (deviation {dev:?})"
    );
    let seq = a.digest();
    for threads in [1usize, 4, 8] {
        for steal in [None, Some(StealCfg { threshold: 1.2, interval: 0.5 })] {
            let par = Cluster::new(cc.clone())
                .run_parallel_cfg(&trace, ParallelCfg { threads, window: 0.0, steal })
                .digest();
            assert_eq!(
                seq, par,
                "autoscaled WFQ fleet diverged @ {threads} threads, steal {steal:?}"
            );
        }
    }
}

#[test]
fn wfq_window_is_output_invariant() {
    // Windowed advance rounds interact with the gate's lockstep mode (a
    // backlogged gate pins the round horizon to the boundary); any window
    // must still produce the sequential digest.
    let trace = tenant_trace(60, 12.0, 89);
    let mut cc =
        ClusterCfg::new(EngineKind::Vllm, ecfg(41), 3, RoutingPolicy::LeastKvPressure);
    cc.wfq = Some(wfq_cfg());
    let seq = Cluster::new(cc.clone()).run(&trace).digest();
    for window in [0.0f64, 0.1, 1.0, 1e6] {
        let par = Cluster::new(cc.clone())
            .run_parallel_cfg(
                &trace,
                ParallelCfg { threads: 4, window, steal: None },
            )
            .digest();
        assert_eq!(seq, par, "WFQ + window {window} changed the digest");
    }
}

#[test]
fn wfq_edge_configs_three_way_digest() {
    // Degenerate gates: unit capacity (strict serialization), a quota-less
    // uniform gate (pure WFQ ordering), and simultaneous-tie arrivals.
    let trace = tenant_trace(40, 10.0, 97);
    let mut serial =
        ClusterCfg::new(EngineKind::Nexus, ecfg(3), 2, RoutingPolicy::RoundRobin);
    serial.wfq = Some(WfqCfg::uniform(3).with_capacity(1));
    assert_three_way_digest(&serial, &trace, "unit-capacity gate");

    let mut open = ClusterCfg::new(EngineKind::Nexus, ecfg(3), 2, RoutingPolicy::RoundRobin);
    open.wfq = Some(WfqCfg::uniform(3));
    assert_three_way_digest(&open, &trace, "uncapped uniform gate");

    let mut ties = Vec::new();
    for id in 0..12usize {
        ties.push(Request {
            id,
            arrival: if id < 6 { 0.0 } else { 1.5 },
            prompt_len: 64 + 32 * (id as u32 % 3),
            output_len: 6,
            tenant: (id % 3) as u16,
            prefix: 0,
            shared_len: 0,
        });
    }
    let mut tie_cc =
        ClusterCfg::new(EngineKind::Vllm, ecfg(29), 2, RoutingPolicy::JoinShortestQueue);
    tie_cc.wfq = Some(wfq_cfg().with_capacity(4));
    assert_three_way_digest(&tie_cc, &ties, "gated simultaneous ties");
}
