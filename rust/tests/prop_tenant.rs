//! Property battery for multi-tenant serving: WFQ fairness envelopes,
//! admission-quota invariants, and the pay-for-what-you-use contract
//! (tenant labels and a trivial gate must be observationally invisible) —
//! driven by the in-repo mini property harness (`nexus::testing`).

use nexus::cluster::{run_cluster, Cluster, ClusterCfg, RoutingPolicy, TenantGate, WfqCfg};
use nexus::engine::{EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::testing::prop;
use nexus::util::rng::Rng;
use nexus::workload::{
    generate, generate_with_tenants, Dataset, Request, TenantMix, TenantSpec,
};

fn treq(id: usize, tenant: u16) -> Request {
    Request { id, arrival: 0.0, prompt_len: 64, output_len: 4, tenant, prefix: 0, shared_len: 0 }
}

fn random_policy(rng: &mut Rng) -> RoutingPolicy {
    let all = RoutingPolicy::all();
    all[rng.below(all.len())]
}

#[test]
fn prop_wfq_service_share_tracks_weights_under_saturation() {
    // Every tenant keeps a deep backlog while we dispatch (completing each
    // request immediately, so quotas never bind). Classic WFQ guarantee:
    // with unit request cost, tenant i's service over N dispatches stays
    // within a constant envelope of its weight share N·w_i/Σw — the
    // discrepancy is bounded by the per-tenant partial requests at the
    // virtual-time frontier, not by N.
    prop("wfq weight-share fairness", 25, |rng| {
        let n_tenants = rng.range_usize(2, 5);
        let weights: Vec<f64> = (0..n_tenants).map(|_| rng.range_f64(0.5, 8.0)).collect();
        let specs: Vec<TenantSpec> = weights
            .iter()
            .map(|&w| TenantSpec { weight: w, ..TenantSpec::default() })
            .collect();
        let mut gate = TenantGate::new(WfqCfg::new(specs));
        let pops = rng.range_usize(100, 400);
        let mut id = 0usize;
        for t in 0..n_tenants {
            for _ in 0..pops + 4 {
                gate.push(treq(id, t as u16));
                id += 1;
            }
        }
        let total_w: f64 = weights.iter().sum();
        let mut served = vec![0usize; n_tenants];
        for _ in 0..pops {
            let r = gate.pop_next().ok_or("backlogged gate refused to dispatch")?;
            served[r.tenant as usize] += 1;
            gate.on_complete(r.tenant);
        }
        let envelope = n_tenants as f64 + 2.0;
        for t in 0..n_tenants {
            let expect = pops as f64 * weights[t] / total_w;
            let got = served[t] as f64;
            if (got - expect).abs() > envelope {
                return Err(format!(
                    "tenant {t} (weight {:.2}) served {got} of {pops}, \
                     expected {expect:.1} ± {envelope:.1} (weights {weights:?})",
                    weights[t]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wfq_quota_and_capacity_never_exceeded() {
    // Random interleaving of arrivals, dispatches, and completions: the
    // per-tenant in-flight count must never exceed its admission quota and
    // the fleet total must never exceed the capacity cap; the gate's own
    // accounting must agree with the external tally throughout.
    prop("wfq quota/capacity invariant", 25, |rng| {
        let n_tenants = rng.range_usize(1, 4);
        let quotas: Vec<usize> = (0..n_tenants).map(|_| rng.range_usize(1, 5)).collect();
        let capacity = rng.range_usize(1, 8);
        let specs: Vec<TenantSpec> = quotas
            .iter()
            .map(|&q| TenantSpec { admission_quota: q, ..TenantSpec::default() })
            .collect();
        let mut gate = TenantGate::new(WfqCfg::new(specs).with_capacity(capacity));
        let mut inflight = vec![0usize; n_tenants];
        let mut total = 0usize;
        let mut live: Vec<u16> = Vec::new();
        let mut id = 0usize;
        for _ in 0..400 {
            match rng.below(3) {
                0 => {
                    let t = rng.below(n_tenants) as u16;
                    gate.push(treq(id, t));
                    id += 1;
                }
                1 => {
                    if let Some(r) = gate.pop_next() {
                        let t = r.tenant as usize;
                        inflight[t] += 1;
                        total += 1;
                        live.push(r.tenant);
                        if inflight[t] > quotas[t] {
                            return Err(format!(
                                "tenant {t}: {} in flight > quota {}",
                                inflight[t], quotas[t]
                            ));
                        }
                        if total > capacity {
                            return Err(format!("{total} in flight > capacity {capacity}"));
                        }
                        if gate.inflight_for(r.tenant) != inflight[t]
                            || gate.inflight_total() != total
                        {
                            return Err("gate accounting disagrees with tally".into());
                        }
                    } else if total < capacity
                        && (0..n_tenants)
                            .any(|t| gate.queued_for(t as u16) > 0 && inflight[t] < quotas[t])
                    {
                        return Err("eligible head refused while under quota".into());
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let pick = rng.below(live.len());
                        let t = live.swap_remove(pick);
                        gate.on_complete(t);
                        inflight[t as usize] -= 1;
                        total -= 1;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tenant_tags_without_wfq_are_observationally_invisible() {
    // Pay-for-what-you-use, half 1: labeling the workload (no gate) must
    // not move a single virtual-time field — same arrivals, same routing,
    // same per-request timings, bit for bit.
    prop("tenant tags are free", 8, |rng| {
        let n = rng.range_usize(20, 45);
        let rate = rng.range_f64(2.0, 12.0);
        let seed = rng.next_u64();
        let dataset = [Dataset::ShareGpt, Dataset::Mixed][rng.below(2)];
        let shares: Vec<u32> = (0..rng.range_usize(2, 4)).map(|_| rng.range_usize(1, 4) as u32).collect();
        let tagged = generate_with_tenants(dataset, n, rate, seed, &TenantMix::new(shares));
        let untagged = generate(dataset, n, rate, seed);
        let kind = [EngineKind::Vllm, EngineKind::Nexus][rng.below(2)];
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let cc = ClusterCfg::new(kind, ecfg, rng.range_usize(1, 4), random_policy(rng));
        let a = run_cluster(&cc, &tagged);
        let b = run_cluster(&cc, &untagged);
        if a.fleet.records.len() != b.fleet.records.len() {
            return Err("record counts diverged".into());
        }
        for (x, y) in a.fleet.records.iter().zip(&b.fleet.records) {
            if x.id != y.id
                || x.arrival != y.arrival
                || x.first_token != y.first_token
                || x.finish != y.finish
            {
                return Err(format!("request {} timing moved under tagging", x.id));
            }
        }
        let ra: Vec<usize> = a.replicas.iter().map(|r| r.routed).collect();
        let rb: Vec<usize> = b.replicas.iter().map(|r| r.routed).collect();
        if ra != rb {
            return Err("routing decisions moved under tagging".into());
        }
        Ok(())
    });
}

#[test]
fn prop_trivial_gate_is_digest_identical_to_baseline() {
    // Pay-for-what-you-use, half 2: a single-tenant gate with no quota and
    // no capacity cap admits everything immediately in arrival order, so
    // the full cluster digest must equal the ungated run's — on all three
    // fleet loops.
    prop("trivial gate is free", 8, |rng| {
        let n = rng.range_usize(20, 45);
        let trace = generate(
            [Dataset::ShareGpt, Dataset::Mixed][rng.below(2)],
            n,
            rng.range_f64(2.0, 12.0),
            rng.next_u64(),
        );
        let kind = [EngineKind::Vllm, EngineKind::Nexus][rng.below(2)];
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), rng.next_u64());
        let base_cc = ClusterCfg::new(kind, ecfg, rng.range_usize(1, 4), random_policy(rng));
        let mut gated_cc = base_cc.clone();
        gated_cc.wfq = Some(WfqCfg::uniform(1));
        let base = Cluster::new(base_cc.clone()).run(&trace).digest();
        let gated = Cluster::new(gated_cc.clone()).run(&trace).digest();
        if base != gated {
            return Err("trivial gate changed the sequential digest".into());
        }
        // The reference loop slices time differently from the heap loop, so
        // compare it against its own ungated run, not across loops.
        let base_ref = Cluster::new(base_cc).run_reference(&trace).digest();
        let gated_ref = Cluster::new(gated_cc.clone()).run_reference(&trace).digest();
        if base_ref != gated_ref {
            return Err("trivial gate changed the reference digest".into());
        }
        let threads = rng.range_usize(2, 6);
        let gated_par =
            Cluster::new(gated_cc).run_parallel(&trace, threads, 0.0).digest();
        if base != gated_par {
            return Err(format!("trivial gate changed the parallel digest @ {threads} threads"));
        }
        Ok(())
    });
}
