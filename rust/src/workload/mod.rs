//! Workload generators — paper §6.1 / Table 1.
//!
//! The paper evaluates on three dataset-derived traces (Long Data
//! Collections, ArXiv Summarization, ShareGPT) whose only serving-relevant
//! signal is the joint distribution of (input length, output length) plus a
//! Poisson arrival process. Table 1 fully characterizes those distributions
//! (mean / P50 / P95 / P99 per direction), so we fit a clamped log-normal
//! per (dataset, direction) to the published percentiles and generate
//! synthetic traces from it; `cargo bench --bench table1_workloads` prints
//! the generated statistics next to the paper's numbers.

use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::{mean, percentile};
use std::collections::BTreeMap;

/// A serving request as the engine layer sees it.
///
/// Hot-state compaction (§Perf): token lengths are `u32`, the tenant
/// label a `u16`, and the prefix lineage a `u32` chain id + `u16` shared
/// length (32 bytes per request instead of 48+ with `usize` fields) —
/// a million-request streaming trace holds only the in-flight window, but
/// per-request copies also live in every engine's `ReqState`, so the narrow
/// struct pays at fleet scale. Lengths are bounded by context windows
/// (≪ 2³²); use [`Request::plen`] / [`Request::olen`] where `usize`
/// arithmetic is needed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Arrival time (seconds from trace start).
    pub arrival: f64,
    pub prompt_len: u32,
    pub output_len: u32,
    /// Owning tenant (index into the run's `TenantSpec` table; single-tenant
    /// workloads leave it 0).
    pub tenant: u16,
    /// Prefix-chain id (session lineage); 0 means "no chain" — the request
    /// shares no prefix and seeds no residency. See [`PrefixCfg`].
    pub prefix: u32,
    /// Tokens of the prompt shared with the chain's accumulated prefix
    /// (0 for the first turn of a chain; always < `prompt_len`).
    pub shared_len: u16,
}

impl Request {
    /// Prompt length as `usize` (index/sum arithmetic).
    #[inline]
    pub fn plen(&self) -> usize {
        self.prompt_len as usize
    }

    /// Output length as `usize` (index/sum arithmetic).
    #[inline]
    pub fn olen(&self) -> usize {
        self.output_len as usize
    }

    /// Tenant label as `usize` (index arithmetic).
    #[inline]
    pub fn tid(&self) -> usize {
        self.tenant as usize
    }

    /// Shared-prefix length as `usize`, clamped below the prompt length
    /// (a request always has at least one novel token to prefill).
    #[inline]
    pub fn shared(&self) -> usize {
        (self.shared_len as usize).min(self.plen().saturating_sub(1))
    }
}

/// Per-tenant service contract: a WFQ weight, the two latency SLOs that
/// define goodput (DistServe-style: a request counts iff it meets *both*),
/// and an admission quota bounding the tenant's in-flight requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantSpec {
    /// Weighted-fair-queueing weight (> 0; service share under saturation
    /// is proportional to it).
    pub weight: f64,
    /// Time-to-first-token SLO (seconds).
    pub ttft_slo: f64,
    /// Time-between-tokens SLO (seconds, mean inter-token gap).
    pub tbt_slo: f64,
    /// Max requests this tenant may have admitted-but-unfinished at once.
    pub admission_quota: usize,
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec {
            weight: 1.0,
            ttft_slo: 10.0,
            tbt_slo: 0.2,
            admission_quota: usize::MAX,
        }
    }
}

/// Deterministic tenant-mix labeling for the generators: integer shares,
/// applied by request id so that tagging is a pure function of the id —
/// streaming and Vec generators agree trivially, and every window of
/// `sum(shares)` consecutive ids carries the exact mix.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    /// Integer share per tenant (tenant k gets `shares[k] / sum` of ids).
    pub shares: Vec<u32>,
}

impl TenantMix {
    pub fn new(shares: Vec<u32>) -> Self {
        assert!(!shares.is_empty(), "tenant mix needs at least one tenant");
        assert!(shares.iter().any(|&s| s > 0), "tenant mix needs a nonzero share");
        TenantMix { shares }
    }

    /// `n` tenants with equal shares.
    pub fn uniform(n: usize) -> Self {
        TenantMix::new(vec![1; n.max(1)])
    }

    pub fn tenants(&self) -> usize {
        self.shares.len()
    }

    /// Tenant owning request `id`: the id's residue modulo the total share
    /// falls into tenant k's contiguous share band.
    pub fn tag(&self, id: usize) -> u16 {
        let total: u64 = self.shares.iter().map(|&s| s as u64).sum();
        let mut r = (id as u64) % total;
        for (k, &s) in self.shares.iter().enumerate() {
            if r < s as u64 {
                return k as u16;
            }
            r -= s as u64;
        }
        unreachable!("residue exceeds total share")
    }

    /// Apply the mix to an existing trace in place.
    pub fn apply(&self, trace: &mut [Request]) {
        for r in trace {
            r.tenant = self.tag(r.id);
        }
    }
}

/// Deterministic prefix-lineage model: multi-turn session structure as the
/// router can see it.
///
/// Requests are grouped into `sessions` round-robin by id (a chat session /
/// system-prompt group). Each session carries a *chain* — the accumulated
/// conversation prefix — identified by a globally unique nonzero
/// [`Request::prefix`] id. A request is a *warm turn* with probability
/// `hit_prob` (matching the probabilistic `sched::RadixCache` hit rate):
/// it extends the session's live chain and shares
/// `frac ≈ mean_frac ± 0.15` of its prompt with the chain
/// ([`Request::shared_len`]). Otherwise it opens a fresh chain (topic
/// change / new conversation) with `shared_len = 0`.
///
/// All draws are pure functions of `(seed, id)` (splitmix-style hashing, no
/// RNG stream), so tagging never consumes the arrival/length RNG — arrival
/// times and token lengths are byte-identical to the untagged generators,
/// and the streaming/Vec twins stay in lockstep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixCfg {
    /// Concurrent sessions the id space is striped over.
    pub sessions: usize,
    /// Probability a request extends its session's chain (warm turn).
    pub hit_prob: f64,
    /// Mean fraction of the prompt shared with the chain on a warm turn.
    pub mean_frac: f64,
    /// Hash seed for the per-id draws (independent of the arrival seed).
    pub seed: u64,
}

impl Default for PrefixCfg {
    fn default() -> Self {
        PrefixCfg { sessions: 40, hit_prob: 0.5, mean_frac: 0.5, seed: 0x9e37 }
    }
}

impl PrefixCfg {
    /// Per-dataset prefix model matching the coordinator's radix hit-rate
    /// table (chat traffic reuses aggressively, arXiv summarization barely):
    /// single-engine `serve` runs (probabilistic `RadixCache`) and fleet
    /// `cluster` runs (deterministic lineage) share one prefix model.
    pub fn for_dataset(dataset: Dataset, seed: u64) -> Self {
        let (hit_prob, mean_frac) = match dataset {
            Dataset::ShareGpt => (0.5, 0.5),
            Dataset::Mixed => (0.4, 0.5),
            Dataset::LongData => (0.3, 0.4),
            Dataset::Arxiv => (0.2, 0.4),
        };
        PrefixCfg { sessions: 40, hit_prob, mean_frac, seed }
    }
}

/// splitmix64-style avalanche of `(seed, id)` to a uniform draw in [0, 1).
fn hash01(seed: u64, id: usize) -> f64 {
    let mut x = seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Stateful lineage assigner for [`PrefixCfg`]: tracks each session's live
/// chain and hands out globally unique chain ids. Deterministic — the k-th
/// call with the same `(id, plen)` sequence always produces the same tags.
#[derive(Debug, Clone)]
pub struct PrefixTagger {
    cfg: PrefixCfg,
    /// Live chain id per session (0 = none yet).
    chains: Vec<u32>,
    next_chain: u32,
}

impl PrefixTagger {
    pub fn new(cfg: &PrefixCfg) -> Self {
        assert!(cfg.sessions > 0, "prefix model needs at least one session");
        assert!((0.0..=1.0).contains(&cfg.hit_prob));
        assert!((0.0..=1.0).contains(&cfg.mean_frac));
        PrefixTagger { cfg: *cfg, chains: vec![0; cfg.sessions], next_chain: 0 }
    }

    /// Tag one request: returns `(prefix, shared_len)`.
    pub fn tag(&mut self, id: usize, plen: usize) -> (u32, u16) {
        let s = id % self.cfg.sessions;
        let warm = self.chains[s] != 0 && hash01(self.cfg.seed, id) < self.cfg.hit_prob;
        if warm {
            // Jitter the shared fraction exactly like RadixCache's draw:
            // mean_frac ± 0.15 uniform, clamped to [0.05, 0.95].
            let frac = (self.cfg.mean_frac + 0.3 * (hash01(self.cfg.seed ^ 0xA5A5, id) - 0.5))
                .clamp(0.05, 0.95);
            let shared = ((plen as f64 * frac) as usize)
                .min(plen.saturating_sub(1))
                .min(u16::MAX as usize);
            (self.chains[s], shared as u16)
        } else {
            self.next_chain += 1;
            self.chains[s] = self.next_chain;
            (self.next_chain, 0)
        }
    }

    /// Apply the lineage to an existing trace in place (ids must be in
    /// generation order for the chain state to match the generators).
    pub fn apply(&mut self, trace: &mut [Request]) {
        for r in trace {
            let (p, s) = self.tag(r.id, r.plen());
            r.prefix = p;
            r.shared_len = s;
        }
    }
}

/// Clamped log-normal token-length distribution, parameterized directly
/// from two published percentiles (median → `mu`, P95 → `sigma`).
#[derive(Debug, Clone, Copy)]
pub struct LenDist {
    pub mu: f64,
    pub sigma: f64,
    pub min: usize,
    pub max: usize,
}

/// z-score of the 95th percentile of the standard normal.
const Z95: f64 = 1.6448536269514722;

impl LenDist {
    /// Fit from (P50, P95): `median = e^mu`, `p95 = e^(mu + Z95·sigma)`.
    pub fn from_percentiles(p50: f64, p95: f64, min: usize, max: usize) -> Self {
        assert!(p95 > p50 && p50 > 0.0);
        let mu = p50.ln();
        let sigma = (p95.ln() - mu) / Z95;
        LenDist { mu, sigma, min, max }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.lognormal(self.mu, self.sigma);
        (x.round() as usize).clamp(self.min, self.max)
    }

    /// Analytical mean of the (unclamped) log-normal.
    pub fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }
}

/// The paper's three workloads (§6.1) plus the 60/40 Mixed composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// Long Data Collections: long prompts, moderate outputs (Qwen2.5-3B).
    LongData,
    /// ArXiv Summarization: long input / short output, stable lengths.
    Arxiv,
    /// ShareGPT: short interactive prompts, skewed outputs.
    ShareGpt,
    /// 60% ShareGPT + 40% Long Data Collections (Llama8B / Qwen14B).
    Mixed,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::LongData => "long-data-collections",
            Dataset::Arxiv => "arxiv-summarization",
            Dataset::ShareGpt => "sharegpt",
            Dataset::Mixed => "mixed",
        }
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name {
            "ldc" | "long-data-collections" | "longdata" => Some(Dataset::LongData),
            "arxiv" | "arxiv-summarization" => Some(Dataset::Arxiv),
            "sharegpt" => Some(Dataset::ShareGpt),
            "mixed" => Some(Dataset::Mixed),
            _ => None,
        }
    }

    /// (input, output) length distributions fit to Table 1.
    pub fn dists(&self) -> (LenDist, LenDist) {
        match self {
            // Table 1: In mean 5905 P50 5461 P95 9292 P99 9817
            //          Out mean 180 P50 159 P95 339 P99 454
            Dataset::LongData => (
                LenDist::from_percentiles(5461.0, 9292.0, 64, 10500),
                LenDist::from_percentiles(159.0, 339.0, 4, 512),
            ),
            // In mean 3832 P50 3575 P95 6460 P99 6894; Out mean 200 P50 181 P95 357 P99 443
            Dataset::Arxiv => (
                LenDist::from_percentiles(3575.0, 6460.0, 64, 7300),
                LenDist::from_percentiles(181.0, 357.0, 4, 480),
            ),
            // In mean 496 P50 432 P95 970 P99 1367; Out mean 97 P50 37 P95 383 P99 474
            Dataset::ShareGpt => (
                LenDist::from_percentiles(432.0, 970.0, 8, 1500),
                LenDist::from_percentiles(37.0, 383.0, 1, 520),
            ),
            Dataset::Mixed => unreachable!("Mixed samples its components"),
        }
    }

    /// Sample one (prompt_len, output_len) pair.
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        match self {
            Dataset::Mixed => {
                // 60% ShareGPT + 40% Long Data Collections (§6.1).
                if rng.chance(0.6) {
                    Dataset::ShareGpt.sample(rng)
                } else {
                    Dataset::LongData.sample(rng)
                }
            }
            _ => {
                let (di, do_) = self.dists();
                (di.sample(rng), do_.sample(rng))
            }
        }
    }
}

/// Streaming variant of [`generate`]: lazily yields `n` requests with
/// Poisson arrivals at `rate` req/s, never materializing the trace. The RNG
/// stream (one arrival draw, then one length sample, per request) is
/// consumed in exactly [`generate`]'s order, so collecting this iterator is
/// byte-identical to the Vec version for the same seed.
pub fn generate_iter(
    dataset: Dataset,
    n: usize,
    rate: f64,
    seed: u64,
) -> impl Iterator<Item = Request> {
    assert!(rate > 0.0);
    let mut rng = Rng::new(seed);
    let mut lens_rng = rng.fork();
    let mut t = 0.0;
    (0..n).map(move |id| {
        t += rng.exponential(rate);
        let (prompt_len, output_len) = dataset.sample(&mut lens_rng);
        Request {
            id,
            arrival: t,
            prompt_len: prompt_len as u32,
            output_len: output_len as u32,
            tenant: 0,
            prefix: 0,
            shared_len: 0,
        }
    })
}

/// Generate `n` requests with Poisson arrivals at `rate` req/s.
pub fn generate(dataset: Dataset, n: usize, rate: f64, seed: u64) -> Vec<Request> {
    generate_iter(dataset, n, rate, seed).collect()
}

/// [`generate_iter`] with tenant labels from a [`TenantMix`]. Tagging is a
/// pure function of the request id, so the underlying RNG stream — and
/// therefore every arrival time and length — is identical to the untagged
/// generator for the same seed.
pub fn generate_iter_with_tenants(
    dataset: Dataset,
    n: usize,
    rate: f64,
    seed: u64,
    mix: &TenantMix,
) -> impl Iterator<Item = Request> {
    let mix = mix.clone();
    generate_iter(dataset, n, rate, seed).map(move |mut r| {
        r.tenant = mix.tag(r.id);
        r
    })
}

/// [`generate`] with tenant labels from a [`TenantMix`].
pub fn generate_with_tenants(
    dataset: Dataset,
    n: usize,
    rate: f64,
    seed: u64,
    mix: &TenantMix,
) -> Vec<Request> {
    generate_iter_with_tenants(dataset, n, rate, seed, mix).collect()
}

/// Bursty/diurnal arrival process: a Gamma-modulated Poisson rate under a
/// sinusoidal diurnal envelope (a doubly-stochastic Cox process).
///
/// The instantaneous rate is piecewise-constant over `epoch`-second
/// windows: `rate(t) = base_rate · (1 + diurnal_amp·sin(2πt/diurnal_period))
/// · G_e`, where each epoch draws an independent burst factor
/// `G_e ~ Gamma(burst_shape, 1/burst_shape)` (mean 1). Lower `burst_shape`
/// means heavier bursts; `burst_shape → ∞` recovers plain [`generate`]
/// modulo the envelope.
#[derive(Debug, Clone, Copy)]
pub struct BurstyCfg {
    /// Long-run mean arrival rate (req/s).
    pub base_rate: f64,
    /// Gamma shape `k` of the per-epoch burst factor (mean-1, var `1/k`).
    pub burst_shape: f64,
    /// Seconds per burst-factor resample.
    pub epoch: f64,
    /// Diurnal amplitude ∈ [0, 1).
    pub diurnal_amp: f64,
    /// Seconds per diurnal cycle.
    pub diurnal_period: f64,
}

impl Default for BurstyCfg {
    fn default() -> Self {
        BurstyCfg {
            base_rate: 4.0,
            burst_shape: 0.5,
            epoch: 20.0,
            diurnal_amp: 0.6,
            diurnal_period: 600.0,
        }
    }
}

/// Streaming bursty/diurnal arrival generator — see [`generate_bursty_iter`].
#[derive(Debug, Clone)]
pub struct BurstyIter {
    dataset: Dataset,
    cfg: BurstyCfg,
    rng: Rng,
    lens_rng: Rng,
    n: usize,
    count: usize,
    epoch_start: f64,
    rate: f64,
    t: f64,
    /// Whether the current epoch's burst factor has been drawn.
    epoch_open: bool,
}

impl Iterator for BurstyIter {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.count >= self.n {
            return None;
        }
        loop {
            if !self.epoch_open {
                let mid = self.epoch_start + 0.5 * self.cfg.epoch;
                let envelope = 1.0
                    + self.cfg.diurnal_amp
                        * (2.0 * std::f64::consts::PI * mid / self.cfg.diurnal_period).sin();
                let factor = self.rng.gamma(self.cfg.burst_shape, 1.0 / self.cfg.burst_shape);
                self.rate = (self.cfg.base_rate * envelope * factor).max(1e-3);
                self.t = self.epoch_start;
                self.epoch_open = true;
            }
            self.t += self.rng.exponential(self.rate);
            if self.t >= self.epoch_start + self.cfg.epoch {
                self.epoch_start += self.cfg.epoch;
                self.epoch_open = false;
                continue;
            }
            let (prompt_len, output_len) = self.dataset.sample(&mut self.lens_rng);
            let id = self.count;
            self.count += 1;
            return Some(Request {
                id,
                arrival: self.t,
                prompt_len: prompt_len as u32,
                output_len: output_len as u32,
                tenant: 0,
                prefix: 0,
                shared_len: 0,
            });
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.n - self.count;
        (left, Some(left))
    }
}

/// Streaming variant of [`generate_bursty`]: lazily yields `n` requests from
/// the Cox process without materializing the trace. Draws the epoch burst
/// factor, inter-arrival exponentials, and length samples in exactly
/// [`generate_bursty`]'s order, so collecting it reproduces the Vec version
/// byte-for-byte. (The Vec version consumes one trailing inter-arrival draw
/// after its `n`-th request; the iterator simply stops — the yielded
/// sequence is identical.)
pub fn generate_bursty_iter(dataset: Dataset, n: usize, cfg: &BurstyCfg, seed: u64) -> BurstyIter {
    assert!(cfg.base_rate > 0.0 && cfg.epoch > 0.0 && cfg.burst_shape > 0.0);
    assert!((0.0..1.0).contains(&cfg.diurnal_amp));
    let mut rng = Rng::new(seed);
    let lens_rng = rng.fork();
    BurstyIter {
        dataset,
        cfg: *cfg,
        rng,
        lens_rng,
        n,
        count: 0,
        epoch_start: 0.0,
        rate: 0.0,
        t: 0.0,
        epoch_open: false,
    }
}

/// Generate `n` requests from the bursty/diurnal process (see [`BurstyCfg`]).
pub fn generate_bursty(dataset: Dataset, n: usize, cfg: &BurstyCfg, seed: u64) -> Vec<Request> {
    generate_bursty_iter(dataset, n, cfg, seed).collect()
}

/// [`generate_bursty_iter`] with tenant labels from a [`TenantMix`] — the
/// Cox-process RNG stream is untouched (tagging is a pure function of id).
pub fn generate_bursty_iter_with_tenants(
    dataset: Dataset,
    n: usize,
    cfg: &BurstyCfg,
    seed: u64,
    mix: &TenantMix,
) -> impl Iterator<Item = Request> {
    let mix = mix.clone();
    generate_bursty_iter(dataset, n, cfg, seed).map(move |mut r| {
        r.tenant = mix.tag(r.id);
        r
    })
}

/// [`generate_bursty`] with tenant labels from a [`TenantMix`].
pub fn generate_bursty_with_tenants(
    dataset: Dataset,
    n: usize,
    cfg: &BurstyCfg,
    seed: u64,
    mix: &TenantMix,
) -> Vec<Request> {
    generate_bursty_iter_with_tenants(dataset, n, cfg, seed, mix).collect()
}

/// [`generate_iter`] with deterministic prefix lineage from a [`PrefixCfg`].
/// The tagger draws from `(cfg.seed, id)` hashes only — the arrival/length
/// RNG stream is untouched, so everything but the lineage labels is
/// identical to the untagged generator for the same seed.
pub fn generate_iter_with_prefixes(
    dataset: Dataset,
    n: usize,
    rate: f64,
    seed: u64,
    cfg: &PrefixCfg,
) -> impl Iterator<Item = Request> {
    let mut tagger = PrefixTagger::new(cfg);
    generate_iter(dataset, n, rate, seed).map(move |mut r| {
        let (p, s) = tagger.tag(r.id, r.plen());
        r.prefix = p;
        r.shared_len = s;
        r
    })
}

/// [`generate`] with deterministic prefix lineage from a [`PrefixCfg`].
pub fn generate_with_prefixes(
    dataset: Dataset,
    n: usize,
    rate: f64,
    seed: u64,
    cfg: &PrefixCfg,
) -> Vec<Request> {
    generate_iter_with_prefixes(dataset, n, rate, seed, cfg).collect()
}

/// [`generate_bursty_iter`] with prefix lineage — the Cox-process RNG stream
/// is untouched (lineage draws are pure `(seed, id)` hashes).
pub fn generate_bursty_iter_with_prefixes(
    dataset: Dataset,
    n: usize,
    cfg: &BurstyCfg,
    seed: u64,
    prefix: &PrefixCfg,
) -> impl Iterator<Item = Request> {
    let mut tagger = PrefixTagger::new(prefix);
    generate_bursty_iter(dataset, n, cfg, seed).map(move |mut r| {
        let (p, s) = tagger.tag(r.id, r.plen());
        r.prefix = p;
        r.shared_len = s;
        r
    })
}

/// [`generate_bursty`] with prefix lineage from a [`PrefixCfg`].
pub fn generate_bursty_with_prefixes(
    dataset: Dataset,
    n: usize,
    cfg: &BurstyCfg,
    seed: u64,
    prefix: &PrefixCfg,
) -> Vec<Request> {
    generate_bursty_iter_with_prefixes(dataset, n, cfg, seed, prefix).collect()
}

/// Generate an *offline* batch: all `n` requests arrive at t=0 (§6.3).
pub fn offline(dataset: Dataset, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| {
            let (prompt_len, output_len) = dataset.sample(&mut rng);
            Request {
                id,
                arrival: 0.0,
                prompt_len: prompt_len as u32,
                output_len: output_len as u32,
                tenant: 0,
                prefix: 0,
                shared_len: 0,
            }
        })
        .collect()
}

/// Summary statistics in Table-1 layout: (mean, P50, P95, P99).
pub fn length_stats(lens: &[usize]) -> (f64, f64, f64, f64) {
    let xs: Vec<f64> = lens.iter().map(|&x| x as f64).collect();
    (
        mean(&xs),
        percentile(&xs, 50.0),
        percentile(&xs, 95.0),
        percentile(&xs, 99.0),
    )
}

/// Serialize a trace to JSON (for replay / cross-engine comparisons).
pub fn trace_to_json(trace: &[Request]) -> Json {
    Json::Arr(
        trace
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("arrival", Json::Num(r.arrival)),
                    ("prompt_len", Json::Num(r.prompt_len as f64)),
                    ("output_len", Json::Num(r.output_len as f64)),
                    ("tenant", Json::Num(r.tenant as f64)),
                    ("prefix", Json::Num(r.prefix as f64)),
                    ("shared_len", Json::Num(r.shared_len as f64)),
                ])
            })
            .collect(),
    )
}

/// Parse a trace back from [`trace_to_json`] output.
pub fn trace_from_json(j: &Json) -> Result<Vec<Request>, String> {
    let arr = j.as_arr().ok_or("trace must be a JSON array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let field = |k: &str| -> Result<f64, String> {
            item.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("trace[{i}]: missing numeric '{k}'"))
        };
        out.push(Request {
            id: field("id")? as usize,
            arrival: field("arrival")?,
            prompt_len: field("prompt_len")? as u32,
            output_len: (field("output_len")? as u32).max(1),
            // Pre-tenant/pre-prefix traces omit the fields; default to 0.
            tenant: item.get("tenant").and_then(Json::as_f64).unwrap_or(0.0) as u16,
            prefix: item.get("prefix").and_then(Json::as_f64).unwrap_or(0.0) as u32,
            shared_len: item.get("shared_len").and_then(Json::as_f64).unwrap_or(0.0) as u16,
        });
    }
    Ok(out)
}

/// Paper Table 1 reference rows for the bench harness: dataset →
/// (in_mean, in_p50, in_p95, in_p99, out_mean, out_p50, out_p95, out_p99).
pub fn table1_reference() -> BTreeMap<&'static str, [f64; 8]> {
    let mut m = BTreeMap::new();
    m.insert(
        "long-data-collections",
        [5905.0, 5461.0, 9292.0, 9817.0, 180.0, 159.0, 339.0, 454.0],
    );
    m.insert(
        "arxiv-summarization",
        [3832.0, 3575.0, 6460.0, 6894.0, 200.0, 181.0, 357.0, 443.0],
    );
    m.insert("sharegpt", [496.0, 432.0, 970.0, 1367.0, 97.0, 37.0, 383.0, 474.0]);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_poisson() {
        let tr = generate(Dataset::ShareGpt, 500, 2.5, 42);
        assert_eq!(tr.len(), 500);
        for w in tr.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
        // Mean inter-arrival ≈ 1/rate within 15%.
        let span = tr.last().unwrap().arrival - tr[0].arrival;
        let mean_gap = span / 499.0;
        assert!((mean_gap - 0.4).abs() < 0.06, "mean gap {mean_gap}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(Dataset::Mixed, 100, 1.0, 7);
        let b = generate(Dataset::Mixed, 100, 1.0, 7);
        assert_eq!(a, b);
        let c = generate(Dataset::Mixed, 100, 1.0, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn table1_percentiles_match_within_tolerance() {
        // Generated length stats must land near the paper's Table 1 rows.
        for (ds, want) in [
            (Dataset::LongData, table1_reference()["long-data-collections"]),
            (Dataset::Arxiv, table1_reference()["arxiv-summarization"]),
            (Dataset::ShareGpt, table1_reference()["sharegpt"]),
        ] {
            let tr = generate(ds, 4000, 1.0, 123);
            let ins: Vec<usize> = tr.iter().map(|r| r.plen()).collect();
            let outs: Vec<usize> = tr.iter().map(|r| r.olen()).collect();
            let (im, i50, i95, _) = length_stats(&ins);
            let (om, o50, o95, _) = length_stats(&outs);
            for (got, exp, what) in [
                (im, want[0], "in mean"),
                (i50, want[1], "in p50"),
                (i95, want[2], "in p95"),
                (om, want[4], "out mean"),
                (o50, want[5], "out p50"),
                (o95, want[6], "out p95"),
            ] {
                let rel = (got - exp).abs() / exp;
                assert!(rel < 0.22, "{}: {what} got {got:.0} want {exp:.0}", ds.name());
            }
        }
    }

    #[test]
    fn mixed_is_bimodal() {
        let tr = generate(Dataset::Mixed, 3000, 1.0, 99);
        let short = tr.iter().filter(|r| r.prompt_len < 2000).count();
        let long = tr.iter().filter(|r| r.prompt_len >= 2000).count();
        let frac_short = short as f64 / tr.len() as f64;
        assert!((frac_short - 0.6).abs() < 0.06, "short frac {frac_short}");
        assert!(long > 0);
    }

    #[test]
    fn bursty_is_monotone_deterministic_and_complete() {
        let cfg = BurstyCfg::default();
        let tr = generate_bursty(Dataset::ShareGpt, 400, &cfg, 11);
        assert_eq!(tr.len(), 400);
        for (i, w) in tr.windows(2).enumerate() {
            assert!(w[1].arrival >= w[0].arrival, "order broken at {i}");
        }
        for (i, r) in tr.iter().enumerate() {
            assert_eq!(r.id, i, "ids must be dense and ordered");
        }
        let again = generate_bursty(Dataset::ShareGpt, 400, &cfg, 11);
        assert_eq!(tr, again);
        assert_ne!(tr, generate_bursty(Dataset::ShareGpt, 400, &cfg, 12));
    }

    #[test]
    fn streaming_iterators_match_vec_generators() {
        // The Vec generators are thin collectors over the iterators, but pin
        // the equivalence explicitly (and lazily: no full materialization is
        // needed to take a prefix).
        let v = generate(Dataset::Mixed, 200, 3.0, 77);
        let it: Vec<Request> = generate_iter(Dataset::Mixed, 200, 3.0, 77).collect();
        assert_eq!(v, it);
        let cfg = BurstyCfg::default();
        let vb = generate_bursty(Dataset::ShareGpt, 300, &cfg, 19);
        let itb: Vec<Request> = generate_bursty_iter(Dataset::ShareGpt, 300, &cfg, 19).collect();
        assert_eq!(vb, itb);
        // A prefix of the stream equals a prefix of the Vec (same RNG path).
        let prefix: Vec<Request> =
            generate_bursty_iter(Dataset::ShareGpt, 300, &cfg, 19).take(50).collect();
        assert_eq!(&vb[..50], &prefix[..]);
        let (lo, hi) = generate_bursty_iter(Dataset::ShareGpt, 300, &cfg, 19).size_hint();
        assert_eq!((lo, hi), (300, Some(300)));
    }

    #[test]
    fn request_hot_state_is_compact() {
        // §Perf hot-state audit: 32 bytes per request (24 B of core fields +
        // u16 tenant + u32 prefix chain + u16 shared length — exactly the
        // f64-aligned padding the tenant label left free). A regression
        // here silently bloats every engine queue.
        assert!(std::mem::size_of::<Request>() <= 32);
    }

    #[test]
    fn tenant_mix_shares_are_exact_per_block() {
        let mix = TenantMix::new(vec![3, 1]);
        // Every window of sum(shares)=4 consecutive ids carries the exact mix.
        let tags: Vec<u16> = (0..8).map(|id| mix.tag(id)).collect();
        assert_eq!(tags, vec![0, 0, 0, 1, 0, 0, 0, 1]);
        let uni = TenantMix::uniform(3);
        assert_eq!(uni.tenants(), 3);
        let tags: Vec<u16> = (0..6).map(|id| uni.tag(id)).collect();
        assert_eq!(tags, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn tenant_tagging_leaves_arrivals_and_lengths_untouched() {
        // Tagging is a pure function of id: the tagged generators reuse the
        // untagged RNG stream, so everything but the label is identical.
        let mix = TenantMix::new(vec![2, 1, 1]);
        let plain = generate(Dataset::Mixed, 120, 3.0, 77);
        let tagged = generate_with_tenants(Dataset::Mixed, 120, 3.0, 77, &mix);
        assert_eq!(plain.len(), tagged.len());
        for (a, b) in plain.iter().zip(&tagged) {
            assert_eq!((a.id, a.prompt_len, a.output_len), (b.id, b.prompt_len, b.output_len));
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(b.tenant, mix.tag(b.id));
        }
        let cfg = BurstyCfg::default();
        let plain_b = generate_bursty(Dataset::ShareGpt, 150, &cfg, 19);
        let tagged_b = generate_bursty_with_tenants(Dataset::ShareGpt, 150, &cfg, 19, &mix);
        for (a, b) in plain_b.iter().zip(&tagged_b) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(b.tenant, mix.tag(b.id));
        }
        // Streaming twins match the Vec versions.
        let it: Vec<Request> =
            generate_iter_with_tenants(Dataset::Mixed, 120, 3.0, 77, &mix).collect();
        assert_eq!(tagged, it);
        let itb: Vec<Request> =
            generate_bursty_iter_with_tenants(Dataset::ShareGpt, 150, &cfg, 19, &mix).collect();
        assert_eq!(tagged_b, itb);
    }

    #[test]
    fn prefix_tagging_leaves_arrivals_and_lengths_untouched() {
        // Lineage draws are pure (seed, id) hashes: the tagged generators
        // reuse the untagged RNG stream, so everything but the lineage
        // labels is identical — arrivals, lengths, ids, tenants.
        let pc = PrefixCfg::for_dataset(Dataset::ShareGpt, 13);
        let plain = generate(Dataset::ShareGpt, 200, 5.0, 77);
        let tagged = generate_with_prefixes(Dataset::ShareGpt, 200, 5.0, 77, &pc);
        assert_eq!(plain.len(), tagged.len());
        for (a, b) in plain.iter().zip(&tagged) {
            assert_eq!((a.id, a.prompt_len, a.output_len, a.tenant), (b.id, b.prompt_len, b.output_len, b.tenant));
            assert_eq!(a.arrival, b.arrival);
        }
        let cfg = BurstyCfg::default();
        let plain_b = generate_bursty(Dataset::ShareGpt, 150, &cfg, 19);
        let tagged_b = generate_bursty_with_prefixes(Dataset::ShareGpt, 150, &cfg, 19, &pc);
        for (a, b) in plain_b.iter().zip(&tagged_b) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
        }
        // Streaming twins match the Vec versions (stateful tagger included).
        let it: Vec<Request> =
            generate_iter_with_prefixes(Dataset::ShareGpt, 200, 5.0, 77, &pc).collect();
        assert_eq!(tagged, it);
        let itb: Vec<Request> =
            generate_bursty_iter_with_prefixes(Dataset::ShareGpt, 150, &cfg, 19, &pc).collect();
        assert_eq!(tagged_b, itb);
        // PrefixTagger::apply over the plain trace reproduces the generator.
        let mut applied = plain.clone();
        PrefixTagger::new(&pc).apply(&mut applied);
        assert_eq!(applied, tagged);
    }

    #[test]
    fn prefix_lineage_is_well_formed() {
        let pc = PrefixCfg { sessions: 8, hit_prob: 0.7, mean_frac: 0.6, seed: 42 };
        let tr = generate_with_prefixes(Dataset::ShareGpt, 400, 5.0, 3, &pc);
        let mut last_chain = vec![0u32; pc.sessions];
        let mut warm = 0usize;
        for r in &tr {
            assert_ne!(r.prefix, 0, "every request belongs to a chain");
            assert!(
                (r.shared_len as usize) < r.plen(),
                "shared prefix must leave novel tokens (req {})",
                r.id
            );
            let s = r.id % pc.sessions;
            if r.shared_len > 0 {
                // Warm turns extend the session's live chain.
                assert_eq!(r.prefix, last_chain[s], "warm turn switched chains (req {})", r.id);
                warm += 1;
            }
            last_chain[s] = r.prefix;
        }
        // Warm fraction tracks hit_prob loosely (first turns are always cold).
        let frac = warm as f64 / tr.len() as f64;
        assert!((frac - pc.hit_prob).abs() < 0.15, "warm fraction {frac}");
        // Deterministic: same cfg, same tags.
        assert_eq!(tr, generate_with_prefixes(Dataset::ShareGpt, 400, 5.0, 3, &pc));
    }

    #[test]
    fn uniform_single_tenant_mix_is_the_untagged_trace() {
        // Pay-for-what-you-use: one tenant with any share leaves every
        // request labeled 0 — exactly the untagged generator's output.
        let mix = TenantMix::uniform(1);
        let plain = generate(Dataset::ShareGpt, 60, 4.0, 5);
        let tagged = generate_with_tenants(Dataset::ShareGpt, 60, 4.0, 5, &mix);
        assert_eq!(plain, tagged);
    }

    #[test]
    fn tenant_spec_default_is_permissive() {
        let s = TenantSpec::default();
        assert_eq!(s.weight, 1.0);
        assert_eq!(s.admission_quota, usize::MAX);
        assert!(s.ttft_slo > 0.0 && s.tbt_slo > 0.0);
    }

    #[test]
    fn bursty_is_overdispersed_vs_poisson() {
        // Index of dispersion of per-window counts: 1 for Poisson, ≫ 1 for
        // the Gamma-modulated process with a small shape.
        let cfg = BurstyCfg {
            base_rate: 4.0,
            burst_shape: 0.3,
            epoch: 10.0,
            diurnal_amp: 0.0, // isolate the burst modulation
            diurnal_period: 600.0,
        };
        let dispersion = |tr: &[Request], window: f64| -> f64 {
            let horizon = tr.last().unwrap().arrival;
            let bins = (horizon / window).ceil() as usize;
            let mut counts = vec![0.0f64; bins.max(1)];
            for r in tr {
                let b = ((r.arrival / window) as usize).min(counts.len() - 1);
                counts[b] += 1.0;
            }
            let m = mean(&counts);
            let var =
                counts.iter().map(|c| (c - m) * (c - m)).sum::<f64>() / counts.len() as f64;
            var / m.max(1e-9)
        };
        let bursty = generate_bursty(Dataset::ShareGpt, 2000, &cfg, 7);
        let poisson = generate(Dataset::ShareGpt, 2000, 4.0, 7);
        let db = dispersion(&bursty, cfg.epoch);
        let dp = dispersion(&poisson, cfg.epoch);
        assert!(db > 2.0, "bursty dispersion {db} should be ≫ 1");
        assert!(db > 2.0 * dp, "bursty {db} must exceed Poisson {dp}");
    }

    #[test]
    fn diurnal_envelope_shifts_load_across_phases() {
        // With a strong envelope and mild bursts, the sin-peak half of each
        // cycle must carry clearly more arrivals than the trough half.
        let cfg = BurstyCfg {
            base_rate: 4.0,
            burst_shape: 50.0, // nearly deterministic epochs
            epoch: 5.0,
            diurnal_amp: 0.9,
            diurnal_period: 200.0,
        };
        let tr = generate_bursty(Dataset::ShareGpt, 3000, &cfg, 5);
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in &tr {
            let phase = (r.arrival / cfg.diurnal_period).fract();
            if phase < 0.5 {
                peak += 1; // sin ≥ 0 half-cycle
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} vs trough {trough} under amp 0.9"
        );
    }

    #[test]
    fn offline_all_arrive_at_zero() {
        let tr = offline(Dataset::LongData, 50, 1);
        assert!(tr.iter().all(|r| r.arrival == 0.0));
        assert_eq!(tr.len(), 50);
    }

    #[test]
    fn trace_json_roundtrip() {
        let mix = TenantMix::new(vec![1, 2]);
        let mut tr = generate_with_tenants(Dataset::Arxiv, 20, 3.0, 5, &mix);
        PrefixTagger::new(&PrefixCfg::default()).apply(&mut tr);
        let j = trace_to_json(&tr);
        let back = trace_from_json(&j).unwrap();
        assert_eq!(tr.len(), back.len());
        for (a, b) in tr.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!((a.prefix, a.shared_len), (b.prefix, b.shared_len));
            assert!((a.arrival - b.arrival).abs() < 1e-9);
        }
        // Pre-tenant/pre-prefix traces (no such keys) parse with zeros.
        let legacy = Json::parse(
            r#"[{"id": 3, "arrival": 0.5, "prompt_len": 64, "output_len": 8}]"#,
        )
        .unwrap();
        let parsed = trace_from_json(&legacy).unwrap();
        assert_eq!(parsed[0].tenant, 0);
        assert_eq!((parsed[0].prefix, parsed[0].shared_len), (0, 0));
    }

    #[test]
    fn by_name_roundtrip() {
        for d in [Dataset::LongData, Dataset::Arxiv, Dataset::ShareGpt, Dataset::Mixed] {
            assert_eq!(Dataset::by_name(d.name()), Some(d));
        }
        assert!(Dataset::by_name("wikitext").is_none());
    }

    #[test]
    fn lendist_clamps() {
        let d = LenDist::from_percentiles(100.0, 500.0, 50, 200);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = d.sample(&mut rng);
            assert!((50..=200).contains(&x));
        }
    }
}
