//! PJRT runtime — the real-compute path.
//!
//! Loads the artifacts that `make artifacts` produced (Layer 2 JAX model +
//! Layer 1 Pallas kernels, AOT-lowered to **HLO text** — the image's
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos), compiles them on
//! the PJRT CPU client, and exposes a typed prefill/decode API to the
//! serving layer. Python never runs here: the artifacts directory is
//! self-contained (`manifest.json` + `*.hlo.txt` + `weights.bin`).
//!
//! Entry signatures (shapes fixed at AOT time, see `python/compile/aot.py`):
//!
//! * `prefill(w…, tokens i32[P], len i32[])` → `(logits f32[V], kv f32[L,2,C,KVD])`
//! * `decode(w…, tokens i32[B], pos i32[B], kv f32[B,L,2,C,KVD])`
//!   → `(logits f32[B,V], kv f32[B,L,2,C,KVD])`
//!
//! Weights are uploaded to device once at load and reused across calls
//! (`execute_b` with persistent `PjRtBuffer`s).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Architecture + AOT shape parameters recorded in `manifest.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TinyDims {
    pub layers: usize,
    pub d: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// Max prompt tokens the prefill entry accepts (padded).
    pub max_prompt: usize,
    /// Per-request KV capacity (tokens) baked into the decode entry.
    pub kv_cap: usize,
    /// Decode batch width baked into the decode entry.
    pub decode_batch: usize,
}

impl TinyDims {
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * (self.d / self.heads)
    }

    /// f32 elements of one request's KV cache: `[L, 2, C, KVD]`.
    pub fn kv_elems(&self) -> usize {
        self.layers * 2 * self.kv_cap * self.kv_dim()
    }

    /// f32 elements of the batched decode KV: `[B, L, 2, C, KVD]`.
    pub fn batch_kv_elems(&self) -> usize {
        self.decode_batch * self.kv_elems()
    }
}

/// One weight tensor's manifest entry.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: TinyDims,
    pub weights_file: String,
    pub tensors: Vec<TensorSpec>,
    pub prefill_hlo: String,
    pub decode_hlo: String,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e:?}"))?;
        let num = |node: &Json, k: &str| -> Result<usize> {
            node.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest: missing numeric '{k}'"))
        };
        let model = j.get("model").ok_or_else(|| anyhow!("manifest: missing 'model'"))?;
        let dims = TinyDims {
            layers: num(model, "layers")?,
            d: num(model, "d")?,
            heads: num(model, "heads")?,
            kv_heads: num(model, "kv_heads")?,
            d_ff: num(model, "d_ff")?,
            vocab: num(model, "vocab")?,
            max_prompt: num(model, "max_prompt")?,
            kv_cap: num(model, "kv_cap")?,
            decode_batch: num(model, "decode_batch")?,
        };
        let weights = j.get("weights").ok_or_else(|| anyhow!("manifest: missing 'weights'"))?;
        let weights_file = weights
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest: weights.file"))?
            .to_string();
        let mut tensors = Vec::new();
        for t in weights
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: weights.tensors"))?
        {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest: tensor name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest: tensor shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("manifest: bad dim")))
                .collect::<Result<Vec<usize>>>()?;
            tensors.push(TensorSpec { name, shape });
        }
        let mut prefill_hlo = String::new();
        let mut decode_hlo = String::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'entries'"))?
        {
            let name = e.get("name").and_then(Json::as_str).unwrap_or("");
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest: entry file"))?;
            match name {
                "prefill" => prefill_hlo = file.to_string(),
                "decode" => decode_hlo = file.to_string(),
                other => bail!("manifest: unknown entry '{other}'"),
            }
        }
        if prefill_hlo.is_empty() || decode_hlo.is_empty() {
            bail!("manifest: need both 'prefill' and 'decode' entries");
        }
        Ok(Manifest { dims, weights_file, tensors, prefill_hlo, decode_hlo })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn total_weight_elems(&self) -> usize {
        self.tensors.iter().map(TensorSpec::elems).sum()
    }
}

/// Result of one prefill call.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// Next-token logits for the last real prompt token: `[vocab]`.
    pub logits: Vec<f32>,
    /// Populated per-request KV cache: `[L, 2, C, KVD]` flattened.
    pub kv: Vec<f32>,
}

/// The compiled model: PJRT client + executables + device-resident weights.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dims: TinyDims,
    pub dir: PathBuf,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

/// Read `weights.bin`: little-endian f32, tensors concatenated in manifest
/// order.
pub fn read_weights(path: &Path, expect_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect_elems * 4 {
        bail!(
            "{}: expected {} f32 ({} bytes), found {} bytes",
            path.display(),
            expect_elems,
            expect_elems * 4,
            bytes.len()
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Runtime {
    /// Load + compile every artifact under `dir` and upload the weights.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile(&manifest.prefill_hlo)?;
        let decode_exe = compile(&manifest.decode_hlo)?;

        let flat = read_weights(&dir.join(&manifest.weights_file), manifest.total_weight_elems())?;
        let mut weight_bufs = Vec::with_capacity(manifest.tensors.len());
        let mut off = 0usize;
        for t in &manifest.tensors {
            let n = t.elems();
            let buf = client.buffer_from_host_buffer(&flat[off..off + n], &t.shape, None)?;
            weight_bufs.push(buf);
            off += n;
        }

        Ok(Runtime {
            client,
            dims: manifest.dims,
            dir: dir.to_path_buf(),
            prefill_exe,
            decode_exe,
            weight_bufs,
        })
    }

    /// Default artifacts directory: `$NEXUS_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("NEXUS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from("artifacts")
        })
    }

    /// Run the prefill entry on a prompt (≤ `max_prompt` tokens).
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let d = &self.dims;
        if tokens.is_empty() || tokens.len() > d.max_prompt {
            bail!("prefill: prompt length {} not in 1..={}", tokens.len(), d.max_prompt);
        }
        let mut padded = vec![0i32; d.max_prompt];
        padded[..tokens.len()].copy_from_slice(tokens);
        let len = [tokens.len() as i32];

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        let tok_buf = self.client.buffer_from_host_buffer(&padded, &[d.max_prompt], None)?;
        let len_buf = self.client.buffer_from_host_buffer(&len, &[], None)?;
        args.push(&tok_buf);
        args.push(&len_buf);

        let out = self.decode_tuple(&self.prefill_exe, &args)?;
        let (logits_l, kv_l) = match out.len() {
            2 => (&out[0], &out[1]),
            n => bail!("prefill: expected 2 outputs, got {n}"),
        };
        Ok(PrefillOut { logits: logits_l.to_vec::<f32>()?, kv: kv_l.to_vec::<f32>()? })
    }

    /// Run one batched decode step.
    ///
    /// `tokens`/`pos` are `[B]`; `kv` is the flattened `[B, L, 2, C, KVD]`
    /// state, updated in place. Returns `[B, vocab]` logits (flattened).
    pub fn decode(&self, tokens: &[i32], pos: &[i32], kv: &mut Vec<f32>) -> Result<Vec<f32>> {
        let d = &self.dims;
        if tokens.len() != d.decode_batch || pos.len() != d.decode_batch {
            bail!("decode: batch must be exactly {}", d.decode_batch);
        }
        if kv.len() != d.batch_kv_elems() {
            bail!("decode: kv has {} elems, want {}", kv.len(), d.batch_kv_elems());
        }
        let kvd = d.kv_dim();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[d.decode_batch], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(pos, &[d.decode_batch], None)?;
        let kv_buf = self.client.buffer_from_host_buffer(
            kv.as_slice(),
            &[d.decode_batch, d.layers, 2, d.kv_cap, kvd],
            None,
        )?;
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&kv_buf);

        let out = self.decode_tuple(&self.decode_exe, &args)?;
        let (logits_l, kv_l) = match out.len() {
            2 => (&out[0], &out[1]),
            n => bail!("decode: expected 2 outputs, got {n}"),
        };
        *kv = kv_l.to_vec::<f32>()?;
        Ok(logits_l.to_vec::<f32>()?)
    }

    /// Execute and unpack the 1-tuple-of-N output convention
    /// (`return_tuple=True` at lowering time).
    fn decode_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let res = exe.execute_b(args)?;
        let lit = res
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| anyhow!("execute returned no outputs"))?
            .to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Greedy (argmax) sampling from a logits row.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let text = r#"{
            "model": {"layers": 4, "d": 256, "heads": 4, "kv_heads": 4,
                      "d_ff": 1024, "vocab": 512, "max_prompt": 128,
                      "kv_cap": 192, "decode_batch": 4},
            "weights": {"file": "weights.bin",
                        "tensors": [{"name": "embed", "shape": [512, 256]},
                                    {"name": "w1", "shape": [256, 1024]}]},
            "entries": [{"name": "prefill", "file": "prefill.hlo.txt"},
                        {"name": "decode", "file": "decode.hlo.txt"}]
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.dims.layers, 4);
        assert_eq!(m.dims.kv_dim(), 256);
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.total_weight_elems(), 512 * 256 + 256 * 1024);
        assert_eq!(m.prefill_hlo, "prefill.hlo.txt");
        assert_eq!(m.dims.kv_elems(), 4 * 2 * 192 * 256);
        assert_eq!(m.dims.batch_kv_elems(), 4 * 4 * 2 * 192 * 256);
    }

    #[test]
    fn manifest_rejects_missing_entries() {
        assert!(Manifest::parse("{}").is_err());
        let no_decode = r#"{
            "model": {"layers":1,"d":8,"heads":1,"kv_heads":1,"d_ff":16,
                      "vocab":32,"max_prompt":8,"kv_cap":8,"decode_batch":1},
            "weights": {"file": "w.bin", "tensors": []},
            "entries": [{"name": "prefill", "file": "p.txt"}]
        }"#;
        assert!(Manifest::parse(no_decode).is_err());
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(Runtime::argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(Runtime::argmax(&[3.0]), 0);
    }

    #[test]
    fn read_weights_validates_size() {
        let dir = std::env::temp_dir().join("nexus_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        let floats: Vec<f32> = vec![1.0, -2.5, 3.25];
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&p, &bytes).unwrap();
        let back = read_weights(&p, 3).unwrap();
        assert_eq!(back, floats);
        assert!(read_weights(&p, 4).is_err());
    }
}
