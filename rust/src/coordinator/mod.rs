//! Experiment coordinator: trace construction, engine comparison runs, the
//! sustainable-throughput search used for Fig. 9/10 column 1–2, and the
//! fleet-level [`ClusterExperiment`] driver.

use crate::cluster::{
    AutoscalerCfg, Cluster, ClusterCfg, ClusterMetrics, ParallelCfg, PrefixCacheCfg,
    RoutingPolicy, StealCfg, WfqCfg,
};
use crate::engine::{run_engine, EngineCfg, EngineKind};
use crate::metrics::{RunMetrics, Summary};
use crate::model::ModelConfig;
use crate::trace::Tracer;
use crate::workload::{self, BurstyCfg, Dataset, PrefixCfg, PrefixTagger, TenantMix};

/// One experiment's shape: which model/dataset, how many requests, at what
/// Poisson rate (requests/second).
#[derive(Debug, Clone)]
pub struct Experiment {
    pub model: ModelConfig,
    pub dataset: Dataset,
    pub n_requests: usize,
    pub rate: f64,
    pub seed: u64,
}

impl Experiment {
    pub fn new(model: ModelConfig, dataset: Dataset, n_requests: usize, rate: f64) -> Self {
        Experiment { model, dataset, n_requests, rate, seed: 42 }
    }

    pub fn trace(&self) -> Vec<workload::Request> {
        workload::generate(self.dataset, self.n_requests, self.rate, self.seed)
    }

    pub fn cfg(&self) -> EngineCfg {
        let mut cfg = EngineCfg::new(self.model, self.seed);
        // Radix hit rates by workload: chat traffic shares prefixes far more
        // than long-document summarization.
        cfg.radix = match self.dataset {
            Dataset::ShareGpt => (0.5, 0.5),
            Dataset::Mixed => (0.4, 0.5),
            Dataset::LongData => (0.3, 0.4),
            Dataset::Arxiv => (0.2, 0.4),
        };
        cfg
    }

    /// Run one engine on this experiment's trace.
    pub fn run(&self, kind: EngineKind) -> RunMetrics {
        run_engine(kind, &self.cfg(), &self.trace())
    }

    /// Run all requested engines, returning (kind, metrics) pairs.
    pub fn run_all(&self, kinds: &[EngineKind]) -> Vec<(EngineKind, RunMetrics)> {
        kinds.iter().map(|&k| (k, self.run(k))).collect()
    }
}

/// A fleet-level experiment: one [`Experiment`] shape served by a cluster
/// of engine replicas instead of a single instance. Existing single-engine
/// benches keep using [`Experiment`] untouched; fleet benches layer this on
/// top.
#[derive(Debug, Clone)]
pub struct ClusterExperiment {
    pub base: Experiment,
    pub replicas: usize,
    pub policy: RoutingPolicy,
    pub autoscale: Option<AutoscalerCfg>,
    /// When set, arrivals come from the bursty/diurnal process (the
    /// `base.rate` field is ignored in favor of `bursty.base_rate`).
    pub bursty: Option<BurstyCfg>,
    /// Worker threads for the sharded fleet loop: `1` runs the sequential
    /// [`Cluster::run`], `> 1` the digest-identical
    /// [`Cluster::run_parallel`] (see `--threads`).
    pub threads: usize,
    /// Virtual-time synchronization window for the sharded loop, seconds;
    /// `0` = free-run to the next interaction. Output-invariant by
    /// construction (see `--window`).
    pub window: f64,
    /// Deterministic work stealing for the sharded loop: `Some` migrates
    /// replicas between shards when virtual-time load skews past the
    /// threshold (see `--steal-threshold` / `--balance-interval`).
    /// Output-invariant by construction.
    pub steal: Option<StealCfg>,
    /// Tenant labels on generated arrivals (`None` leaves every request on
    /// the default tenant 0 — arrivals are byte-identical to untagged).
    pub tenant_mix: Option<TenantMix>,
    /// Weighted-fair-queueing admission front: `Some` interposes the
    /// [`TenantGate`] between arrivals and the router in all three fleet
    /// loops (see `--wfq`).
    ///
    /// [`TenantGate`]: crate::cluster::TenantGate
    pub wfq: Option<WfqCfg>,
    /// Fleet prefix-cache tier configuration (`--prefix-capacity`,
    /// `--tier`). `None` with a non-prefix policy disables the machinery;
    /// [`RoutingPolicy::PrefixAware`] auto-fills the default config. Any
    /// enabled config also tags the generated trace with deterministic
    /// prefix lineage from [`PrefixCfg::for_dataset`] — the same per-dataset
    /// reuse model as the single-engine `RadixCache` table in
    /// [`Experiment::cfg`].
    pub prefix: Option<PrefixCacheCfg>,
}

impl ClusterExperiment {
    pub fn new(base: Experiment, replicas: usize, policy: RoutingPolicy) -> Self {
        ClusterExperiment {
            base,
            replicas,
            policy,
            autoscale: None,
            bursty: None,
            threads: 1,
            window: 0.0,
            steal: None,
            tenant_mix: None,
            wfq: None,
            prefix: None,
        }
    }

    /// Whether the fleet prefix-cache machinery (and hence deterministic
    /// trace lineage) is engaged for this experiment.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some() || self.policy == RoutingPolicy::PrefixAware
    }

    pub fn trace(&self) -> Vec<workload::Request> {
        let mut trace = match (&self.bursty, &self.tenant_mix) {
            (Some(b), None) => workload::generate_bursty(
                self.base.dataset,
                self.base.n_requests,
                b,
                self.base.seed,
            ),
            (Some(b), Some(mix)) => workload::generate_bursty_with_tenants(
                self.base.dataset,
                self.base.n_requests,
                b,
                self.base.seed,
                mix,
            ),
            (None, None) => self.base.trace(),
            (None, Some(mix)) => workload::generate_with_tenants(
                self.base.dataset,
                self.base.n_requests,
                self.base.rate,
                self.base.seed,
                mix,
            ),
        };
        if self.prefix_enabled() {
            // Lineage tagging is pure `(seed, id)` hashing — arrivals,
            // lengths, and tenant labels are untouched.
            let pcfg = PrefixCfg::for_dataset(self.base.dataset, self.base.seed);
            PrefixTagger::new(&pcfg).apply(&mut trace);
        }
        trace
    }

    /// Run the fleet with every replica running `kind`.
    pub fn run(&self, kind: EngineKind) -> ClusterMetrics {
        self.run_traced(kind, &Tracer::default())
    }

    /// Run the fleet with a trace handle attached to the loop, router,
    /// autoscaler, and every replica engine. Drain the recorded events
    /// afterwards with [`Tracer::take`]; pass `Tracer::default()` for an
    /// untraced run (this is exactly [`ClusterExperiment::run`]).
    pub fn run_traced(&self, kind: EngineKind, tracer: &Tracer) -> ClusterMetrics {
        let mut cfg = ClusterCfg::new(kind, self.base.cfg(), self.replicas, self.policy);
        cfg.autoscale = self.autoscale;
        cfg.wfq = self.wfq.clone();
        cfg.prefix = self.prefix;
        let mut cluster = Cluster::new(cfg);
        cluster.tracer = tracer.clone();
        if self.threads > 1 {
            cluster.run_parallel_cfg(
                &self.trace(),
                ParallelCfg { threads: self.threads, window: self.window, steal: self.steal },
            )
        } else {
            cluster.run(&self.trace())
        }
    }
}

/// Latency constraints defining "sustainable" load (§6.2.1: the highest
/// arrival rate handled without violating token latency constraints).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// P95 normalized latency ceiling (s per output token).
    pub p95_norm: f64,
    /// Mean TTFT ceiling (s).
    pub mean_ttft: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { p95_norm: 0.20, mean_ttft: 15.0 }
    }
}

impl SloSpec {
    pub fn satisfied(&self, s: &Summary, total: usize) -> bool {
        s.completed == total && s.p95_norm <= self.p95_norm && s.mean_ttft <= self.mean_ttft
    }
}

/// Binary-search the maximum sustainable request rate for one engine.
///
/// Runs `n_requests`-sized traces at candidate rates in `[lo, hi]` req/s and
/// returns the highest rate whose run satisfies `slo` (resolution `tol`).
pub fn sustainable_throughput(
    kind: EngineKind,
    base: &Experiment,
    slo: SloSpec,
    lo: f64,
    hi: f64,
    tol: f64,
) -> f64 {
    let ok_at = |rate: f64| -> bool {
        let mut exp = base.clone();
        exp.rate = rate;
        let m = exp.run(kind);
        slo.satisfied(&m.summary(), exp.n_requests)
    };
    let mut lo = lo;
    let mut hi = hi;
    if !ok_at(lo) {
        return 0.0;
    }
    if ok_at(hi) {
        return hi;
    }
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        if ok_at(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Offline makespan (§6.3): all requests submitted at t=0; returns the
/// completion time, or `None` on timeout (some request never finished).
pub fn offline_makespan(kind: EngineKind, exp: &Experiment) -> Option<(f64, RunMetrics)> {
    let trace = workload::offline(exp.dataset, exp.n_requests, exp.seed);
    let m = run_engine(kind, &exp.cfg(), &trace);
    if m.timeouts > 0 || m.summary().completed < exp.n_requests {
        None
    } else {
        Some((m.makespan, m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Experiment {
        Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 25, 3.0)
    }

    #[test]
    fn experiment_runs_and_summarizes() {
        let exp = small();
        let m = exp.run(EngineKind::Nexus);
        let s = m.summary();
        assert_eq!(s.completed, 25);
        assert!(s.mean_ttft > 0.0 && s.mean_tbt > 0.0);
    }

    #[test]
    fn run_all_covers_kinds() {
        let exp = small();
        let res = exp.run_all(&[EngineKind::Vllm, EngineKind::Nexus]);
        assert_eq!(res.len(), 2);
        assert!(res.iter().all(|(_, m)| m.summary().completed == 25));
    }

    #[test]
    fn throughput_search_brackets() {
        let mut exp = small();
        exp.n_requests = 20;
        let slo = SloSpec::default();
        let thr = sustainable_throughput(EngineKind::Nexus, &exp, slo, 0.5, 40.0, 2.0);
        assert!(thr > 0.0, "nexus must sustain some load");
        // An absurd SLO yields zero.
        let strict = SloSpec { p95_norm: 1e-6, mean_ttft: 1e-6 };
        assert_eq!(
            sustainable_throughput(EngineKind::Vllm, &exp, strict, 0.5, 40.0, 2.0),
            0.0
        );
    }

    #[test]
    fn cluster_experiment_runs_all_policies() {
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 30, 6.0);
        for &policy in RoutingPolicy::all() {
            let exp = ClusterExperiment::new(base.clone(), 2, policy);
            let m = exp.run(EngineKind::Nexus);
            assert_eq!(
                m.fleet.records.len() + m.fleet.timeouts,
                30,
                "{} lost requests",
                policy.name()
            );
        }
    }

    #[test]
    fn cluster_experiment_parallel_dispatch_matches_sequential() {
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 30, 6.0);
        let mut exp = ClusterExperiment::new(base, 3, RoutingPolicy::JoinShortestQueue);
        let seq = exp.run(EngineKind::Nexus);
        exp.threads = 4;
        exp.window = 2.0;
        let par = exp.run(EngineKind::Nexus);
        assert_eq!(seq.digest(), par.digest(), "--threads must not change results");
        exp.steal = Some(StealCfg { threshold: 1.2, interval: 0.5 });
        let stolen = exp.run(EngineKind::Nexus);
        assert_eq!(
            seq.digest(),
            stolen.digest(),
            "--steal-threshold must not change results"
        );
    }

    #[test]
    fn cluster_experiment_bursty_and_autoscaled() {
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 40, 4.0);
        let mut exp = ClusterExperiment::new(base, 1, RoutingPolicy::JoinShortestQueue);
        exp.bursty = Some(BurstyCfg { base_rate: 8.0, ..BurstyCfg::default() });
        exp.autoscale = Some(AutoscalerCfg { max_replicas: 3, ..AutoscalerCfg::default() });
        let m = exp.run(EngineKind::Nexus);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 40);
        assert!(m.peak_replicas <= 3);
    }

    #[test]
    fn cluster_experiment_tenant_mix_and_wfq() {
        use crate::workload::TenantSpec;
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 30, 6.0);
        let mut exp = ClusterExperiment::new(base, 2, RoutingPolicy::JoinShortestQueue);
        exp.tenant_mix = Some(TenantMix::uniform(2));
        // Tagging alone must not perturb arrivals or results.
        let tagged = exp.trace();
        assert!(tagged.iter().any(|r| r.tenant == 1), "mix must label tenants");
        let untagged = ClusterExperiment::new(
            Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 30, 6.0),
            2,
            RoutingPolicy::JoinShortestQueue,
        )
        .trace();
        for (a, b) in tagged.iter().zip(&untagged) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
        }
        // WFQ front engaged: nothing lost, per-tenant report populated.
        let specs = vec![TenantSpec::default(), TenantSpec::default()];
        exp.wfq = Some(WfqCfg::new(specs.clone()));
        let m = exp.run(EngineKind::Nexus);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 30);
        let rep = m.tenant_report(&specs);
        assert_eq!(rep.len(), 2);
        assert_eq!(rep.iter().map(|t| t.completed).sum::<usize>(), 30);
    }

    #[test]
    fn cluster_experiment_prefix_policy_tags_and_reports() {
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 120, 10.0);
        let exp = ClusterExperiment::new(base.clone(), 2, RoutingPolicy::PrefixAware);
        let trace = exp.trace();
        assert!(trace.iter().all(|r| r.prefix != 0), "every request gets a lineage");
        assert!(trace.iter().any(|r| r.shared() > 0), "chat workload must have warm turns");
        // Tagging is observational on arrivals/lengths.
        let untagged = ClusterExperiment::new(base, 2, RoutingPolicy::JoinShortestQueue).trace();
        assert!(untagged.iter().all(|r| r.prefix == 0));
        for (a, b) in trace.iter().zip(&untagged) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prompt_len, b.prompt_len);
        }
        let m = exp.run(EngineKind::Nexus);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 120);
        assert!(m.prefix.lookups > 0, "warm turns must reach the prefix store");
        assert!(m.prefix.tokens_saved > 0, "resident prefixes must save prefill");
        assert!(m.prefix.hit_rate() > 0.0 && m.prefix.hit_rate() <= 1.0);
    }

    #[test]
    fn offline_makespan_positive() {
        let exp = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, 20, 1.0);
        let (mk, m) = offline_makespan(EngineKind::Vllm, &exp).unwrap();
        assert!(mk > 0.0);
        assert_eq!(m.summary().completed, 20);
    }
}
