//! Phase-specific schedulers (paper §4.3) and baseline scheduling policies.
//!
//! * [`spf_batch`] — Nexus's Shortest-Prompt-First prefill scheduler
//!   (Algorithm 2) with the age-decay anti-starvation term
//!   `score = remaining − γ·age`.
//! * [`fcfs_batch`] — FCFS token-budget packing (vLLM/SGLang prefill, and
//!   Nexus's decode queue admission).
//! * [`mixed_batch`] — Sarathi-style chunked-prefill batching used by the
//!   monolithic baselines: decode tokens share the iteration with a chunk
//!   of the head-of-line prefill.
//! * [`Mlfq`] — FastServe's skip-join multi-level feedback queue.
//! * [`RadixCache`] — SGLang-style prefix-cache model: repeated prompt
//!   prefixes skip recomputation, shortening effective prefill length.

use crate::util::rng::Rng;
use crate::util::{f64_total_key, OrderedIdSet};
use std::collections::HashMap;

/// Reusable sort scratch for the `*_into` batch builders, so the per-batch
/// hot path allocates nothing: engines own one and thread it through every
/// scheduling call (§Perf).
#[derive(Debug, Clone, Default)]
pub struct SchedScratch {
    /// (primary key, secondary key, id, queue index) sort records.
    keys: Vec<(u64, u64, usize, usize)>,
}

/// A request waiting for (more) prefill.
#[derive(Debug, Clone, Copy)]
pub struct PrefillItem {
    pub id: usize,
    pub prompt_len: usize,
    /// Tokens already prefilled (chunked prefill may leave a remainder).
    pub prefilled: usize,
    pub arrival: f64,
}

impl PrefillItem {
    pub fn remaining(&self) -> usize {
        self.prompt_len - self.prefilled
    }
}

/// Algorithm 2 — Shortest-Prompt-First with anti-starvation.
///
/// Ranks queue entries by `remaining − γ·(now − arrival)` and greedily packs
/// them into a `budget`-token batch. Returns indices into `queue` in
/// scheduling order; a prefix of each selected request may still be chunked
/// by the caller if the last one does not fit entirely.
pub fn spf_batch(queue: &[PrefillItem], now: f64, budget: usize, gamma: f64) -> Vec<usize> {
    let mut out = Vec::new();
    spf_batch_into(queue, now, budget, gamma, &mut SchedScratch::default(), &mut out);
    out
}

/// Allocation-free [`spf_batch`]: clears and fills `out` with indices into
/// `queue` in scheduling order, reusing `scratch` for the sort records.
pub fn spf_batch_into(
    queue: &[PrefillItem],
    now: f64,
    budget: usize,
    gamma: f64,
    scratch: &mut SchedScratch,
    out: &mut Vec<usize>,
) {
    out.clear();
    // Precompute scores once and sort by order-preserving integer keys:
    // float comparators recompute/branch per comparison and are ~4x slower
    // on deep queues (§Perf).
    scratch.keys.clear();
    scratch.keys.extend(queue.iter().enumerate().map(|(idx, r)| {
        let score = r.remaining() as f64 - gamma * (now - r.arrival);
        (f64_total_key(score), f64_total_key(r.arrival), r.id, idx)
    }));
    scratch.keys.sort_unstable();
    let mut total = 0usize;
    for &(_, _, _, idx) in &scratch.keys {
        let rem = queue[idx].remaining();
        if total + rem <= budget {
            out.push(idx);
            total += rem;
        } else if total < budget && out.is_empty() {
            // Nothing fits whole: chunk the best-scored request.
            out.push(idx);
            break;
        }
    }
}

/// FCFS token-budget packing: take requests in arrival order while the
/// budget lasts; the first non-fitting head request is included for
/// chunking when `chunk_head` is set.
pub fn fcfs_batch(queue: &[PrefillItem], budget: usize, chunk_head: bool) -> Vec<usize> {
    let mut out = Vec::new();
    fcfs_batch_into(queue, budget, chunk_head, &mut SchedScratch::default(), &mut out);
    out
}

/// Allocation-free [`fcfs_batch`]: clears and fills `out` with indices into
/// `queue` in (arrival, id) order, reusing `scratch` for the sort records.
pub fn fcfs_batch_into(
    queue: &[PrefillItem],
    budget: usize,
    chunk_head: bool,
    scratch: &mut SchedScratch,
    out: &mut Vec<usize>,
) {
    out.clear();
    scratch.keys.clear();
    scratch.keys.extend(
        queue
            .iter()
            .enumerate()
            .map(|(idx, r)| (f64_total_key(r.arrival), r.id as u64, idx, 0)),
    );
    scratch.keys.sort_unstable();
    let mut total = 0usize;
    for &(_, _, idx, _) in &scratch.keys {
        let rem = queue[idx].remaining();
        if total + rem <= budget {
            out.push(idx);
            total += rem;
        } else {
            if chunk_head && total < budget {
                out.push(idx);
            }
            break;
        }
    }
}

/// A mixed (chunked-prefill) batch for monolithic engines.
#[derive(Debug, Clone, Default)]
pub struct MixedBatch {
    /// Decode request ids included (1 token each).
    pub decode_ids: Vec<usize>,
    /// (queue index, tokens of prefill to run) — at most the chunk budget.
    pub prefill_parts: Vec<(usize, usize)>,
}

impl MixedBatch {
    pub fn prefill_tokens(&self) -> usize {
        self.prefill_parts.iter().map(|&(_, t)| t).sum()
    }
    pub fn is_empty(&self) -> bool {
        self.decode_ids.is_empty() && self.prefill_parts.is_empty()
    }
}

/// Sarathi-Serve / vLLM chunked-prefill batching: all running decodes join
/// (one token each), then prefill chunks fill the remaining token budget
/// FCFS, splitting the head request if needed (`chunk_size` caps any single
/// request's share per iteration).
pub fn mixed_batch(
    decode_ids: &[usize],
    prefill_queue: &[PrefillItem],
    token_budget: usize,
    chunk_size: usize,
) -> MixedBatch {
    let mut batch = MixedBatch::default();
    batch.decode_ids.extend_from_slice(decode_ids);
    mixed_batch_into(
        decode_ids.len(),
        prefill_queue,
        token_budget,
        chunk_size,
        &mut SchedScratch::default(),
        &mut batch,
    );
    batch
}

/// Allocation-free core of [`mixed_batch`]: clears and refills
/// `batch.prefill_parts` in place, reusing `scratch` for the FCFS sort
/// records. `batch.decode_ids` is left untouched — the engine hot path
/// already owns its decode set, so copying it per iteration would be dead
/// work; only the decode *count* matters here (it charges the token
/// budget).
pub fn mixed_batch_into(
    decode_count: usize,
    prefill_queue: &[PrefillItem],
    token_budget: usize,
    chunk_size: usize,
    scratch: &mut SchedScratch,
    batch: &mut MixedBatch,
) {
    batch.prefill_parts.clear();
    let mut left = token_budget.saturating_sub(decode_count);
    scratch.keys.clear();
    scratch.keys.extend(
        prefill_queue
            .iter()
            .enumerate()
            .map(|(idx, r)| (f64_total_key(r.arrival), r.id as u64, idx, 0)),
    );
    scratch.keys.sort_unstable();
    for &(_, _, idx, _) in &scratch.keys {
        if left == 0 {
            break;
        }
        let take = prefill_queue[idx].remaining().min(chunk_size).min(left);
        if take > 0 {
            batch.prefill_parts.push((idx, take));
            left -= take;
        }
    }
}

/// FastServe's skip-join multi-level feedback queue.
///
/// Queue levels have geometrically growing token quanta. New requests
/// *skip-join* the level whose quantum covers their prefill length (so long
/// prompts don't stall level 0), and are demoted when they exhaust their
/// quantum of generated tokens.
#[derive(Debug, Clone)]
pub struct Mlfq {
    /// Per-level quantum in tokens.
    pub quanta: Vec<usize>,
    /// levels[l] = FIFO of request ids (insertion-ordered, O(1) removal).
    levels: Vec<OrderedIdSet>,
    /// id -> (level, tokens consumed at this level).
    state: HashMap<usize, (usize, usize)>,
}

impl Mlfq {
    pub fn new(base_quantum: usize, levels: usize) -> Self {
        let quanta: Vec<usize> = (0..levels).map(|l| base_quantum << l).collect();
        Mlfq {
            quanta,
            levels: vec![OrderedIdSet::new(); levels],
            state: HashMap::new(),
        }
    }

    /// Skip-join admission: enter the first level whose quantum ≥ prompt_len.
    pub fn admit(&mut self, id: usize, prompt_len: usize) {
        let lvl = self
            .quanta
            .iter()
            .position(|&q| q >= prompt_len)
            .unwrap_or(self.quanta.len() - 1);
        self.levels[lvl].insert(id);
        self.state.insert(id, (lvl, 0));
    }

    /// Up to `max` ids in priority order: the highest non-empty level's
    /// FIFO first, then lower levels while capacity remains (iteration-level
    /// scheduling fills the batch rather than idling slots).
    pub fn pick(&self, max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.pick_into(max, &mut out);
        out
    }

    /// Allocation-free [`Mlfq::pick`]: clears and fills `out`.
    pub fn pick_into(&self, max: usize, out: &mut Vec<usize>) {
        out.clear();
        for lvl in &self.levels {
            for id in lvl.iter() {
                if out.len() >= max {
                    return;
                }
                out.push(id);
            }
        }
    }

    /// Record `tokens` of service; demotes when the level quantum runs out.
    pub fn charge(&mut self, id: usize, tokens: usize) {
        if let Some(&(lvl, used)) = self.state.get(&id) {
            let used = used + tokens;
            if used >= self.quanta[lvl] && lvl + 1 < self.quanta.len() {
                self.levels[lvl].remove(id);
                self.levels[lvl + 1].insert(id);
                self.state.insert(id, (lvl + 1, 0));
            } else {
                self.state.insert(id, (lvl, used));
            }
        }
    }

    pub fn remove(&mut self, id: usize) {
        if let Some((lvl, _)) = self.state.remove(&id) {
            self.levels[lvl].remove(id);
        }
    }

    pub fn level_of(&self, id: usize) -> Option<usize> {
        self.state.get(&id).map(|&(l, _)| l)
    }

    pub fn len(&self) -> usize {
        self.state.len()
    }
    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }
}

/// SGLang RadixAttention model: a probabilistic prefix cache. A request's
/// prompt shares a cached prefix with earlier traffic with probability
/// `hit_prob`; on a hit, a Beta-ish distributed fraction of the prompt is
/// served from cache, shrinking effective prefill work (and KV writes).
#[derive(Debug, Clone)]
pub struct RadixCache {
    pub hit_prob: f64,
    /// Mean cached fraction on a hit.
    pub mean_frac: f64,
    rng: Rng,
    pub hits: usize,
    pub misses: usize,
}

impl RadixCache {
    pub fn new(hit_prob: f64, mean_frac: f64, seed: u64) -> Self {
        RadixCache {
            hit_prob,
            mean_frac,
            rng: Rng::new(seed),
            hits: 0,
            misses: 0,
        }
    }

    /// Effective tokens that still need prefill for a `prompt_len` request.
    pub fn effective_prefill(&mut self, prompt_len: usize) -> usize {
        if self.rng.chance(self.hit_prob) {
            self.hits += 1;
            // Triangular-ish around mean_frac, clamped.
            let f = (self.mean_frac + 0.3 * (self.rng.f64() - 0.5)).clamp(0.05, 0.95);
            let cached = (prompt_len as f64 * f) as usize;
            (prompt_len - cached).max(1)
        } else {
            self.misses += 1;
            prompt_len
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: usize, len: usize, arrival: f64) -> PrefillItem {
        PrefillItem {
            id,
            prompt_len: len,
            prefilled: 0,
            arrival,
        }
    }

    #[test]
    fn spf_prefers_short_prompts() {
        let q = vec![item(0, 5000, 0.0), item(1, 100, 0.1), item(2, 800, 0.2)];
        let picked = spf_batch(&q, 0.3, 1000, 0.0);
        assert_eq!(picked, vec![1, 2], "short prompts first, long doesn't fit");
    }

    #[test]
    fn spf_age_decay_promotes_old_requests() {
        // With γ high enough, the old long request outranks the fresh short one.
        let q = vec![item(0, 2000, 0.0), item(1, 100, 100.0)];
        let now = 100.0;
        let no_age = spf_batch(&q, now, 2000, 0.0);
        assert_eq!(no_age[0], 1);
        let aged = spf_batch(&q, now, 2000, 50.0);
        assert_eq!(aged[0], 0, "γ=50 over 100s of age beats 1900-token gap");
    }

    #[test]
    fn spf_respects_budget() {
        let q = vec![item(0, 400, 0.0), item(1, 400, 0.0), item(2, 400, 0.0)];
        let picked = spf_batch(&q, 1.0, 900, 0.0);
        let total: usize = picked.iter().map(|&i| q[i].remaining()).sum();
        assert!(total <= 900);
        assert_eq!(picked.len(), 2);
    }

    #[test]
    fn spf_chunks_when_nothing_fits() {
        let q = vec![item(0, 5000, 0.0)];
        let picked = spf_batch(&q, 1.0, 512, 0.0);
        assert_eq!(picked, vec![0], "head request still scheduled for chunking");
    }

    #[test]
    fn fcfs_is_arrival_ordered() {
        let q = vec![item(0, 100, 5.0), item(1, 100, 1.0), item(2, 100, 3.0)];
        let picked = fcfs_batch(&q, 250, false);
        assert_eq!(picked, vec![1, 2]);
    }

    #[test]
    fn mixed_batch_fills_after_decodes() {
        let q = vec![item(7, 3000, 0.0), item(8, 200, 1.0)];
        let b = mixed_batch(&[1, 2, 3], &q, 512, 256);
        assert_eq!(b.decode_ids.len(), 3);
        // 509 tokens left; head chunk capped at 256, then 200 from next, then 53 more head? No:
        // FCFS order = [0 (id7), 1 (id8)]; head takes min(3000,256,509)=256, next takes min(200,253)=200.
        assert_eq!(b.prefill_parts, vec![(0, 256), (1, 200)]);
        assert!(b.prefill_tokens() + b.decode_ids.len() <= 512);
    }

    #[test]
    fn mlfq_skip_join_and_demotion() {
        let mut m = Mlfq::new(512, 4); // quanta 512,1024,2048,4096
        m.admit(1, 100); // level 0
        m.admit(2, 2000); // skip-joins level 2
        assert_eq!(m.level_of(1), Some(0));
        assert_eq!(m.level_of(2), Some(2));
        assert_eq!(m.pick(10), vec![1, 2], "fill across levels, priority first");
        assert_eq!(m.pick(1), vec![1], "capacity respected");
        m.charge(1, 512); // exhaust level-0 quantum → demote
        assert_eq!(m.level_of(1), Some(1));
        m.remove(1);
        assert_eq!(m.pick(10), vec![2]);
        m.remove(2);
        assert!(m.is_empty());
    }

    #[test]
    fn radix_cache_shrinks_prompts() {
        let mut rc = RadixCache::new(1.0, 0.5, 42);
        let mut total = 0usize;
        for _ in 0..200 {
            total += rc.effective_prefill(1000);
        }
        let mean = total as f64 / 200.0;
        assert!(mean < 700.0 && mean > 300.0, "mean effective {mean}");
        assert_eq!(rc.hits, 200);

        let mut rc0 = RadixCache::new(0.0, 0.5, 42);
        assert_eq!(rc0.effective_prefill(1000), 1000);
        assert_eq!(rc0.misses, 1);
    }
}
