//! Serving metrics: TTFT, TBT, normalized latency, stage breakdown.
//!
//! The paper reports mean and P95 of three latency metrics (§6.1):
//! *TTFT* (arrival → first output token), *TBT* (inter-token gap during
//! decode), and *normalized latency* (end-to-end latency / output tokens).
//! Figure 12 additionally decomposes per-token latency into scheduling,
//! queuing, and execution stages.

mod hist;
pub use hist::Histogram;

use crate::util::{mean, percentile};
use crate::workload::TenantSpec;

/// Per-request record accumulated by an engine run.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: usize,
    /// Owning tenant (mirrors `Request::tenant`; 0 for untagged workloads).
    pub tenant: u16,
    pub arrival: f64,
    /// Time the first output token was produced (end of prefill).
    pub first_token: f64,
    /// Completion time of the last token.
    pub finish: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Inter-token gaps observed during decode (seconds).
    pub token_gaps: Vec<f64>,
    /// Cumulative time spent in scheduler decision-making for this request.
    pub sched_time: f64,
    /// Cumulative time spent waiting in queues (not executing).
    pub queue_time: f64,
    /// Cumulative time spent in GPU execution.
    pub exec_time: f64,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }
    pub fn e2e(&self) -> f64 {
        self.finish - self.arrival
    }
    /// End-to-end latency divided by output tokens (paper's normalized latency).
    pub fn normalized_latency(&self) -> f64 {
        self.e2e() / self.output_len.max(1) as f64
    }

    /// Mean inter-token gap during decode (0.0 for single-token outputs —
    /// a request with no decode gaps cannot violate a TBT SLO).
    pub fn mean_tbt(&self) -> f64 {
        if self.token_gaps.is_empty() {
            0.0
        } else {
            self.token_gaps.iter().sum::<f64>() / self.token_gaps.len() as f64
        }
    }

    /// DistServe-style goodput predicate: the request counts iff it meets
    /// *both* latency SLOs. Boundary semantics are inclusive — a latency
    /// exactly at the SLO meets it (pinned by the metrics edge-case tests).
    pub fn meets_slo(&self, spec: &TenantSpec) -> bool {
        self.ttft() <= spec.ttft_slo && self.mean_tbt() <= spec.tbt_slo
    }
}

/// Per-tenant SLO attainment and goodput over one run's records.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantSummary {
    pub tenant: usize,
    /// Completed requests belonging to this tenant.
    pub completed: usize,
    /// Completed requests meeting both SLOs ([`RequestRecord::meets_slo`]).
    pub slo_ok: usize,
    /// `slo_ok / completed`; a tenant with no completed requests has
    /// vacuous attainment 1.0 (it violated nothing).
    pub attainment: f64,
    /// SLO-meeting requests per second of run span (0.0 on an empty run).
    pub goodput: f64,
}

/// Aggregated metrics for one engine run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub records: Vec<RequestRecord>,
    /// Virtual-time span of the run (first arrival → last finish).
    pub makespan: f64,
    /// Number of SM repartition events that actually applied (Nexus only).
    pub repartitions: usize,
    /// Number of repartition proposals suppressed by the hysteresis buffer.
    pub suppressed_repartitions: usize,
    /// KV-cache swap / eviction / recompute events (FastServe, vLLM-P/D).
    pub swaps: usize,
    pub recomputes: usize,
    /// Requests that timed out / were rejected (offline runs).
    pub timeouts: usize,
    /// Time-weighted mean prefill SM share over the run (0.0 when the
    /// engine does not report partitions).
    pub mean_rp: f64,
    /// Fraction of virtual time spent decode-prioritized (Nexus only).
    pub decode_mode_frac: f64,
    /// Time-weighted mean / peak KV-cache usage `KV_u` (engines that track it).
    pub mean_kv_usage: f64,
    pub peak_kv_usage: f64,
}

/// Summary statistics over a set of request records.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub mean_ttft: f64,
    pub p95_ttft: f64,
    pub mean_tbt: f64,
    pub p95_tbt: f64,
    pub mean_norm: f64,
    pub p95_norm: f64,
    pub throughput_rps: f64,
    pub token_throughput: f64,
    pub completed: usize,
}

impl RunMetrics {
    pub fn push(&mut self, r: RequestRecord) {
        self.makespan = self.makespan.max(r.finish);
        self.records.push(r);
    }

    pub fn summary(&self) -> Summary {
        let ttfts: Vec<f64> = self.records.iter().map(|r| r.ttft()).collect();
        let norms: Vec<f64> = self.records.iter().map(|r| r.normalized_latency()).collect();
        let gaps: Vec<f64> = self
            .records
            .iter()
            .flat_map(|r| r.token_gaps.iter().copied())
            .collect();
        let span = self.span().max(1e-9);
        let tokens: usize = self.records.iter().map(|r| r.output_len).sum();
        Summary {
            mean_ttft: mean(&ttfts),
            p95_ttft: percentile(&ttfts, 95.0),
            mean_tbt: mean(&gaps),
            p95_tbt: percentile(&gaps, 95.0),
            mean_norm: mean(&norms),
            p95_norm: percentile(&norms, 95.0),
            throughput_rps: self.records.len() as f64 / span,
            token_throughput: tokens as f64 / span,
            completed: self.records.len(),
        }
    }

    /// First arrival → last finish.
    pub fn span(&self) -> f64 {
        let first = self
            .records
            .iter()
            .map(|r| r.arrival)
            .fold(f64::INFINITY, f64::min);
        if self.records.is_empty() {
            0.0
        } else {
            self.makespan - first
        }
    }

    /// Merge another run's metrics into this one (fleet aggregation).
    ///
    /// Records and event counters are concatenated/summed; the
    /// time-weighted trajectory means (`mean_rp`, `decode_mode_frac`,
    /// `mean_kv_usage`) are combined weighted by each side's makespan, so
    /// merging into an empty `RunMetrics::default()` is the identity.
    pub fn merge(&mut self, other: RunMetrics) {
        let (wa, wb) = (self.makespan, other.makespan);
        if wa + wb > 0.0 {
            let mix = |a: f64, b: f64| (a * wa + b * wb) / (wa + wb);
            self.mean_rp = mix(self.mean_rp, other.mean_rp);
            self.decode_mode_frac = mix(self.decode_mode_frac, other.decode_mode_frac);
            self.mean_kv_usage = mix(self.mean_kv_usage, other.mean_kv_usage);
        }
        self.makespan = self.makespan.max(other.makespan);
        self.repartitions += other.repartitions;
        self.suppressed_repartitions += other.suppressed_repartitions;
        self.swaps += other.swaps;
        self.recomputes += other.recomputes;
        self.timeouts += other.timeouts;
        self.peak_kv_usage = self.peak_kv_usage.max(other.peak_kv_usage);
        self.records.extend(other.records);
    }

    /// TTFT distribution of this run (one sample per completed request).
    pub fn ttft_histogram(&self) -> Histogram {
        let mut h = Histogram::new();
        for r in &self.records {
            h.record(r.ttft().max(0.0));
        }
        h
    }

    /// Inter-token-gap (TBT) distribution of this run.
    pub fn tbt_histogram(&self) -> Histogram {
        let mut h = Histogram::new();
        for r in &self.records {
            for &g in &r.token_gaps {
                h.record(g.max(0.0));
            }
        }
        h
    }

    /// Per-tenant SLO attainment and goodput. The report covers
    /// `max(specs.len(), highest observed label + 1)` tenants; records
    /// labeled beyond `specs` are judged against [`TenantSpec::default`]
    /// (permissive SLOs), so an untagged run with no specs reports one
    /// all-zero-tenant row.
    pub fn tenant_report(&self, specs: &[TenantSpec]) -> Vec<TenantSummary> {
        let observed = self.records.iter().map(|r| r.tenant as usize + 1).max().unwrap_or(0);
        let n = specs.len().max(observed).max(1);
        let span = self.span();
        let default_spec = TenantSpec::default();
        let mut out: Vec<TenantSummary> = (0..n)
            .map(|tenant| TenantSummary {
                tenant,
                completed: 0,
                slo_ok: 0,
                attainment: 1.0,
                goodput: 0.0,
            })
            .collect();
        for r in &self.records {
            let t = r.tenant as usize;
            let spec = specs.get(t).unwrap_or(&default_spec);
            out[t].completed += 1;
            if r.meets_slo(spec) {
                out[t].slo_ok += 1;
            }
        }
        for s in &mut out {
            if s.completed > 0 {
                s.attainment = s.slo_ok as f64 / s.completed as f64;
            }
            if span > 0.0 {
                s.goodput = s.slo_ok as f64 / span;
            }
        }
        out
    }

    /// Fleet goodput (DistServe): SLO-meeting requests per second of run
    /// span, summed over all tenants.
    pub fn goodput(&self, specs: &[TenantSpec]) -> f64 {
        self.tenant_report(specs).iter().map(|s| s.goodput).sum()
    }

    /// Behavioral digest of a run: an FNV-1a hash over every per-request
    /// record (sorted by id, so fleet merge order is irrelevant) plus the
    /// run-level event counters, with all virtual times quantized to 1 ns.
    ///
    /// The golden-digest tests use this to pin behavior where two code
    /// paths advance the simulators in *identical* time slices (re-running
    /// the same loop, or a 1-replica cluster vs. the plain engine drive):
    /// there the virtual times are bit-identical and any reordering,
    /// dropped token, or changed preemption shows up as a mismatch. For
    /// comparisons across *different* slicings (the event-queue fleet loop
    /// vs. the step-everyone reference loop), quantized hashing is not
    /// boundary-safe — use [`RunMetrics::deviation`] with a tolerance
    /// instead. Wall-clock-derived fields (`sched_time`) and the
    /// time-weighted trajectory means are excluded from the digest.
    pub fn digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(FNV_PRIME);
            }
        }
        /// Quantize a virtual time / ratio to integer nanoseconds.
        fn q(x: f64) -> u64 {
            (x * 1e9).round() as i64 as u64
        }
        let mut order: Vec<usize> = (0..self.records.len()).collect();
        order.sort_by_key(|&i| self.records[i].id);
        let mut h = FNV_OFFSET;
        for &i in &order {
            let r = &self.records[i];
            mix(&mut h, r.id as u64);
            mix(&mut h, r.tenant as u64);
            mix(&mut h, q(r.arrival));
            mix(&mut h, q(r.first_token));
            mix(&mut h, q(r.finish));
            mix(&mut h, r.prompt_len as u64);
            mix(&mut h, r.output_len as u64);
            mix(&mut h, r.token_gaps.len() as u64);
            for &g in &r.token_gaps {
                mix(&mut h, q(g));
            }
            mix(&mut h, q(r.queue_time));
            mix(&mut h, q(r.exec_time));
        }
        mix(&mut h, self.records.len() as u64);
        mix(&mut h, q(self.makespan));
        mix(&mut h, self.repartitions as u64);
        mix(&mut h, self.suppressed_repartitions as u64);
        mix(&mut h, self.swaps as u64);
        mix(&mut h, self.recomputes as u64);
        mix(&mut h, self.timeouts as u64);
        mix(&mut h, q(self.peak_kv_usage));
        h
    }

    /// Structural-equivalence check against another run: `None` when the
    /// runs differ structurally (request sets, per-request token counts, or
    /// any event counter), otherwise the maximum absolute deviation across
    /// every virtual-time field (records matched by id, so fleet merge
    /// order is irrelevant).
    ///
    /// Two serving loops that made identical scheduling decisions deviate
    /// only by float-associativity noise from advancing the GPU simulators
    /// in different time slices (≪ 1 ns); any real behavioral change either
    /// shifts times by whole iteration durations or trips a counter. The
    /// differential tests assert `deviation ≤ 1e-9` — unlike quantized
    /// digest equality, a tolerance cannot be defeated by a value landing
    /// on a rounding-bucket boundary.
    pub fn deviation(&self, other: &RunMetrics) -> Option<f64> {
        if self.records.len() != other.records.len()
            || self.repartitions != other.repartitions
            || self.suppressed_repartitions != other.suppressed_repartitions
            || self.swaps != other.swaps
            || self.recomputes != other.recomputes
            || self.timeouts != other.timeouts
        {
            return None;
        }
        let mut a: Vec<&RequestRecord> = self.records.iter().collect();
        let mut b: Vec<&RequestRecord> = other.records.iter().collect();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        let mut dev = (self.makespan - other.makespan)
            .abs()
            .max((self.peak_kv_usage - other.peak_kv_usage).abs());
        for (x, y) in a.iter().zip(&b) {
            if x.id != y.id
                || x.tenant != y.tenant
                || x.prompt_len != y.prompt_len
                || x.output_len != y.output_len
                || x.token_gaps.len() != y.token_gaps.len()
            {
                return None;
            }
            dev = dev.max((x.arrival - y.arrival).abs());
            dev = dev.max((x.first_token - y.first_token).abs());
            dev = dev.max((x.finish - y.finish).abs());
            dev = dev.max((x.queue_time - y.queue_time).abs());
            dev = dev.max((x.exec_time - y.exec_time).abs());
            for (g, h) in x.token_gaps.iter().zip(&y.token_gaps) {
                dev = dev.max((g - h).abs());
            }
        }
        Some(dev)
    }

    /// Figure-12 style decomposition, normalized per output token.
    pub fn breakdown(&self) -> StageBreakdown {
        let mut b = StageBreakdown::default();
        let mut tokens = 0usize;
        for r in &self.records {
            b.sched += r.sched_time;
            b.queue += r.queue_time;
            b.exec += r.exec_time;
            tokens += r.output_len.max(1);
        }
        if tokens > 0 {
            b.sched /= tokens as f64;
            b.queue /= tokens as f64;
            b.exec /= tokens as f64;
        }
        b
    }
}

/// Per-token latency decomposition (Figure 12).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageBreakdown {
    pub sched: f64,
    pub queue: f64,
    pub exec: f64,
}

impl StageBreakdown {
    pub fn total(&self) -> f64 {
        self.sched + self.queue + self.exec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, first: f64, finish: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id: 0,
            tenant: 0,
            arrival,
            first_token: first,
            finish,
            prompt_len: 100,
            output_len: out,
            token_gaps: vec![0.01; out.saturating_sub(1)],
            sched_time: 0.001,
            queue_time: 0.1,
            exec_time: 0.2,
        }
    }

    /// A record for `tenant` with the given TTFT and constant token gap.
    fn trec(id: usize, tenant: u16, ttft: f64, gap: f64) -> RequestRecord {
        RequestRecord {
            id,
            tenant,
            arrival: 0.0,
            first_token: ttft,
            finish: ttft + gap * 4.0,
            prompt_len: 100,
            output_len: 5,
            token_gaps: vec![gap; 4],
            sched_time: 0.0,
            queue_time: 0.0,
            exec_time: 0.1,
        }
    }

    #[test]
    fn ttft_and_normalized() {
        let r = rec(1.0, 1.5, 3.0, 10);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.normalized_latency() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn summary_aggregates() {
        let mut m = RunMetrics::default();
        m.push(rec(0.0, 0.5, 2.0, 5));
        m.push(rec(1.0, 1.2, 4.0, 10));
        let s = m.summary();
        assert_eq!(s.completed, 2);
        assert!((s.mean_ttft - 0.35).abs() < 1e-12);
        assert!((s.mean_tbt - 0.01).abs() < 1e-12);
        assert!(s.throughput_rps > 0.0);
        assert!((m.span() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_per_token() {
        let mut m = RunMetrics::default();
        m.push(rec(0.0, 0.5, 2.0, 10));
        let b = m.breakdown();
        assert!((b.queue - 0.01).abs() < 1e-12);
        assert!((b.exec - 0.02).abs() < 1e-12);
        assert!(b.total() > 0.0);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = RunMetrics::default();
        let s = m.summary();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_ttft, 0.0);
        assert_eq!(m.span(), 0.0);
    }

    #[test]
    fn merge_into_default_is_identity() {
        let mut a = RunMetrics::default();
        let mut b = RunMetrics::default();
        b.push(rec(0.0, 0.5, 2.0, 5));
        b.push(rec(1.0, 1.2, 4.0, 10));
        b.recomputes = 3;
        b.mean_rp = 0.6;
        b.mean_kv_usage = 0.4;
        b.peak_kv_usage = 0.9;
        let want = b.summary();
        a.merge(b);
        let got = a.summary();
        assert_eq!(got.completed, want.completed);
        assert!((got.mean_ttft - want.mean_ttft).abs() < 1e-12);
        assert_eq!(a.recomputes, 3);
        assert!((a.mean_rp - 0.6).abs() < 1e-12);
        assert!((a.peak_kv_usage - 0.9).abs() < 1e-12);
    }

    #[test]
    fn merge_concatenates_and_weights() {
        let mut a = RunMetrics::default();
        a.push(rec(0.0, 0.5, 2.0, 5));
        a.mean_kv_usage = 0.2;
        let mut b = RunMetrics::default();
        b.push(rec(0.0, 1.0, 6.0, 5));
        b.mean_kv_usage = 0.8;
        a.merge(b);
        assert_eq!(a.records.len(), 2);
        assert!((a.makespan - 6.0).abs() < 1e-12);
        // Weighted 2:6 → 0.2·0.25 + 0.8·0.75 = 0.65.
        assert!((a.mean_kv_usage - 0.65).abs() < 1e-12, "got {}", a.mean_kv_usage);
    }

    #[test]
    fn merge_sums_every_event_counter() {
        let mut a = RunMetrics::default();
        a.repartitions = 2;
        a.suppressed_repartitions = 1;
        a.swaps = 5;
        a.recomputes = 3;
        a.timeouts = 1;
        a.peak_kv_usage = 0.7;
        let mut b = RunMetrics::default();
        b.repartitions = 4;
        b.suppressed_repartitions = 6;
        b.swaps = 7;
        b.recomputes = 9;
        b.timeouts = 2;
        b.peak_kv_usage = 0.5;
        a.merge(b);
        assert_eq!(a.repartitions, 6);
        assert_eq!(a.suppressed_repartitions, 7);
        assert_eq!(a.swaps, 12);
        assert_eq!(a.recomputes, 12);
        assert_eq!(a.timeouts, 3);
        assert!((a.peak_kv_usage - 0.7).abs() < 1e-12, "peak is maxed, not summed");
    }

    #[test]
    fn digest_pins_behavior_and_ignores_record_order() {
        let mut a = RunMetrics::default();
        a.push(rec(0.0, 0.5, 2.0, 5));
        a.push(rec(1.0, 1.2, 4.0, 10));
        a.records[1].id = 1;
        let mut b = RunMetrics::default();
        b.push(rec(1.0, 1.2, 4.0, 10));
        b.push(rec(0.0, 0.5, 2.0, 5));
        b.records[0].id = 1;
        assert_eq!(a.digest(), b.digest(), "merge order must not matter");
        // Sub-ns drift is absorbed; a real change is not.
        let mut c = a.clone();
        c.records[0].finish += 1e-13;
        assert_eq!(a.digest(), c.digest(), "1e-13 drift must be quantized away");
        c.records[0].finish += 1e-3;
        assert_ne!(a.digest(), c.digest(), "1 ms shift must change the digest");
        let mut d = a.clone();
        d.recomputes += 1;
        assert_ne!(a.digest(), d.digest(), "counters are part of the digest");
    }

    #[test]
    fn deviation_measures_drift_and_rejects_structural_change() {
        let mut a = RunMetrics::default();
        a.push(rec(0.0, 0.5, 2.0, 5));
        a.push(rec(1.0, 1.2, 4.0, 10));
        a.records[1].id = 1;
        let mut b = a.clone();
        // Reordered records with sub-ns drift: tiny deviation, not None.
        b.records.swap(0, 1);
        b.records[0].finish += 3e-13;
        let dev = a.deviation(&b).expect("structurally identical");
        assert!(dev >= 3e-13 - 1e-15 && dev < 1e-9, "dev {dev}");
        // A counter change is structural.
        let mut c = a.clone();
        c.recomputes = 1;
        assert!(a.deviation(&c).is_none());
        // A missing token gap is structural.
        let mut d = a.clone();
        d.records[0].token_gaps.pop();
        assert!(a.deviation(&d).is_none());
    }

    #[test]
    fn tenant_digest_and_deviation_see_the_label() {
        let mut a = RunMetrics::default();
        a.push(rec(0.0, 0.5, 2.0, 5));
        let mut b = a.clone();
        b.records[0].tenant = 1;
        assert_ne!(a.digest(), b.digest(), "tenant label must be digested");
        assert!(a.deviation(&b).is_none(), "a relabeled record is structural");
    }

    #[test]
    fn slo_boundary_is_inclusive() {
        // Exactly-at-SLO latencies meet the SLO (`<=` semantics): the
        // boundary request counts toward goodput, an epsilon above does not.
        let spec = TenantSpec { weight: 1.0, ttft_slo: 0.5, tbt_slo: 0.01, admission_quota: 8 };
        assert!(trec(0, 0, 0.5, 0.01).meets_slo(&spec), "at-SLO must pass");
        assert!(!trec(0, 0, 0.5 + 1e-9, 0.01).meets_slo(&spec), "ttft above fails");
        assert!(!trec(0, 0, 0.5, 0.01 + 1e-9).meets_slo(&spec), "tbt above fails");
        // A single-token output has no gaps and cannot violate TBT.
        let mut single = trec(0, 0, 0.4, 0.0);
        single.token_gaps.clear();
        single.output_len = 1;
        assert!(single.meets_slo(&spec));
    }

    #[test]
    fn tenant_report_edge_cases() {
        let specs = vec![
            TenantSpec { weight: 2.0, ttft_slo: 1.0, tbt_slo: 0.05, admission_quota: 8 },
            TenantSpec { weight: 1.0, ttft_slo: 1.0, tbt_slo: 0.05, admission_quota: 8 },
            TenantSpec { weight: 1.0, ttft_slo: 1.0, tbt_slo: 0.05, admission_quota: 8 },
        ];
        let mut m = RunMetrics::default();
        // Tenant 0: one meeting, one violating TTFT. Tenant 1: all violate.
        // Tenant 2: zero requests.
        m.push(trec(0, 0, 0.5, 0.01));
        m.push(trec(1, 0, 2.0, 0.01));
        m.push(trec(2, 1, 3.0, 0.2));
        let rep = m.tenant_report(&specs);
        assert_eq!(rep.len(), 3);
        assert_eq!((rep[0].completed, rep[0].slo_ok), (2, 1));
        assert!((rep[0].attainment - 0.5).abs() < 1e-12);
        assert_eq!((rep[1].completed, rep[1].slo_ok), (2 - 1, 0));
        assert_eq!(rep[1].attainment, 0.0, "all-violating tenant attains 0");
        assert_eq!(rep[2].completed, 0);
        assert_eq!(rep[2].attainment, 1.0, "zero-request tenant attains vacuously");
        assert_eq!(rep[2].goodput, 0.0);
        // Fleet goodput = total slo_ok / span.
        let span = m.span();
        assert!((m.goodput(&specs) - 1.0 / span).abs() < 1e-12);
        // Empty run: no rows with requests, zero goodput, no panic.
        let empty = RunMetrics::default();
        let rep = empty.tenant_report(&specs);
        assert!(rep.iter().all(|s| s.completed == 0 && s.attainment == 1.0));
        assert_eq!(empty.goodput(&specs), 0.0);
        // A label beyond the spec table falls back to the permissive default.
        let mut unlabeled = RunMetrics::default();
        unlabeled.push(trec(0, 7, 0.5, 0.01));
        let rep = unlabeled.tenant_report(&[]);
        assert_eq!(rep.len(), 8);
        assert_eq!((rep[7].completed, rep[7].slo_ok), (1, 1));
    }

    #[test]
    fn tenant_report_survives_merge() {
        let specs = vec![
            TenantSpec { weight: 1.0, ttft_slo: 1.0, tbt_slo: 0.05, admission_quota: 8 },
            TenantSpec { weight: 1.0, ttft_slo: 1.0, tbt_slo: 0.05, admission_quota: 8 },
        ];
        let mut a = RunMetrics::default();
        a.push(trec(0, 0, 0.5, 0.01));
        let mut b = RunMetrics::default();
        b.push(trec(1, 1, 0.4, 0.01));
        b.push(trec(2, 1, 5.0, 0.01));
        a.merge(b);
        let rep = a.tenant_report(&specs);
        assert_eq!((rep[0].completed, rep[0].slo_ok), (1, 1));
        assert_eq!((rep[1].completed, rep[1].slo_ok), (2, 1));
        // Per-tenant counts sum across the merge; goodput uses the merged span.
        let total: usize = rep.iter().map(|s| s.completed).sum();
        assert_eq!(total, a.records.len());
        assert!((a.goodput(&specs) - 2.0 / a.span()).abs() < 1e-12);
    }

    #[test]
    fn run_histograms_match_records() {
        let mut m = RunMetrics::default();
        m.push(rec(0.0, 0.5, 2.0, 5));
        m.push(rec(1.0, 1.2, 4.0, 10));
        let th = m.ttft_histogram();
        assert_eq!(th.count(), 2);
        assert!((th.mean() - 0.35).abs() < 1e-12);
        let gh = m.tbt_histogram();
        assert_eq!(gh.count(), 4 + 9);
        assert!((gh.mean() - 0.01).abs() < 1e-12);
    }
}
