//! Streaming log-bucketed histogram for latency distributions.
//!
//! Used by long benches where storing every sample would be wasteful; exact
//! per-request records remain the source of truth for headline numbers.

/// Log-spaced histogram covering [1µs, ~1000s) with ~4% relative resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    lo: f64,
    ratio: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 512 buckets, geometric from 1e-6 s; ratio chosen to reach ~2000 s.
        let lo = 1e-6;
        let hi: f64 = 2000.0;
        let n = 512usize;
        let ratio = (hi / lo).powf(1.0 / n as f64);
        Histogram {
            buckets: vec![0; n + 2], // + underflow/overflow
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            lo,
            ratio,
        }
    }

    fn index(&self, x: f64) -> usize {
        if x < self.lo {
            return 0;
        }
        let i = ((x / self.lo).ln() / self.ratio.ln()).floor() as usize + 1;
        i.min(self.buckets.len() - 1)
    }

    pub fn record(&mut self, x: f64) {
        debug_assert!(x.is_finite() && x >= 0.0, "histogram sample {x}");
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let i = self.index(x);
        self.buckets[i] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile (bucket upper edge), `q` in [0,1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                if i == 0 {
                    return self.min;
                }
                let edge = self.lo * self.ratio.powi(i as i32);
                return edge.min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for x in [0.1, 0.2, 0.3] {
            h.record(x);
        }
        assert!((h.mean() - 0.2).abs() < 1e-12);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn quantile_within_resolution() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms..1s uniform
        }
        let p50 = h.quantile(0.5);
        assert!((p50 - 0.5).abs() / 0.5 < 0.06, "p50={p50}");
        let p95 = h.quantile(0.95);
        assert!((p95 - 0.95).abs() / 0.95 < 0.06, "p95={p95}");
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.1);
        b.record(0.3);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_quantile_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn single_sample_merge_pins_quantiles_to_the_sample() {
        // min/max clamping makes every quantile of a 1-sample histogram
        // exact, including after merging into an empty one.
        let mut empty = Histogram::new();
        let mut one = Histogram::new();
        one.record(0.123);
        empty.merge(&one);
        assert_eq!(empty.count(), 1);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(empty.quantile(q), 0.123, "q={q}");
        }
        assert!((empty.mean() - 0.123).abs() < 1e-12);
    }

    #[test]
    fn merge_of_disjoint_ranges_keeps_both_modes() {
        // One "replica" in the 10–100 µs regime, one in the 100–1000 s
        // regime; the merged quantiles must land in the correct mode.
        let mut low = Histogram::new();
        let mut high = Histogram::new();
        for i in 0..100 {
            low.record(1e-5 + i as f64 * 9e-7); // 10µs..~100µs
            high.record(100.0 + i as f64 * 9.0); // 100s..~1000s
        }
        low.merge(&high);
        assert_eq!(low.count(), 200);
        let q25 = low.quantile(0.25);
        assert!(q25 < 1e-3, "q25={q25} must come from the low mode");
        let q75 = low.quantile(0.75);
        assert!(q75 > 50.0, "q75={q75} must come from the high mode");
    }

    #[test]
    fn underflow_samples_report_the_true_minimum() {
        // Samples below the 1µs bucket floor land in the underflow bucket;
        // quantiles there return the exact recorded minimum, not the edge.
        let mut h = Histogram::new();
        h.record(1e-9);
        h.record(2e-9);
        assert_eq!(h.quantile(0.5), 1e-9);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn merged_percentiles_equal_concatenated_samples() {
        // Fleet-aggregation correctness: merging per-replica histograms
        // must yield the same percentiles as one histogram over the
        // concatenation of all samples (exactly), and both must agree with
        // the exact sample percentiles within the bucket resolution.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF1EE7);
        let mut shards: Vec<Vec<f64>> = Vec::new();
        for shard in 0..4 {
            // Deliberately different latency regimes per "replica".
            let scale = 10f64.powi(shard - 2); // 10ms .. 10s
            shards.push((0..500).map(|_| scale * (0.1 + rng.f64())).collect());
        }
        let mut merged = Histogram::new();
        for s in &shards {
            let mut h = Histogram::new();
            for &x in s {
                h.record(x);
            }
            merged.merge(&h);
        }
        let all: Vec<f64> = shards.concat();
        let mut concat = Histogram::new();
        for &x in &all {
            concat.record(x);
        }
        assert_eq!(merged.count(), all.len() as u64);
        assert!((merged.mean() - concat.mean()).abs() < 1e-9);
        for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
            let qm = merged.quantile(q);
            let qc = concat.quantile(q);
            assert_eq!(qm, qc, "merge must be exact at q={q}");
            // Against the exact (nearest-rank) percentile of the samples:
            // within the histogram's ~4–5% relative bucket resolution.
            let exact = crate::util::percentile(&all, q * 100.0);
            let rel = (qm - exact).abs() / exact.max(1e-12);
            assert!(rel < 0.06, "q={q}: hist {qm} vs exact {exact} (rel {rel:.3})");
        }
    }
}
