//! # Nexus — proactive intra-GPU disaggregation of prefill and decode
//!
//! Rust + JAX + Pallas reproduction of *"Proactive Intra-GPU Disaggregation
//! of Prefill and Decode in LLM Serving"* (Nexus, cs.DC 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack (see
//! `DESIGN.md`): it owns every part of the serving system — request routing,
//! phase-specific scheduling, KV-cache management, the contention-aware cost
//! model, the greedy SM-partition controller with hysteresis, five serving
//! engines (Nexus + four baselines), the GPU simulator substrate that stands
//! in for an NVIDIA L20, the workload generators, the multi-replica cluster
//! layer, and the benchmark harness that regenerates every table and figure
//! of the paper's evaluation.
//!
//! Layer 2 (JAX model) and Layer 1 (Pallas kernels) live under `python/` and
//! are only used at *build* time: `make artifacts` AOT-lowers them to HLO
//! text which [`runtime`] loads and executes through the PJRT C API (`xla`
//! crate) — Python is never on the request path. The PJRT path needs the
//! vendored `xla` crate closure and is gated behind the `pjrt` cargo
//! feature; the default build is dependency-free so the simulator stack
//! builds offline.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG, JSON, CLI, table formatting (offline image: no serde/clap/rand) |
//! | [`metrics`] | streaming histograms, TTFT/TBT/normalized latency, stage breakdown, fleet merge |
//! | [`model`] | transformer operator FLOPs/bytes (paper §2.2–2.3), model configs |
//! | [`gpusim`] | fluid-model GPU simulator: SM partitions, saturation, bandwidth contention |
//! | [`kv`] | paged KV-cache allocator, usage watermarks, swap + transfer buffers |
//! | [`costmodel`] | contention-aware analytical cost model (paper Eq. 5–9) + calibration |
//! | [`partition`] | dual-objective greedy SM search (Alg. 1) + hysteresis control |
//! | [`sched`] | SPF (Alg. 2), FCFS, chunked-prefill, MLFQ, radix-cache schedulers |
//! | [`engine`] | Nexus + vLLM-like, SGLang-like, FastServe, disaggregated P/D engines; stepping API |
//! | [`cluster`] | multi-replica fleet: pluggable routing, cost-model autoscaling, metric merge |
//! | [`trace`] | zero-cost tracing: lifecycle events, fleet time-series, Perfetto/JSONL export |
//! | [`workload`] | Table-1 dataset generators, Poisson + bursty/diurnal arrivals, trace I/O |
//! | [`coordinator`] | virtual-time serving loop, throughput search, experiment drivers |
//! | [`runtime`] | PJRT artifact loading + execution (real compute path, `pjrt` feature) |
//! | [`server`] | real-compute serving: threads + channels, wall-clock metrics (`pjrt` feature) |
//! | [`testing`] | mini property-testing harness (proptest is not vendored) |

pub mod cluster;
pub mod coordinator;
pub mod costmodel;
pub mod engine;
pub mod gpusim;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod partition;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod testing;
pub mod trace;
pub mod util;
pub mod workload;
