//! Contention-aware analytical cost model — paper §4.1.1 (Eq. 5–9).
//!
//! Predicts prefill/decode iteration latency under any SM split *without
//! executing*, from three ingredients:
//!
//! 1. **Two-regime compute curve (Eq. 7)** — latency scales `c/(r·C)` up to
//!    a per-operator-class saturation point `R_sat`, then flattens with a
//!    decay coefficient `λ`. `(C_eff, R_sat, λ)` per class come from a
//!    **one-time calibration pass** ([`calibrate`]) that profiles isolated
//!    kernels on the GPU substrate across an SM grid — mirroring the
//!    paper's per-(model, config) offline kernel profiling. No
//!    workload-specific retraining, no online feedback fitting.
//! 2. **Phase latency (Eq. 5–6)** — each phase is the sum over its
//!    operators of `max(T_compute, T_mem)`, capturing shifting bottlenecks.
//! 3. **Memory-contention model (Eq. 8–9)** — decode attention's effective
//!    bandwidth shrinks when it overlaps memory-bound prefill activity:
//!    `B_dec = m_d/(m_d+m_p1)·P_attn·B + m_d/(m_d+m_p2)·(1−P_attn)·B`,
//!    where `P_attn = T_prefill_attn / T_prefill` is the probability that a
//!    decode access overlaps prefill attention.

use crate::gpusim::GpuSpec;
use crate::model::{OpClass, OpWork};

/// Calibrated Eq.-7 parameters for one operator class.
#[derive(Debug, Clone, Copy)]
pub struct OpCurve {
    /// Effective peak throughput (FLOP/s at full allocation of this class).
    pub c_eff: f64,
    /// Saturation threshold `R_sat` ∈ (0, 1].
    pub r_sat: f64,
    /// Post-saturation decay coefficient `λ` (paper Eq. 7).
    pub lambda: f64,
}

impl OpCurve {
    /// Eq. 7: compute latency of `flops` at SM fraction `r`.
    pub fn compute_time(&self, flops: f64, r: f64) -> f64 {
        let r = r.clamp(1e-3, 1.0);
        if r <= self.r_sat {
            flops / (r * self.c_eff)
        } else {
            flops / (self.r_sat * self.c_eff) * (1.0 + self.lambda * (r - self.r_sat))
        }
    }
}

/// Snapshot of concurrent prefill activity used by the Eq. 8–9 contention
/// term when predicting decode latency.
///
/// Refinement over the paper's literal formulation: Eq. 9 weights bandwidth
/// shares by per-iteration byte *totals* (`m_p1`, `m_p2`). When the dense
/// operators' weight-read footprint dwarfs the attention KV traffic (any
/// small-chunk prefill on a multi-GB model), total-based shares *invert*
/// the Fig.-6a trend: growing prefill KV would predict *faster* decode. We
/// keep Eq. 8–9's window-probability × share structure but measure each
/// window's share from the concurrent demand **rates** (`bytes / window
/// duration`), which preserves the paper's two claimed dynamics — (1)
/// contention grows with prefill KV traffic, (2) stretching `T_prefill`
/// lowers `P_attn` and mitigates contention.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefillPressure {
    /// Probability a decode access overlaps prefill attention (Eq. 8).
    pub p_attn: f64,
    /// Prefill attention's bandwidth demand rate during its window (B/s):
    /// `m_p1 / T_attn`.
    pub r_attn: f64,
    /// Prefill dense operators' demand rate during the remaining window:
    /// `m_p2 / (T_prefill − T_attn)`.
    pub r_dense: f64,
}

/// Full per-phase latency prediction with the attention share needed to
/// derive [`PrefillPressure`].
#[derive(Debug, Clone, Copy)]
pub struct PhasePrediction {
    pub total: f64,
    /// Time attributed to memory-bound attention segments.
    pub attn_time: f64,
    pub pressure: PrefillPressure,
}

/// The calibrated model for one (GPU, model dtype) configuration.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub gpu: GpuSpec,
    curves: Vec<OpCurve>, // indexed by OpClass discriminant order
}

fn class_index(c: OpClass) -> usize {
    OpClass::all().iter().position(|&x| x == c).unwrap()
}

impl CostModel {
    pub fn curve(&self, class: OpClass) -> &OpCurve {
        &self.curves[class_index(class)]
    }

    /// Memory time of one operator at SM fraction `r`. A partition too
    /// small to keep enough loads in flight cannot reach peak bandwidth:
    /// achievable bandwidth is capped at `B·min(r / r_memsat, 1)` (the same
    /// `mem_sat_frac` the substrate exhibits; it is a hardware constant
    /// covered by the one-time profiling pass).
    fn mem_time(&self, op: &OpWork, r: f64) -> f64 {
        if op.class == OpClass::Comm {
            op.bytes / self.gpu.link_bw
        } else {
            op.bytes / self.gpu.bw_cap(r)
        }
    }

    /// Eq. 5: prefill iteration latency at SM fraction `r_p`, plus the
    /// pressure snapshot that feeds decode's Eq. 8–9 term.
    pub fn prefill(&self, ops: &[OpWork], r_p: f64) -> PhasePrediction {
        let mut total = 0.0;
        let mut attn_time = 0.0;
        let mut m_p1 = 0.0;
        let mut m_p2 = 0.0;
        for op in ops {
            let tc = if op.class == OpClass::Comm {
                0.0
            } else {
                self.curve(op.class).compute_time(op.flops, r_p)
            };
            let tm = self.mem_time(op, r_p);
            let t = tc.max(tm);
            total += t;
            if op.class == OpClass::AttnPrefill {
                attn_time += t;
                m_p1 += op.bytes;
            } else {
                m_p2 += op.bytes;
            }
        }
        let p_attn = if total > 0.0 { attn_time / total } else { 0.0 };
        let dense_time = (total - attn_time).max(1e-12);
        PhasePrediction {
            total,
            attn_time,
            pressure: PrefillPressure {
                p_attn,
                r_attn: if attn_time > 0.0 { m_p1 / attn_time } else { 0.0 },
                r_dense: m_p2 / dense_time,
            },
        }
    }

    /// Eq. 9 (rate-based shares — see [`PrefillPressure`]): effective
    /// decode-attention bandwidth under prefill pressure. Decode attention
    /// alone would saturate the bus (`r_d = B`), so its share of each
    /// window is `B / (B + r_window)`.
    pub fn decode_bandwidth(&self, m_d: f64, pressure: &PrefillPressure) -> f64 {
        let b = self.gpu.mem_bw;
        if m_d <= 0.0 {
            return b;
        }
        let p = pressure.p_attn.clamp(0.0, 1.0);
        // Each window's rates can't exceed what the bus physically serves.
        let r_attn = pressure.r_attn.min(b);
        let r_dense = pressure.r_dense.min(b);
        let share_attn = b / (b + r_attn);
        let share_dense = b / (b + r_dense);
        (share_attn * p * b + share_dense * (1.0 - p) * b).min(b)
    }

    /// Eq. 6: decode iteration latency at SM fraction `r_d`, optionally
    /// under concurrent prefill pressure.
    ///
    /// Generalization of the paper's Eq. 8–9 scoping: the paper applies the
    /// contention bandwidth only to decode *attention* ("which dominates
    /// bandwidth usage") — true for large batches over long contexts. At
    /// small decode batches the *weight stream* dominates decode traffic
    /// and contends identically on the shared bus, so we apply the
    /// contended bandwidth to every decode operator's memory side.
    pub fn decode(&self, ops: &[OpWork], r_d: f64, pressure: Option<&PrefillPressure>) -> f64 {
        let mut total = 0.0;
        for op in ops {
            let tc = if op.class == OpClass::Comm {
                0.0
            } else {
                self.curve(op.class).compute_time(op.flops, r_d)
            };
            let tm = if op.class == OpClass::Comm {
                op.bytes / self.gpu.link_bw
            } else {
                let contended = match pressure {
                    Some(p) => self.decode_bandwidth(op.bytes, p),
                    None => self.gpu.mem_bw,
                };
                // Both limits apply: contention on the bus and the SM
                // share's achievable-bandwidth ceiling.
                op.bytes / contended.min(self.gpu.bw_cap(r_d))
            };
            total += tc.max(tm);
        }
        total
    }

    /// Convenience: predict a phase by kind (used by the Alg.-1 controller,
    /// which treats `CostModel(phase, R)` as a black box).
    pub fn phase_time(
        &self,
        prefill: bool,
        ops: &[OpWork],
        r: f64,
        pressure: Option<&PrefillPressure>,
    ) -> f64 {
        if prefill {
            self.prefill(ops, r).total
        } else {
            self.decode(ops, r, pressure)
        }
    }
}

/// Grid of SM fractions used for calibration (one point per SM group).
fn calibration_grid(gpu: &GpuSpec) -> Vec<f64> {
    let groups = (gpu.sm_count + gpu.sm_group - 1) / gpu.sm_group;
    (1..=groups).map(|g| g as f64 / groups as f64).collect()
}

/// One-time kernel-profiling pass (paper §4.1.1 / §5): run each operator
/// class isolated on the GPU substrate across the SM grid, then fit the
/// Eq.-7 two-regime curve per class.
///
/// Fit procedure per class, over measured latencies `T(r)` of a reference
/// kernel with FLOPs `c`:
/// * for each candidate `R_sat` on the grid, estimate
///   `C_eff = mean over r ≤ R_sat of c / (T(r)·r)` (sub-saturation inverse
///   scaling) and `λ` by least squares on the post-saturation residual
///   `T(r)·R_sat·C_eff/c − 1 = λ·(r − R_sat)`;
/// * keep the `(R_sat, C_eff, λ)` with minimum total squared relative error.
pub fn calibrate(gpu: &GpuSpec) -> CostModel {
    let grid = calibration_grid(gpu);
    let mut curves = Vec::new();
    for &class in OpClass::all() {
        if class == OpClass::Comm {
            curves.push(OpCurve {
                c_eff: gpu.link_bw,
                r_sat: 1.0,
                lambda: 0.0,
            });
            continue;
        }
        // Reference kernel: pure compute so the curve isolates SM scaling.
        let c = 1.0e12;
        let op = OpWork {
            class,
            flops: c,
            bytes: 1.0, // negligible memory side
        };
        let meas: Vec<(f64, f64)> = grid
            .iter()
            .map(|&r| (r, gpu.solo_time(&op, r) - gpu.launch_overhead))
            .collect();

        let mut best: Option<(f64, OpCurve)> = None;
        for (i, &(r_sat, _)) in meas.iter().enumerate() {
            if i == 0 {
                continue; // need at least one sub-saturation point
            }
            let sub = &meas[..=i];
            let c_eff =
                sub.iter().map(|&(r, t)| c / (t * r)).sum::<f64>() / sub.len() as f64;
            let t_sat = c / (r_sat * c_eff);
            let post = &meas[i + 1..];
            let lambda = if post.is_empty() {
                0.0
            } else {
                let mut num = 0.0;
                let mut den = 0.0;
                for &(r, t) in post {
                    let x = r - r_sat;
                    let y = t / t_sat - 1.0;
                    num += x * y;
                    den += x * x;
                }
                if den > 0.0 {
                    num / den
                } else {
                    0.0
                }
            };
            let cand = OpCurve {
                c_eff,
                r_sat,
                lambda,
            };
            let err: f64 = meas
                .iter()
                .map(|&(r, t)| {
                    let p = cand.compute_time(c, r);
                    let e = (p - t) / t;
                    e * e
                })
                .sum();
            if best.as_ref().map(|(b, _)| err < *b).unwrap_or(true) {
                best = Some((err, cand));
            }
        }
        curves.push(best.expect("calibration grid non-empty").1);
    }
    CostModel { gpu: *gpu, curves }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::iteration_time_isolated;
    use crate::model::ModelConfig;

    fn cm() -> CostModel {
        calibrate(&GpuSpec::l20())
    }

    #[test]
    fn curve_monotone_decreasing_then_flat() {
        let m = cm();
        let cur = m.curve(OpClass::Ffn);
        let t30 = cur.compute_time(1e12, 0.3);
        let t60 = cur.compute_time(1e12, 0.6);
        let t90 = cur.compute_time(1e12, 0.9);
        assert!(t60 < t30);
        // Post-saturation change must be small relative to sub-saturation.
        let gain_low = (t30 - t60) / t30;
        let gain_high = ((t60 - t90) / t60).abs();
        assert!(gain_low > 1.3 * gain_high, "low {gain_low} high {gain_high}");
        // Decode attention saturates by ~30% SMs (Fig. 5c): past that the
        // fitted curve is nearly flat in both directions.
        let dec = m.curve(OpClass::AttnDecode);
        let d_mid = ((dec.compute_time(1e12, 0.3) - dec.compute_time(1e12, 0.6))
            / dec.compute_time(1e12, 0.3))
        .abs();
        let d_high = ((dec.compute_time(1e12, 0.6) - dec.compute_time(1e12, 0.9))
            / dec.compute_time(1e12, 0.6))
        .abs();
        assert!(d_mid < 0.15, "decode 0.3→0.6 change {d_mid} should be flat");
        assert!(d_high < 0.15, "decode 0.6→0.9 change {d_high} should be flat");
    }

    #[test]
    fn decode_attn_saturates_earlier_than_ffn() {
        let m = cm();
        assert!(
            m.curve(OpClass::AttnDecode).r_sat < m.curve(OpClass::Ffn).r_sat,
            "decode attention must saturate earlier: {} vs {}",
            m.curve(OpClass::AttnDecode).r_sat,
            m.curve(OpClass::Ffn).r_sat
        );
    }

    #[test]
    fn calibration_matches_substrate_isolated() {
        // The fitted model should predict isolated iteration latency within
        // 15% across the SM grid — the paper's "transferable one-time pass".
        let gpu = GpuSpec::l20();
        let m = cm();
        let cfg = ModelConfig::qwen3b();
        let pre = cfg.prefill_ops(512, 512.0 * 2048.0, 2048.0, 1);
        let dec = cfg.decode_ops(32, 32.0 * 1500.0);
        for r in [0.25, 0.5, 0.75, 1.0] {
            let truth_p = iteration_time_isolated(&gpu, &pre, r);
            let pred_p = m.prefill(&pre, gpu.quantize(r)).total;
            let rel_p = (pred_p - truth_p).abs() / truth_p;
            assert!(rel_p < 0.20, "prefill r={r}: pred {pred_p} truth {truth_p}");
            let truth_d = iteration_time_isolated(&gpu, &dec, r);
            let pred_d = m.decode(&dec, gpu.quantize(r), None);
            let rel_d = (pred_d - truth_d).abs() / truth_d;
            assert!(rel_d < 0.20, "decode r={r}: pred {pred_d} truth {truth_d}");
        }
    }

    #[test]
    fn contention_shrinks_decode_bandwidth() {
        let m = cm();
        let no = PrefillPressure::default();
        let heavy = PrefillPressure {
            p_attn: 0.5,
            r_attn: m.gpu.mem_bw,        // attention window saturates the bus
            r_dense: 0.1 * m.gpu.mem_bw, // dense ops are compute-bound
        };
        let m_d = 4.0e9;
        let b0 = m.decode_bandwidth(m_d, &no);
        let b1 = m.decode_bandwidth(m_d, &heavy);
        assert!((b0 - m.gpu.mem_bw).abs() < 1.0);
        assert!(b1 < 0.8 * b0, "pressure must cut bandwidth: {b1} vs {b0}");
    }

    #[test]
    fn decode_latency_grows_with_prefill_kv() {
        // Fig. 6a shape: decode latency rises as the co-running prefill's
        // KV footprint grows, decode workload held constant.
        let m = cm();
        let cfg = ModelConfig::qwen3b();
        let dec = cfg.decode_ops(16, 16.0 * 2000.0);
        let ts: Vec<f64> = [2000.0, 6000.0, 10000.0]
            .iter()
            .map(|&kv_len| {
                let pre = cfg.prefill_ops(512, 512.0 * kv_len, kv_len, 0);
                let pp = m.prefill(&pre, 0.6).pressure;
                m.decode(&dec, 0.4, Some(&pp))
            })
            .collect();
        // Overall trend must be upward. (Paper measures +36% on real
        // hardware; the fluid average-rate model reproduces the sign and
        // monotonicity but a smaller magnitude — see EXPERIMENTS.md Fig 6.)
        assert!(ts[2] > 1.01 * ts[0], "2k→10k: {:?} not increasing", ts);
        for w in ts.windows(2) {
            assert!(w[1] > 0.97 * w[0], "large regression within {ts:?}");
        }
        // And contention must hurt vs no-pressure decode.
        let free = m.decode(&dec, 0.4, None);
        assert!(ts[2] > free, "pressure {:.5} must exceed free {:.5}", ts[2], free);
    }

    #[test]
    fn p_attn_between_zero_and_one() {
        let m = cm();
        let cfg = ModelConfig::llama8b();
        for kv in [100.0, 5000.0, 50000.0] {
            let pre = cfg.prefill_ops(256, 256.0 * kv, kv, 0);
            let p = m.prefill(&pre, 0.5).pressure;
            assert!((0.0..=1.0).contains(&p.p_attn), "p_attn {}", p.p_attn);
        }
    }

    #[test]
    fn more_decode_sm_reduces_latency_until_saturation() {
        let m = cm();
        let cfg = ModelConfig::qwen3b();
        let dec = cfg.decode_ops(64, 64.0 * 1024.0);
        let t2 = m.decode(&dec, 0.2, None);
        let t4 = m.decode(&dec, 0.4, None);
        let t8 = m.decode(&dec, 0.8, None);
        assert!(t4 < t2);
        // Past saturation the change is marginal (<10% per paper §3.2).
        assert!((t8 - t4).abs() / t4 < 0.25, "t4 {t4} t8 {t8}");
    }
}
