//! Fluid-model GPU simulator — the hardware substrate for every experiment.
//!
//! The paper runs on an NVIDIA L20 partitioned with MPS / CUDA Green
//! Context. No GPU is available in this environment, so this module
//! implements the closest synthetic equivalent that exercises the same
//! control-system code paths (DESIGN.md §2):
//!
//! * **SM partitioning** — streams (≈ green contexts) own a fraction of the
//!   SM pool, quantized to hardware SM groups. In-flight kernels keep the
//!   partition they launched with (non-preemptive, like real green-context
//!   switching); new kernels pick up the new partition.
//! * **Diminishing compute returns (§3.2)** — each operator class has a
//!   smooth saturation curve `eff(r) = s·(1 − e^(−a·r/s))`: FFN keeps
//!   scaling, decode attention saturates around 30–40% of SMs. These curves
//!   are *ground truth*; the analytical cost model (paper Eq. 7) only
//!   approximates them with its two-regime fit, so calibration error is
//!   real, not circular.
//! * **Memory-bandwidth contention (§3.3)** — concurrently executing
//!   kernels share HBM bandwidth proportionally to their instantaneous
//!   demand (fluid fixed-point), reproducing the "prefill KV reads slow
//!   decode" effect of Fig. 6 mechanistically rather than via the model's
//!   overlap-probability approximation (Eq. 8–9).
//!
//! Kernels within one stream execute serially (CUDA stream semantics);
//! streams execute concurrently and contend. The engine layer submits
//! per-iteration operator lists ([`crate::model::OpWork`]) tagged with a
//! batch id and receives completion events in virtual time.

use crate::model::{OpClass, OpWork};
use std::collections::VecDeque;

/// Physical GPU description. Defaults model an NVIDIA L20.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub sm_count: usize,
    /// Green-context partition granularity (SMs per group).
    pub sm_group: usize,
    /// Peak dense fp16/bf16 throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub mem_bw: f64,
    /// HBM capacity (bytes).
    pub hbm_bytes: f64,
    /// Inter-GPU link bandwidth (bytes/s) — PCIe Gen4 x16 effective.
    pub link_bw: f64,
    /// Stall applied to a stream when its partition is reconfigured (s).
    pub switch_overhead: f64,
    /// Fraction of SMs needed to saturate HBM bandwidth.
    pub mem_sat_frac: f64,
    /// Fixed per-kernel launch latency (s).
    pub launch_overhead: f64,
}

impl GpuSpec {
    /// NVIDIA L20: 92 SMs, 48 GB GDDR6, 864 GB/s, ~119.5 TFLOPS fp16.
    pub fn l20() -> Self {
        GpuSpec {
            name: "L20",
            sm_count: 92,
            sm_group: 8,
            peak_flops: 119.5e12,
            mem_bw: 864.0e9,
            hbm_bytes: 48.0 * 1024.0 * 1024.0 * 1024.0,
            link_bw: 26.0e9,
            switch_overhead: 50e-6,
            mem_sat_frac: 0.25,
            launch_overhead: 6e-6,
        }
    }

    /// Quantize an SM fraction to whole SM groups (green-context constraint),
    /// keeping at least one group.
    pub fn quantize(&self, frac: f64) -> f64 {
        let groups = (self.sm_count + self.sm_group - 1) / self.sm_group;
        let g = (frac * groups as f64).round().max(1.0).min(groups as f64);
        g / groups as f64
    }

    /// Ground-truth compute saturation: effective parallel fraction for an
    /// operator class running on `r` of the SMs. Monotonic, concave,
    /// `eff(r) ≤ min(r·a_boost, s)`.
    pub fn eff_compute(&self, class: OpClass, r: f64) -> f64 {
        let (s, a) = match class {
            // (plateau, initial slope) — FFN scales furthest; decode-attention
            // GEMV saturates earliest (Fig. 5b/5c).
            OpClass::Ffn => (0.92, 2.6),
            OpClass::Qkv => (0.72, 3.0),
            OpClass::AttnLinear => (0.70, 3.0),
            OpClass::AttnPrefill => (0.80, 2.8),
            OpClass::AttnDecode => (0.34, 5.0),
            OpClass::LmHead => (0.75, 2.8),
            OpClass::Comm => (1.0, 1.0), // not compute-scaled
        };
        s * (1.0 - (-a * r / s).exp())
    }

    /// Max HBM bandwidth reachable by a kernel on `r` of the SMs.
    pub fn bw_cap(&self, r: f64) -> f64 {
        self.mem_bw * (r / self.mem_sat_frac).min(1.0)
    }

    /// Duration of one kernel running *alone* on fraction `r`.
    pub fn solo_time(&self, op: &OpWork, r: f64) -> f64 {
        if op.class == OpClass::Comm {
            return op.bytes / self.link_bw + self.launch_overhead;
        }
        let tc = op.flops / (self.peak_flops * self.eff_compute(op.class, r)).max(1.0);
        let tm = op.bytes / self.bw_cap(r).max(1.0);
        tc.max(tm) + self.launch_overhead
    }
}

/// Completion event: the tagged batch on `stream` finished at `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub time: f64,
    pub stream: usize,
    pub tag: u64,
}

/// Per-kernel trace record (enabled via [`Sim::record_kernels`]) — feeds the
/// kernel-level breakdowns of Fig. 4b / 5b / 5c.
#[derive(Debug, Clone, Copy)]
pub struct KernelTrace {
    pub class: OpClass,
    pub stream: usize,
    pub start: f64,
    pub end: f64,
    pub sm_frac: f64,
    pub tag: u64,
}

#[derive(Debug, Clone, Copy)]
struct Active {
    op: OpWork,
    tag: u64,
    /// Partition fraction captured at launch (non-preemptive semantics).
    r: f64,
    /// Fraction of the kernel's work completed.
    progress: f64,
    /// Fixed compute-side duration (doesn't depend on contention).
    tc: f64,
    start: f64,
    last_in_batch: bool,
}

#[derive(Debug, Default)]
struct Stream {
    queue: VecDeque<(OpWork, u64, bool)>,
    active: Option<Active>,
    sm_frac: f64,
    /// Absolute time before which the stream may not launch (switch stall).
    stalled_until: f64,
}

/// Virtual-time GPU simulator with `n` concurrent streams.
#[derive(Debug)]
pub struct Sim {
    pub spec: GpuSpec,
    now: f64,
    streams: Vec<Stream>,
    /// Completions that occurred during the last advance.
    pending: VecDeque<Completion>,
    pub record_kernels: bool,
    pub kernel_trace: Vec<KernelTrace>,
    /// Cumulative busy time per stream (utilization accounting).
    pub busy_time: Vec<f64>,
    // scratch buffers reused across rate computations (hot path)
    scratch_t: Vec<f64>,
    scratch_d: Vec<f64>,
    scratch_r: Vec<f64>,
    /// Rates are invalidated only by launches, completions and partition
    /// changes — not by time passing — so peek/advance pairs share one
    /// fixed-point solve.
    rates_dirty: bool,
}

impl Sim {
    pub fn new(spec: GpuSpec, n_streams: usize) -> Self {
        let mut streams = Vec::with_capacity(n_streams);
        for _ in 0..n_streams {
            streams.push(Stream {
                sm_frac: spec.quantize(1.0 / n_streams as f64),
                ..Default::default()
            });
        }
        Sim {
            spec,
            now: 0.0,
            streams,
            pending: VecDeque::new(),
            record_kernels: false,
            kernel_trace: Vec::new(),
            busy_time: vec![0.0; n_streams],
            scratch_t: Vec::new(),
            scratch_d: Vec::new(),
            scratch_r: Vec::new(),
            rates_dirty: true,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn sm_frac(&self, stream: usize) -> f64 {
        self.streams[stream].sm_frac
    }

    /// Reconfigure a stream's SM partition (quantized to SM groups). The
    /// in-flight kernel keeps its old allocation; the stream pays
    /// `switch_overhead` before its next launch.
    pub fn set_partition(&mut self, stream: usize, frac: f64) {
        let q = self.spec.quantize(frac);
        let st = &mut self.streams[stream];
        if (q - st.sm_frac).abs() > 1e-9 {
            st.sm_frac = q;
            st.stalled_until = st.stalled_until.max(self.now + self.spec.switch_overhead);
            // Note: in-flight kernels keep their captured `r`, so current
            // rates are unaffected; the next launch picks up the change.
        }
    }

    /// Enqueue the operator list of one batch iteration on `stream`; a
    /// [`Completion`] with `tag` fires when the last operator finishes.
    pub fn submit(&mut self, stream: usize, ops: &[OpWork], tag: u64) {
        assert!(!ops.is_empty(), "empty op list");
        let st = &mut self.streams[stream];
        for (i, op) in ops.iter().enumerate() {
            st.queue.push_back((*op, tag, i + 1 == ops.len()));
        }
        self.refill(stream);
    }

    /// True if the stream has queued or in-flight work.
    pub fn busy(&self, stream: usize) -> bool {
        let st = &self.streams[stream];
        st.active.is_some() || !st.queue.is_empty()
    }

    pub fn any_busy(&self) -> bool {
        (0..self.streams.len()).any(|s| self.busy(s))
    }

    fn refill(&mut self, stream: usize) {
        let st = &mut self.streams[stream];
        if st.active.is_none() {
            if let Some((op, tag, last)) = st.queue.pop_front() {
                self.rates_dirty = true;
                let r = st.sm_frac;
                let tc = if op.class == OpClass::Comm {
                    op.bytes / self.spec.link_bw
                } else {
                    op.flops / (self.spec.peak_flops * self.spec.eff_compute(op.class, r)).max(1.0)
                };
                // A partition switch stalls the stream: the kernel launches at
                // `start`, and progress only accrues after it (see advance_to).
                let start = self.now.max(st.stalled_until);
                st.active = Some(Active {
                    op,
                    tag,
                    r,
                    progress: 0.0,
                    tc: tc + self.spec.launch_overhead,
                    start,
                    last_in_batch: last,
                });
            }
        }
    }

    /// Instantaneous per-stream progress rates (1/duration), applying
    /// proportional HBM-bandwidth sharing via a short fixed-point loop.
    /// Results land in `self.scratch_r` (no allocation on the hot path);
    /// memoized until the active set / partitions change.
    fn rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        self.rates_dirty = false;
        let n = self.streams.len();
        let spec = self.spec;
        self.scratch_t.clear();
        self.scratch_t.resize(n, 0.0);
        self.scratch_d.clear();
        self.scratch_d.resize(n, 0.0);

        // Initial durations assuming each kernel gets its solo bandwidth cap.
        for (i, st) in self.streams.iter().enumerate() {
            if let Some(a) = &st.active {
                if a.op.class == OpClass::Comm {
                    self.scratch_t[i] = a.tc; // link-bound, no HBM contention
                } else {
                    let tm = a.op.bytes / spec.bw_cap(a.r).max(1.0);
                    self.scratch_t[i] = a.tc.max(tm);
                }
            }
        }

        // Fixed point: demand_i = bytes_i / T_i; if ΣD > B, split B
        // proportionally (capped by each kernel's own bw ceiling).
        for _ in 0..6 {
            let mut total = 0.0;
            for (i, st) in self.streams.iter().enumerate() {
                self.scratch_d[i] = 0.0;
                if let Some(a) = &st.active {
                    if a.op.class != OpClass::Comm && self.scratch_t[i] > 0.0 {
                        self.scratch_d[i] = a.op.bytes / self.scratch_t[i];
                        total += self.scratch_d[i];
                    }
                }
            }
            if total <= spec.mem_bw {
                break;
            }
            for (i, st) in self.streams.iter().enumerate() {
                if let Some(a) = &st.active {
                    if a.op.class != OpClass::Comm && self.scratch_d[i] > 0.0 {
                        let share =
                            (spec.mem_bw * self.scratch_d[i] / total).min(spec.bw_cap(a.r));
                        let tm = a.op.bytes / share.max(1.0);
                        self.scratch_t[i] = a.tc.max(tm);
                    }
                }
            }
        }

        self.scratch_r.clear();
        for i in 0..n {
            self.scratch_r.push(
                if self.streams[i].active.is_some() && self.scratch_t[i] > 0.0 {
                    1.0 / self.scratch_t[i]
                } else {
                    0.0
                },
            );
        }
    }

    /// Advance virtual time to `t`, processing every kernel completion on
    /// the way; returns the completions in time order.
    pub fn advance_to(&mut self, t: f64) -> Vec<Completion> {
        let mut out = Vec::new();
        self.advance_to_into(t, &mut out);
        out
    }

    /// Allocation-free [`Sim::advance_to`]: clears and fills `out` with the
    /// completions in time order. Engines reuse one buffer per step so the
    /// event hot path performs zero allocations (§Perf).
    pub fn advance_to_into(&mut self, t: f64, out: &mut Vec<Completion>) {
        assert!(t >= self.now - 1e-12, "time went backwards: {} -> {t}", self.now);
        out.clear();
        out.extend(self.pending.drain(..));
        while self.now < t {
            self.rates();
            // Time until the earliest active kernel finishes.
            let mut dt_min = t - self.now;
            let mut who: Option<usize> = None;
            for (i, st) in self.streams.iter().enumerate() {
                if let Some(a) = &st.active {
                    if self.scratch_r[i] > 0.0 {
                        let stall = (a.start - self.now).max(0.0);
                        let dt = stall + (1.0 - a.progress.max(0.0)) / self.scratch_r[i];
                        if dt < dt_min - 1e-15 {
                            dt_min = dt;
                            who = Some(i);
                        }
                    }
                }
            }
            let dt = dt_min.max(0.0);
            // Progress every active kernel by dt (minus any launch stall).
            for (i, st) in self.streams.iter_mut().enumerate() {
                if let Some(a) = &mut st.active {
                    let stall = (a.start - self.now).max(0.0);
                    let run = (dt - stall).max(0.0);
                    a.progress = a.progress.max(0.0) + run * self.scratch_r[i];
                    self.busy_time[i] += run;
                }
            }
            self.now += dt;
            match who {
                Some(i) => {
                    let a = self.streams[i].active.take().unwrap();
                    self.rates_dirty = true;
                    if self.record_kernels {
                        self.kernel_trace.push(KernelTrace {
                            class: a.op.class,
                            stream: i,
                            start: a.start,
                            end: self.now,
                            sm_frac: a.r,
                            tag: a.tag,
                        });
                    }
                    if a.last_in_batch {
                        out.push(Completion {
                            time: self.now,
                            stream: i,
                            tag: a.tag,
                        });
                    }
                    self.refill(i);
                }
                None => {
                    // No completion before t: idle or partial progress only.
                    self.now = t;
                    break;
                }
            }
        }
    }

    /// Time of the next kernel completion if no new work arrives.
    pub fn peek_next_completion(&mut self) -> Option<f64> {
        if !self.pending.is_empty() {
            return Some(self.now);
        }
        self.rates();
        let mut best: Option<f64> = None;
        for (i, st) in self.streams.iter().enumerate() {
            if let Some(a) = &st.active {
                if self.scratch_r[i] > 0.0 {
                    let stall = (a.start - self.now).max(0.0);
                    let dt = stall + (1.0 - a.progress.max(0.0)) / self.scratch_r[i];
                    let t = self.now + dt;
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
            }
        }
        best
    }

    /// Run until all queues drain; returns every completion.
    pub fn drain(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while self.any_busy() {
            let t = self
                .peek_next_completion()
                .expect("busy sim must have a next completion");
            out.extend(self.advance_to(t + 1e-12));
        }
        out
    }
}

/// Duration of one iteration's ops run back-to-back on a single stream with
/// SM fraction `r`, nothing else running (used by calibration and Fig. 5).
pub fn iteration_time_isolated(spec: &GpuSpec, ops: &[OpWork], r: f64) -> f64 {
    let rq = spec.quantize(r);
    ops.iter().map(|o| spec.solo_time(o, rq)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn op(class: OpClass, flops: f64, bytes: f64) -> OpWork {
        OpWork { class, flops, bytes }
    }

    #[test]
    fn quantize_respects_groups() {
        let s = GpuSpec::l20();
        let q = s.quantize(0.5);
        let groups = 12.0; // ceil(92/8)
        assert!((q * groups).fract().abs() < 1e-9);
        assert!(s.quantize(0.0) > 0.0, "at least one group");
        assert_eq!(s.quantize(1.0), 1.0);
    }

    #[test]
    fn eff_compute_monotone_and_saturating() {
        let s = GpuSpec::l20();
        for class in [OpClass::Ffn, OpClass::AttnDecode, OpClass::Qkv] {
            let mut prev = 0.0;
            for i in 1..=10 {
                let e = s.eff_compute(class, i as f64 / 10.0);
                assert!(e > prev, "{class} must be monotone");
                prev = e;
            }
        }
        // Decode attention saturates far below FFN.
        assert!(s.eff_compute(OpClass::AttnDecode, 1.0) < 0.4);
        assert!(s.eff_compute(OpClass::Ffn, 1.0) > 0.8);
        // Diminishing returns: marginal gain 0.3→0.4 exceeds 0.7→0.8.
        let d1 = s.eff_compute(OpClass::Ffn, 0.4) - s.eff_compute(OpClass::Ffn, 0.3);
        let d2 = s.eff_compute(OpClass::Ffn, 0.8) - s.eff_compute(OpClass::Ffn, 0.7);
        assert!(d1 > d2);
    }

    #[test]
    fn single_kernel_runs_at_roofline() {
        let s = GpuSpec::l20();
        let mut sim = Sim::new(s, 1);
        sim.set_partition(0, 1.0);
        // Pure-compute kernel: 1e12 flops of FFN on full GPU.
        sim.submit(0, &[op(OpClass::Ffn, 1.0e12, 1.0e6)], 7);
        let done = sim.drain();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tag, 7);
        let expect = 1.0e12 / (s.peak_flops * s.eff_compute(OpClass::Ffn, 1.0));
        let rel = (done[0].time - expect).abs() / expect;
        assert!(rel < 0.05, "time {} vs {}", done[0].time, expect);
    }

    #[test]
    fn streams_serialize_within_and_overlap_across() {
        let s = GpuSpec::l20();
        let mut sim = Sim::new(s, 2);
        sim.set_partition(0, 0.5);
        sim.set_partition(1, 0.5);
        let k = op(OpClass::Ffn, 5.0e11, 1.0e6);
        // Two kernels on one stream = serial.
        sim.submit(0, &[k], 1);
        sim.submit(0, &[k], 2);
        let done = sim.drain();
        let t_serial = done.last().unwrap().time;

        let mut sim2 = Sim::new(s, 2);
        sim2.set_partition(0, 0.5);
        sim2.set_partition(1, 0.5);
        sim2.submit(0, &[k], 1);
        sim2.submit(1, &[k], 2);
        let done2 = sim2.drain();
        let t_parallel = done2.last().unwrap().time;
        assert!(
            t_parallel < 0.6 * t_serial,
            "parallel {t_parallel} vs serial {t_serial}"
        );
    }

    #[test]
    fn bandwidth_contention_slows_memory_bound_kernels() {
        let s = GpuSpec::l20();
        // Memory-bound kernel alone...
        let mem = op(OpClass::AttnDecode, 1.0e9, 5.0e9);
        let mut solo = Sim::new(s, 2);
        solo.set_partition(0, 0.5);
        solo.set_partition(1, 0.5);
        solo.submit(0, &[mem], 1);
        let t_solo = solo.drain().last().unwrap().time;

        // ...vs co-running with a bandwidth-hungry prefill-attention kernel.
        let mut both = Sim::new(s, 2);
        both.set_partition(0, 0.5);
        both.set_partition(1, 0.5);
        both.submit(0, &[mem], 1);
        both.submit(1, &[op(OpClass::AttnPrefill, 1.0e9, 20.0e9)], 2);
        let done = both.drain();
        let t_mem = done.iter().find(|c| c.tag == 1).unwrap().time;
        assert!(
            t_mem > 1.3 * t_solo,
            "contention should inflate decode: {t_mem} vs {t_solo}"
        );
    }

    #[test]
    fn inflight_kernel_keeps_old_partition() {
        let s = GpuSpec::l20();
        let mut sim = Sim::new(s, 1);
        sim.set_partition(0, 1.0);
        sim.submit(0, &[op(OpClass::Ffn, 1.0e12, 1.0e6)], 1);
        // Shrink partition mid-flight: completion time must match full-SM run.
        let mid = sim.peek_next_completion().unwrap() / 2.0;
        sim.advance_to(mid);
        sim.set_partition(0, 0.1);
        let done = sim.drain();
        let expect = 1.0e12 / (s.peak_flops * s.eff_compute(OpClass::Ffn, 1.0));
        let rel = (done[0].time - expect).abs() / expect;
        assert!(rel < 0.05, "{} vs {}", done[0].time, expect);
    }

    #[test]
    fn iteration_time_decreases_with_sm_then_flattens() {
        let s = GpuSpec::l20();
        let m = ModelConfig::qwen3b();
        let ops = m.prefill_ops(512, 512.0 * 512.0, 512.0, 0);
        let t30 = iteration_time_isolated(&s, &ops, 0.3);
        let t40 = iteration_time_isolated(&s, &ops, 0.4);
        let t70 = iteration_time_isolated(&s, &ops, 0.7);
        let t80 = iteration_time_isolated(&s, &ops, 0.8);
        assert!(t40 < t30 && t80 <= t70);
        let gain_low = (t30 - t40) / t30;
        let gain_high = (t70 - t80) / t70;
        assert!(
            gain_low > gain_high,
            "diminishing returns: {gain_low} vs {gain_high}"
        );
    }

    #[test]
    fn advance_to_without_work_is_idle() {
        let mut sim = Sim::new(GpuSpec::l20(), 2);
        let done = sim.advance_to(5.0);
        assert!(done.is_empty());
        assert_eq!(sim.now(), 5.0);
        assert!(!sim.any_busy());
    }

    #[test]
    fn kernel_trace_records() {
        let s = GpuSpec::l20();
        let mut sim = Sim::new(s, 1);
        sim.record_kernels = true;
        sim.set_partition(0, 1.0);
        sim.submit(0, &[op(OpClass::Qkv, 1e10, 1e8), op(OpClass::Ffn, 1e11, 1e8)], 3);
        sim.drain();
        assert_eq!(sim.kernel_trace.len(), 2);
        assert_eq!(sim.kernel_trace[0].class, OpClass::Qkv);
        assert!(sim.kernel_trace[0].end <= sim.kernel_trace[1].start + 1e-12);
    }

    #[test]
    fn comm_kernel_uses_link_bandwidth() {
        let s = GpuSpec::l20();
        let mut sim = Sim::new(s, 1);
        let bytes = 2.6e9; // 100 ms on a 26 GB/s link
        sim.submit(0, &[op(OpClass::Comm, 0.0, bytes)], 1);
        let done = sim.drain();
        let expect = bytes / s.link_bw;
        assert!((done[0].time - expect).abs() / expect < 0.01);
    }
}
