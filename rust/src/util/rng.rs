//! Deterministic PRNG — splitmix64 seeding + xoshiro256++ core.
//!
//! `rand` is not vendored in this image; serving simulations must be
//! reproducible anyway, so every workload/bench takes an explicit seed.

/// xoshiro256++ PRNG (public-domain algorithm by Blackman & Vigna),
/// seeded via splitmix64 so that any u64 seed yields a good state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift bounded sampling (Lemire); bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *underlying* normal mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang squeeze (k ≥ 1) with the
    /// `U^(1/k)` boost for k < 1. Mean `k·θ`, variance `k·θ²` — used by the
    /// Gamma-modulated (doubly-stochastic) arrival process in `workload`.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "gamma({shape}, {scale})");
        if shape < 1.0 {
            // Gamma(k) = Gamma(k+1) · U^(1/k)
            let boost = self.f64().max(1e-300).powf(1.0 / shape);
            return self.gamma(shape + 1.0, scale) * boost;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * scale;
            }
        }
    }

    /// Fork an independent stream (for per-request decisions that must not
    /// perturb the arrival sequence).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let lambda = 2.5;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gamma_moments() {
        // Mean k·θ and variance k·θ², for shapes below and above 1.
        let mut r = Rng::new(17);
        for (k, theta) in [(0.4, 2.5), (1.0, 1.0), (4.0, 0.5)] {
            let n = 100_000;
            let mut sum = 0.0;
            let mut sq = 0.0;
            for _ in 0..n {
                let x = r.gamma(k, theta);
                assert!(x > 0.0 && x.is_finite());
                sum += x;
                sq += x * x;
            }
            let mean = sum / n as f64;
            let var = sq / n as f64 - mean * mean;
            let (em, ev) = (k * theta, k * theta * theta);
            assert!((mean - em).abs() / em < 0.03, "k={k}: mean {mean} vs {em}");
            assert!((var - ev).abs() / ev < 0.08, "k={k}: var {var} vs {ev}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
