//! Shared utilities.
//!
//! The build image vendors only the `xla` crate closure, so the usual
//! ecosystem crates (rand, serde, clap, prettytable) are unavailable; these
//! modules are small, dependency-free replacements.

pub mod cli;
pub mod fmt;
pub mod json;
pub mod rng;

/// Clamp a float to a closed interval.
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Linear interpolation between `a` and `b`.
#[inline]
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Exact percentile (nearest-rank on a sorted copy); `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn lerp_endpoints() {
        assert_eq!(lerp(2.0, 4.0, 0.0), 2.0);
        assert_eq!(lerp(2.0, 4.0, 1.0), 4.0);
        assert_eq!(lerp(2.0, 4.0, 0.5), 3.0);
    }

    #[test]
    fn mean_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
