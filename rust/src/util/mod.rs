//! Shared utilities.
//!
//! The build image vendors only the `xla` crate closure, so the usual
//! ecosystem crates (rand, serde, clap, prettytable) are unavailable; these
//! modules are small, dependency-free replacements.

pub mod cli;
pub mod fmt;
pub mod idset;
pub mod json;
pub mod rng;

pub use idset::OrderedIdSet;

/// Order-preserving integer key for a (non-NaN) `f64`: `a < b` ⇔
/// `f64_total_key(a) < f64_total_key(b)`. Lets hot paths sort or heap
/// floats on cheap integer comparisons instead of `partial_cmp` (§Perf).
#[inline]
pub fn f64_total_key(x: f64) -> u64 {
    debug_assert!(!x.is_nan(), "f64_total_key is undefined for NaN");
    let b = x.to_bits();
    if x >= 0.0 {
        b ^ 0x8000_0000_0000_0000
    } else {
        !b
    }
}

/// Clamp a float to a closed interval.
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Linear interpolation between `a` and `b`.
#[inline]
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Exact percentile (nearest-rank on a sorted copy); `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_key_is_order_preserving() {
        let xs = [
            f64::NEG_INFINITY,
            -1.0e30,
            -2.5,
            -1.0e-300,
            0.0,
            1.0e-300,
            1.0,
            3.5,
            1.0e30,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(f64_total_key(w[0]) < f64_total_key(w[1]), "{} vs {}", w[0], w[1]);
        }
        assert_eq!(f64_total_key(1.5), f64_total_key(1.5));
    }

    #[test]
    fn clamp_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn lerp_endpoints() {
        assert_eq!(lerp(2.0, 4.0, 0.0), 2.0);
        assert_eq!(lerp(2.0, 4.0, 1.0), 4.0);
        assert_eq!(lerp(2.0, 4.0, 0.5), 3.0);
    }

    #[test]
    fn mean_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
