//! Plain-text table rendering for bench output (paper-style rows).

/// A simple column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1)))
        ));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// Format seconds with an adaptive unit (s / ms / µs).
pub fn dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Format a float with 3 significant decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a ratio like `1.84x`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a byte count (B/KB/MB/GB).
pub fn bytes(n: f64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    if n >= G {
        format!("{:.2}GB", n / G)
    } else if n >= M {
        format!("{:.2}MB", n / M)
    } else if n >= K {
        format!("{:.1}KB", n / K)
    } else {
        format!("{n:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("t", &["sys", "ttft"]);
        t.row(&["nexus".into(), "0.5".into()]);
        t.row(&["vllm-baseline".into(), "10".into()]);
        let r = t.render();
        assert!(r.contains("== t =="));
        assert!(r.contains("vllm-baseline"));
        // Columns aligned: both data lines have '0' at same or later position.
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn dur_units() {
        assert_eq!(dur(2.0), "2.000s");
        assert_eq!(dur(0.25), "250.00ms");
        assert_eq!(dur(0.000003), "3.0us");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512.0), "512B");
        assert_eq!(bytes(2048.0), "2.0KB");
        assert!(bytes(3.0 * 1024.0 * 1024.0 * 1024.0).ends_with("GB"));
    }
}
