//! Tiny CLI argument parser (clap is not vendored in this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! A bare `--name` followed by a non-`--` token is treated as `--key value`
//! (there is no flag registry), so boolean flags adjacent to positional
//! arguments must come last or use `--name=true`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// True when `--name` appeared at all — as a bare flag, or (because the
    /// parser greedily binds a following token as the value) as an option
    /// whose value is not "false"/"0". Lets boolean switches like
    /// `--autoscale` work in any argument position.
    pub fn is_set(&self, name: &str) -> bool {
        if self.flag(name) {
            return true;
        }
        matches!(self.get(name), Some(v) if v != "false" && v != "0")
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse(&["serve", "trace.json", "--rate", "2.5", "--model=qwen3b", "--verbose"]);
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert_eq!(a.get("rate"), Some("2.5"));
        assert_eq!(a.get("model"), Some("qwen3b"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn greedy_value_consumption_is_documented_behavior() {
        // `--verbose trace.json` binds trace.json as the option value.
        let a = parse(&["--verbose", "trace.json"]);
        assert_eq!(a.get("verbose"), Some("trace.json"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--rate", "2.5", "--n", "10"]);
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert_eq!(a.get_usize("n", 0), 10);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn is_set_tolerates_greedy_binding() {
        // Trailing flag form.
        assert!(parse(&["--autoscale"]).is_set("autoscale"));
        // Greedy form: the next token was bound as the value.
        assert!(parse(&["--autoscale", "cluster"]).is_set("autoscale"));
        // Explicit disable and absence.
        assert!(!parse(&["--autoscale=false"]).is_set("autoscale"));
        assert!(!parse(&["--autoscale", "0"]).is_set("autoscale"));
        assert!(!parse(&["--other"]).is_set("autoscale"));
    }
}
