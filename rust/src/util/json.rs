//! Minimal JSON parser + emitter (serde is not vendored in this image).
//!
//! Used for: artifact manifests written by `python/compile/aot.py`, workload
//! trace import/export, and machine-readable metrics dumps from benches.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Copy raw UTF-8 bytes through.
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write(self, &mut s);
        f.write_str(&s)
    }
}

fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", "decode_1".into()),
            ("shape", vec![1usize, 8, 64].into()),
            ("ok", true.into()),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn number_formats() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-1").unwrap().as_f64(), Some(-0.25));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }
}
