//! Insertion-ordered id set with O(1) insert / remove / membership.
//!
//! The serving engines keep their `waiting` / `running` request sets as
//! insertion-ordered sequences: admission order *is* the FCFS order the
//! schedulers consume. The historical representation (`Vec<usize>` +
//! `retain(|&x| x != id)`) pays O(n) per removal, which turns every batch
//! completion into a linear scan (§Perf). `OrderedIdSet` keeps the exact
//! same observable order while making removal O(1) amortized: removed
//! slots are tombstoned and the backing vector is compacted once
//! tombstones outnumber live entries.

/// Marker for a removed slot in `items` / an absent id in `pos`.
const NONE: usize = usize::MAX;

/// An insertion-ordered set of `usize` ids (ids must be `< usize::MAX`).
///
/// Semantically identical to a `Vec<usize>` maintained with `push` +
/// `retain(|&x| x != id)`: iteration yields live ids in insertion order,
/// and removals never reorder the survivors.
#[derive(Debug, Clone, Default)]
pub struct OrderedIdSet {
    /// Ids in insertion order; removed entries become `NONE` tombstones.
    items: Vec<usize>,
    /// id -> index into `items` (`NONE` when absent). Sized to the largest
    /// id ever inserted, which is fine for the dense request-id space.
    pos: Vec<usize>,
    live: usize,
}

impl OrderedIdSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn contains(&self, id: usize) -> bool {
        match self.pos.get(id) {
            Some(&p) => p != NONE,
            None => false,
        }
    }

    /// Append `id` at the back of the order; no-op if already present.
    pub fn insert(&mut self, id: usize) {
        debug_assert!(id != NONE, "id space excludes usize::MAX");
        if self.contains(id) {
            return;
        }
        if id >= self.pos.len() {
            self.pos.resize(id + 1, NONE);
        }
        self.pos[id] = self.items.len();
        self.items.push(id);
        self.live += 1;
    }

    /// Remove `id`, preserving the relative order of the survivors.
    /// Returns whether the id was present.
    pub fn remove(&mut self, id: usize) -> bool {
        let p = match self.pos.get(id) {
            Some(&p) if p != NONE => p,
            _ => return false,
        };
        self.items[p] = NONE;
        self.pos[id] = NONE;
        self.live -= 1;
        // Amortized O(1): each compaction touches ≤ 2×live slots and at
        // least `live` removals must happen before the next one.
        if self.items.len() > 16 && self.items.len() >= 2 * self.live {
            self.compact();
        }
        true
    }

    /// Drop every tombstone and re-densify the position map.
    fn compact(&mut self) {
        self.items.retain(|&x| x != NONE);
        for (i, &id) in self.items.iter().enumerate() {
            self.pos[id] = i;
        }
    }

    /// Live ids in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.items.iter().copied().filter(|&x| x != NONE)
    }

    /// Oldest live id, if any.
    pub fn first(&self) -> Option<usize> {
        self.iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn insert_remove_contains() {
        let mut s = OrderedIdSet::new();
        assert!(s.is_empty());
        s.insert(5);
        s.insert(1);
        s.insert(9);
        assert_eq!(s.len(), 3);
        assert!(s.contains(5) && s.contains(1) && s.contains(9));
        assert!(!s.contains(2) && !s.contains(100));
        assert!(s.remove(1));
        assert!(!s.remove(1), "double remove is a no-op");
        assert!(!s.contains(1));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![5, 9]);
        assert_eq!(s.first(), Some(5));
    }

    #[test]
    fn duplicate_insert_is_noop() {
        let mut s = OrderedIdSet::new();
        s.insert(3);
        s.insert(3);
        assert_eq!(s.len(), 1);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn order_matches_vec_retain_model() {
        // Differential test: OrderedIdSet must be observationally identical
        // to the Vec + retain bookkeeping it replaces, across random
        // insert/remove interleavings (including re-insertion after removal,
        // which must re-enter at the back — exactly what push does).
        let mut rng = Rng::new(0xD1FF);
        for _ in 0..200 {
            let mut set = OrderedIdSet::new();
            let mut model: Vec<usize> = Vec::new();
            for _ in 0..rng.range_usize(1, 120) {
                let id = rng.below(40);
                if rng.chance(0.6) {
                    if !model.contains(&id) {
                        model.push(id);
                    }
                    set.insert(id);
                } else {
                    model.retain(|&x| x != id);
                    set.remove(id);
                }
                assert_eq!(set.iter().collect::<Vec<_>>(), model);
                assert_eq!(set.len(), model.len());
                assert_eq!(set.first(), model.first().copied());
            }
        }
    }

    #[test]
    fn compaction_preserves_order() {
        let mut s = OrderedIdSet::new();
        for id in 0..100 {
            s.insert(id);
        }
        // Remove enough to trigger compaction several times.
        for id in (0..100).step_by(2) {
            s.remove(id);
        }
        let got: Vec<usize> = s.iter().collect();
        let want: Vec<usize> = (1..100).step_by(2).collect();
        assert_eq!(got, want);
        // Survivors still removable / re-insertable after compaction.
        assert!(s.remove(51));
        s.insert(51);
        let mut want: Vec<usize> = (1..100).step_by(2).filter(|&x| x != 51).collect();
        want.push(51);
        assert_eq!(s.iter().collect::<Vec<_>>(), want);
    }
}
