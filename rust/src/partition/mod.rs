//! Dynamic SM partitioning — paper §4.1.2–§4.2 (Algorithm 1).
//!
//! Decides per batch how to split the GPU's SMs between the prefill and
//! decode streams:
//!
//! * **Dual-objective optimization**: minimize the prioritized phase's
//!   latency subject to the other phase staying within a slack factor
//!   (`α` for prefill when decode is prioritized, `β` for decode when
//!   prefill is prioritized) of its all-SMs ideal `T^min`.
//! * **Runtime mode switching**: prefill-prioritized while KV usage
//!   `KV_u ≤ KV_switch`, decode-prioritized above it (memory-pressure
//!   relief).
//! * **Greedy search**: phase 1 shrinks the prioritized share until the
//!   constraint holds; phase 2 grows it while the constraint still holds.
//!   Converges in a handful of cost-model queries — no global solver.
//! * **Hysteresis buffer** (§4.2): proposals whose change is below `δ` are
//!   suppressed, avoiding oscillation from transient workload shifts;
//!   application is asynchronous (streams pick up the new partition at
//!   their next kernel launch — see [`crate::gpusim::Sim::set_partition`]).

use crate::costmodel::{CostModel, PrefillPressure};
use crate::model::OpWork;

/// Which phase the dual objective currently prioritizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    PrefillPrioritized,
    DecodePrioritized,
}

/// What the intra-GPU split optimizes for.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PartitionObjective {
    /// Algorithm 1's dual-objective latency search (the original
    /// behavior): minimize the prioritized phase subject to the other
    /// phase's slack constraint.
    #[default]
    Latency,
    /// SLO-goodput: pick the split maximizing the product of per-phase
    /// SLO-attainment ratios `min(1, ttft_slo/T_p(r)) ·
    /// min(1, tbt_slo/T_d(r))` over a coarse share grid. Latency beyond an
    /// SLO is wasted work; latency below it buys nothing — so the sweep
    /// lands on the cheapest split where both phases just meet their
    /// targets (DistServe's goodput framing applied to SM shares).
    Goodput {
        /// Per-request prefill-latency budget (seconds).
        ttft_slo: f64,
        /// Per-iteration decode-latency budget (seconds).
        tbt_slo: f64,
    },
}

/// Controller configuration (defaults mirror the paper §5).
#[derive(Debug, Clone, Copy)]
pub struct PartitionConfig {
    /// Slack on prefill when decode is prioritized (`α`).
    pub alpha: f64,
    /// Slack on decode when prefill is prioritized (`β`).
    pub beta: f64,
    /// Hysteresis buffer `δ` on the prefill share (fractional).
    pub delta: f64,
    /// KV-usage threshold switching prefill- → decode-prioritized.
    pub kv_switch: f64,
    /// Greedy step size (fraction of SMs; paper steps 1%).
    pub step: f64,
    /// Floor/ceiling so neither stream starves entirely.
    pub min_share: f64,
    /// Insight-1 stop rule: phase 2 stops growing the prioritized share
    /// once its own marginal gain per 1% of SMs falls below this relative
    /// threshold — "allocate only the SMs needed" (§3.2), instead of
    /// grabbing post-saturation SMs the other phase could use.
    pub min_gain: f64,
    /// Search objective; `Latency` keeps the original Algorithm 1 path
    /// byte-for-byte, `Goodput { .. }` switches to the SLO-product sweep.
    pub objective: PartitionObjective,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig {
            alpha: 1.3,
            beta: 1.1,
            delta: 0.05,
            kv_switch: 0.7,
            step: 0.01,
            min_share: 0.05,
            min_gain: 0.003,
            objective: PartitionObjective::Latency,
        }
    }
}

/// Outcome of one controller invocation.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// New prefill share (continuous; quantization happens at application).
    pub r_p: f64,
    pub r_d: f64,
    pub mode: Mode,
    /// False if the hysteresis buffer suppressed the change.
    pub applied: bool,
    /// Cost-model queries consumed by the greedy search.
    pub queries: usize,
}

/// Per-batch SM partition controller (Algorithm 1).
#[derive(Debug, Clone)]
pub struct PartitionController {
    pub cfg: PartitionConfig,
    /// Last *applied* prefill share.
    pub r_p: f64,
    /// Cumulative stats for the stability analysis (Fig. 8).
    pub applied_count: usize,
    pub suppressed_count: usize,
    query_count_last: usize,
}

/// Inputs describing the next prefill/decode iterations to balance.
pub struct BatchState<'a> {
    pub prefill_ops: &'a [OpWork],
    pub decode_ops: &'a [OpWork],
    /// Live KV usage `KV_u` ∈ [0,1].
    pub kv_usage: f64,
}

impl PartitionController {
    pub fn new(cfg: PartitionConfig) -> Self {
        PartitionController {
            cfg,
            r_p: 0.5,
            applied_count: 0,
            suppressed_count: 0,
            query_count_last: 0,
        }
    }

    /// Select the objective mode from live KV usage (paper §4.1.2).
    pub fn mode_for(&self, kv_usage: f64) -> Mode {
        if kv_usage > self.cfg.kv_switch {
            Mode::DecodePrioritized
        } else {
            Mode::PrefillPrioritized
        }
    }

    /// Latency of `prefill?` phase at share `r`, with decode seeing a
    /// *frozen* pressure snapshot (the Eq. 8–9 coupling, measured once per
    /// batch at the current allocation).
    ///
    /// Freezing the snapshot keeps the dual-objective search well-posed:
    /// contention makes decode's contention-free `T^min` unreachable under
    /// *any* split, so the slack constraints are interpreted against the
    /// equally-contended ideal — they then bound the SM-allocation-induced
    /// slowdown, which is what the controller actually distributes.
    fn eval(
        &self,
        cost: &CostModel,
        st: &BatchState<'_>,
        pressure: Option<&PrefillPressure>,
        prefill: bool,
        r: f64,
        queries: &mut usize,
    ) -> f64 {
        *queries += 1;
        if prefill {
            if st.prefill_ops.is_empty() {
                return 0.0;
            }
            cost.prefill(st.prefill_ops, r).total
        } else {
            if st.decode_ops.is_empty() {
                return 0.0;
            }
            cost.decode(st.decode_ops, r, pressure)
        }
    }

    /// Algorithm 1: `PartitionController(KV_u, R_p_cur, R_d_cur)`.
    pub fn decide(&mut self, cost: &CostModel, st: &BatchState<'_>) -> Decision {
        let mode = self.mode_for(st.kv_usage);
        let mut queries = 0usize;

        // Degenerate batches: give everything to the only active phase.
        let target_share = if st.prefill_ops.is_empty() && !st.decode_ops.is_empty() {
            self.cfg.min_share
        } else if st.decode_ops.is_empty() && !st.prefill_ops.is_empty() {
            1.0 - self.cfg.min_share
        } else if st.prefill_ops.is_empty() && st.decode_ops.is_empty() {
            self.r_p
        } else {
            match self.cfg.objective {
                PartitionObjective::Latency => self.adjust(cost, st, mode, &mut queries),
                PartitionObjective::Goodput { ttft_slo, tbt_slo } => {
                    self.goodput_sweep(cost, st, ttft_slo, tbt_slo, &mut queries)
                }
            }
        };

        self.query_count_last = queries;
        let applied = (target_share - self.r_p).abs() >= self.cfg.delta;
        if applied {
            self.r_p = target_share;
            self.applied_count += 1;
        } else {
            // Buffer zone: the proposal (identical or within δ) is absorbed —
            // this is the Fig.-8c stability mechanism.
            self.suppressed_count += 1;
        }
        Decision {
            r_p: self.r_p,
            r_d: 1.0 - self.r_p,
            mode,
            applied,
            queries,
        }
    }

    /// `AdjustPartition(target, …)`: two-phase greedy search over the share
    /// of the *prioritized* phase. Returns the resulting prefill share.
    fn adjust(
        &self,
        cost: &CostModel,
        st: &BatchState<'_>,
        mode: Mode,
        queries: &mut usize,
    ) -> f64 {
        let prioritize_prefill = mode == Mode::PrefillPrioritized;
        let slack = if prioritize_prefill {
            self.cfg.beta
        } else {
            self.cfg.alpha
        };
        // Per-batch pressure snapshot at the current allocation (frozen for
        // the whole search — see [`Self::eval`]).
        let pressure: Option<PrefillPressure> = if st.prefill_ops.is_empty() {
            None
        } else {
            Some(cost.prefill(st.prefill_ops, self.r_p.max(self.cfg.min_share)).pressure)
        };
        let pr = pressure.as_ref();
        // Ideal latency of the non-prioritized phase with all SMs.
        let t_other_opt = self.eval(cost, st, pr, !prioritize_prefill, 1.0, queries);

        let lo = self.cfg.min_share;
        let hi = 1.0 - self.cfg.min_share;
        // Current share of the prioritized phase.
        let mut r = if prioritize_prefill {
            self.r_p
        } else {
            1.0 - self.r_p
        }
        .clamp(lo, hi);

        let other_latency = |r_target: f64, queries: &mut usize| -> f64 {
            self.eval(cost, st, pr, !prioritize_prefill, 1.0 - r_target, queries)
        };

        // Phase 1: shrink until the constraint is satisfied (Alg. 1 l.21–23).
        while r > lo && other_latency(r, queries) > slack * t_other_opt {
            r = (r - self.cfg.step).max(lo);
        }
        // Phase 2: grow while the constraint stays satisfied (l.24–30) AND
        // the prioritized phase still benefits (Insight-1 stop rule).
        let mut t_cur = self.eval(cost, st, pr, prioritize_prefill, r, queries);
        while r < hi {
            let next = (r + self.cfg.step).min(hi);
            if other_latency(next, queries) > slack * t_other_opt {
                break;
            }
            let t_next = self.eval(cost, st, pr, prioritize_prefill, next, queries);
            let step_gain = self.cfg.min_gain * t_cur * (next - r) / 0.01;
            if t_cur - t_next < step_gain {
                break;
            }
            t_cur = t_next;
            r = next;
            if next >= hi {
                break;
            }
        }

        if prioritize_prefill {
            r
        } else {
            1.0 - r
        }
    }

    /// [`PartitionObjective::Goodput`]: sweep the prefill share over a
    /// coarse grid and keep the split maximizing the product of per-phase
    /// SLO-attainment ratios (each capped at 1 — overshooting a budget
    /// earns nothing). Ties break toward the *lowest* prefill share, so an
    /// unconstrained region defaults to giving decode the surplus SMs. The
    /// grid is 5× the greedy step: the objective is flat near its plateau
    /// (both ratios capped), so fine steps only burn cost-model queries.
    /// The δ-hysteresis in [`Self::decide`] still damps the output.
    fn goodput_sweep(
        &self,
        cost: &CostModel,
        st: &BatchState<'_>,
        ttft_slo: f64,
        tbt_slo: f64,
        queries: &mut usize,
    ) -> f64 {
        // Same frozen-pressure convention as `adjust` (see `eval`).
        let pressure =
            Some(cost.prefill(st.prefill_ops, self.r_p.max(self.cfg.min_share)).pressure);
        let pr = pressure.as_ref();
        let lo = self.cfg.min_share;
        let hi = 1.0 - self.cfg.min_share;
        let grid = (self.cfg.step * 5.0).max(1e-3);
        let mut best_r = lo;
        let mut best_score = f64::NEG_INFINITY;
        let mut r = lo;
        loop {
            let t_p = self.eval(cost, st, pr, true, r, queries).max(1e-12);
            let t_d = self.eval(cost, st, pr, false, 1.0 - r, queries).max(1e-12);
            let score = (ttft_slo / t_p).min(1.0) * (tbt_slo / t_d).min(1.0);
            // Strict `>`: equal-score plateaus keep the earliest (lowest) r.
            if score > best_score {
                best_score = score;
                best_r = r;
            }
            if r >= hi {
                break;
            }
            r = (r + grid).min(hi);
        }
        best_r
    }

    pub fn last_queries(&self) -> usize {
        self.query_count_last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::calibrate;
    use crate::gpusim::GpuSpec;
    use crate::model::ModelConfig;

    fn setup() -> (CostModel, ModelConfig) {
        (calibrate(&GpuSpec::l20()), ModelConfig::qwen3b())
    }

    fn state<'a>(pre: &'a [OpWork], dec: &'a [OpWork], kv: f64) -> BatchState<'a> {
        BatchState {
            prefill_ops: pre,
            decode_ops: dec,
            kv_usage: kv,
        }
    }

    #[test]
    fn mode_switches_on_kv_threshold() {
        let ctl = PartitionController::new(PartitionConfig::default());
        assert_eq!(ctl.mode_for(0.2), Mode::PrefillPrioritized);
        assert_eq!(ctl.mode_for(0.69), Mode::PrefillPrioritized);
        assert_eq!(ctl.mode_for(0.71), Mode::DecodePrioritized);
    }

    #[test]
    fn shares_sum_to_one_and_respect_floor() {
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        for kv in [0.1, 0.5, 0.9] {
            let d = ctl.decide(&cm, &state(&pre, &dec, kv));
            assert!((d.r_p + d.r_d - 1.0).abs() < 1e-9);
            assert!(d.r_p >= 0.05 - 1e-9 && d.r_d >= 0.05 - 1e-9);
        }
    }

    #[test]
    fn constraint_satisfied_after_decision() {
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        // Prefill-prioritized: decode must stay within β of its ideal.
        let st = state(&pre, &dec, 0.2);
        let d = ctl.decide(&cm, &st);
        assert_eq!(d.mode, Mode::PrefillPrioritized);
        // The slack is interpreted against the *equally-contended* ideal
        // (see PartitionController::eval): decode at the decided share must
        // be within β of decode at full SMs under the same pressure.
        let pp = cm.prefill(&pre, d.r_p).pressure;
        let t_dec_opt = cm.decode(&dec, 1.0, Some(&pp));
        let t_dec = cm.decode(&dec, d.r_d, Some(&pp));
        assert!(
            t_dec <= ctl.cfg.beta * t_dec_opt * 1.05 + 1e-9,
            "decode {t_dec} vs budget {}",
            ctl.cfg.beta * t_dec_opt
        );
    }

    #[test]
    fn decode_mode_gives_decode_more_sms() {
        let (cm, cfg) = setup();
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        let mut a = PartitionController::new(PartitionConfig::default());
        let mut b = PartitionController::new(PartitionConfig::default());
        let low = a.decide(&cm, &state(&pre, &dec, 0.1));
        let high = b.decide(&cm, &state(&pre, &dec, 0.95));
        assert!(
            high.r_d >= low.r_d,
            "decode-prioritized should not shrink decode: {} vs {}",
            high.r_d,
            low.r_d
        );
    }

    #[test]
    fn hysteresis_suppresses_small_changes() {
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        let st = state(&pre, &dec, 0.3);
        let d1 = ctl.decide(&cm, &st);
        // Same state again: target identical → nothing to apply.
        let d2 = ctl.decide(&cm, &st);
        assert_eq!(d1.r_p, d2.r_p);
        assert!(!d2.applied, "no-change proposal must be suppressed");
    }

    #[test]
    fn empty_prefill_gives_decode_everything() {
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let dec = cfg.decode_ops(8, 8.0 * 512.0);
        let d = ctl.decide(&cm, &state(&[], &dec, 0.4));
        assert!(d.r_d >= 0.94, "r_d {}", d.r_d);
    }

    #[test]
    fn goodput_objective_defaults_identically_to_latency() {
        // `Latency` is the Default: an explicitly-latency config must be
        // indistinguishable from the implicit default (guards the
        // byte-for-byte claim for existing callers).
        let (cm, cfg) = setup();
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        let mut a = PartitionController::new(PartitionConfig::default());
        let mut b = PartitionController::new(PartitionConfig {
            objective: PartitionObjective::Latency,
            ..PartitionConfig::default()
        });
        let da = a.decide(&cm, &state(&pre, &dec, 0.3));
        let db = b.decide(&cm, &state(&pre, &dec, 0.3));
        assert_eq!(da.r_p, db.r_p);
        assert_eq!(da.queries, db.queries);
    }

    #[test]
    fn goodput_sweep_lands_inside_bounds_and_meets_loose_slos() {
        let (cm, cfg) = setup();
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        // Budgets generous enough that some split satisfies both: the
        // sweep must find a share where both ratios cap at 1.
        let mut ctl = PartitionController::new(PartitionConfig {
            objective: PartitionObjective::Goodput { ttft_slo: 60.0, tbt_slo: 60.0 },
            delta: 0.0,
            ..PartitionConfig::default()
        });
        let st = state(&pre, &dec, 0.3);
        let d = ctl.decide(&cm, &st);
        assert!((d.r_p + d.r_d - 1.0).abs() < 1e-9);
        assert!(d.r_p >= 0.05 - 1e-9 && d.r_d >= 0.05 - 1e-9);
        let pp = cm.prefill(&pre, d.r_p).pressure;
        assert!(cm.prefill(&pre, d.r_p).total <= 60.0, "prefill within budget");
        assert!(cm.decode(&dec, d.r_d, Some(&pp)) <= 60.0, "decode within budget");
    }

    #[test]
    fn tight_ttft_budget_pulls_sms_toward_prefill() {
        let (cm, cfg) = setup();
        let pre = cfg.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
        let dec = cfg.decode_ops(32, 32.0 * 2000.0);
        let mk = |ttft: f64| PartitionConfig {
            objective: PartitionObjective::Goodput { ttft_slo: ttft, tbt_slo: 1e9 },
            delta: 0.0,
            ..PartitionConfig::default()
        };
        // With decode's budget unbounded, tightening TTFT can only move
        // the chosen share toward prefill (monotone under the tie-break).
        let mut loose = PartitionController::new(mk(1e9));
        let mut tight = PartitionController::new(mk(1e-6));
        let dl = loose.decide(&cm, &state(&pre, &dec, 0.3));
        let dt = tight.decide(&cm, &state(&pre, &dec, 0.3));
        assert!(
            dt.r_p >= dl.r_p,
            "tight TTFT must not shrink prefill: {} vs {}",
            dt.r_p,
            dl.r_p
        );
        // An unmeetable TTFT budget maximizes raw prefill speed: the sweep
        // pushes prefill to the ceiling share.
        assert!(dt.r_p >= 0.9, "r_p {}", dt.r_p);
    }

    #[test]
    fn goodput_degenerate_batches_keep_latency_behavior() {
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig {
            objective: PartitionObjective::Goodput { ttft_slo: 1.0, tbt_slo: 1.0 },
            ..PartitionConfig::default()
        });
        let dec = cfg.decode_ops(8, 8.0 * 512.0);
        let d = ctl.decide(&cm, &state(&[], &dec, 0.4));
        assert!(d.r_d >= 0.94, "empty prefill still gives decode everything");
    }

    #[test]
    fn greedy_query_budget_small() {
        // Paper: converges in 2–4 iterations; allow a modest query budget.
        let (cm, cfg) = setup();
        let mut ctl = PartitionController::new(PartitionConfig::default());
        let pre = cfg.prefill_ops(256, 256.0 * 3000.0, 3000.0, 0);
        let dec = cfg.decode_ops(16, 16.0 * 1000.0);
        let d = ctl.decide(&cm, &state(&pre, &dec, 0.5));
        assert!(d.queries <= 120, "queries {}", d.queries);
        // Follow-up decisions from a settled state should be cheap.
        let d2 = ctl.decide(&cm, &state(&pre, &dec, 0.5));
        assert!(d2.queries <= 40, "settled queries {}", d2.queries);
    }
}
