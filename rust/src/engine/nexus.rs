//! Nexus — proactive intra-GPU prefill/decode disaggregation (paper §4).
//!
//! Two concurrent streams on one GPU (green-context style), with:
//! * per-batch SM partitioning from the contention-aware cost model +
//!   greedy dual-objective search (Algorithm 1, [`crate::partition`]);
//! * hysteresis-buffered asynchronous switching (§4.2): partitions apply at
//!   the next kernel launch, small changes are suppressed;
//! * phase-specific schedulers (§4.3): Shortest-Prompt-First with age decay
//!   for prefill (Algorithm 2), FCFS for decode.
//!
//! Ablation flags reproduce the Fig.-13 variants: `use_spf = false` falls
//! back to FCFS prefill ("PF-DF"); `dynamic_sm = false` pins a static 50/50
//! split ("Wo-SC").
//!
//! Hot-path layout (§Perf): `waiting` / `running` are insertion-ordered
//! indexed sets ([`OrderedIdSet`]) with O(1) membership updates, and batch
//! assembly (candidate lists, prefill queue, operator lists, estimate ops,
//! completion lists, iteration manifests) reuses engine-owned buffers so the
//! per-iteration path allocates nothing in steady state.

use super::common::{chunk_attn_pairs, ReqState};
use super::{Engine, EngineCfg, EngineKind, StepOutcome};
use crate::costmodel::{calibrate, CostModel};
use crate::gpusim::{Completion, Sim};
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::partition::{BatchState, Mode, PartitionController};
use crate::sched::{fcfs_batch_into, spf_batch_into, PrefillItem, SchedScratch};
use crate::trace::{EngineSnapshot, EventKind, PreemptKind, TracePhase, Tracer};
use crate::util::OrderedIdSet;
use crate::workload::Request;
use std::time::Instant;

const PREFILL_STREAM: usize = 0;
const DECODE_STREAM: usize = 1;

/// Nexus ablation switches (Fig. 13).
#[derive(Debug, Clone, Copy)]
pub struct NexusFlags {
    /// SPF prefill scheduling (false → FCFS, the "PF-DF" variants).
    pub use_spf: bool,
    /// Dynamic SM repartitioning (false → static 50/50, "Wo-SC").
    pub dynamic_sm: bool,
}

impl Default for NexusFlags {
    fn default() -> Self {
        NexusFlags { use_spf: true, dynamic_sm: true }
    }
}

struct Iter {
    /// Decode iteration: ids receiving one token. Prefill iteration: empty.
    decode_ids: Vec<usize>,
    prefill_parts: Vec<(usize, usize)>,
    start: f64,
}

pub struct NexusEngine {
    cfg: EngineCfg,
    pub flags: NexusFlags,
    cost: CostModel,
    sim: Sim,
    controller: PartitionController,
    kv: KvCache,
    metrics: RunMetrics,
    states: Vec<Option<ReqState>>,
    waiting: OrderedIdSet,
    running: OrderedIdSet,
    inflight: [Option<Iter>; 2],
    injected: usize,
    done: usize,
    tag: u64,
    // Partition-trajectory accounting (time-weighted). `start_t` is the
    // first step's time — NaN until then — so replicas spawned mid-run by
    // the cluster autoscaler don't accrue pre-birth idle time.
    rp_time: f64,
    decode_mode_time: f64,
    kv_time: f64,
    start_t: f64,
    last_t: f64,
    // Reusable hot-path buffers (§Perf).
    cand_buf: Vec<usize>,
    queue_buf: Vec<PrefillItem>,
    picked_buf: Vec<usize>,
    ops_buf: Vec<OpWork>,
    est_buf: Vec<OpWork>,
    comp_buf: Vec<Completion>,
    scratch: SchedScratch,
    /// Recycled `Iter` vectors (returned on completion, reused on schedule).
    spare_ids: Vec<Vec<usize>>,
    spare_parts: Vec<Vec<(usize, usize)>>,
    tracer: Tracer,
}

impl NexusEngine {
    pub fn new(cfg: &EngineCfg, flags: NexusFlags) -> Self {
        let cost = calibrate(&cfg.gpu);
        let mut sim = Sim::new(cfg.gpu, 2);
        sim.set_partition(PREFILL_STREAM, 0.5);
        sim.set_partition(DECODE_STREAM, 0.5);
        let controller = PartitionController::new(cfg.partition);
        let kv = cfg.kv_cache();
        NexusEngine {
            cfg: cfg.clone(),
            flags,
            cost,
            sim,
            controller,
            kv,
            metrics: RunMetrics::default(),
            states: Vec::new(),
            waiting: OrderedIdSet::new(),
            running: OrderedIdSet::new(),
            inflight: [None, None],
            injected: 0,
            done: 0,
            tag: 0,
            rp_time: 0.0,
            decode_mode_time: 0.0,
            kv_time: 0.0,
            start_t: f64::NAN,
            last_t: 0.0,
            cand_buf: Vec::new(),
            queue_buf: Vec::new(),
            picked_buf: Vec::new(),
            ops_buf: Vec::new(),
            est_buf: Vec::new(),
            comp_buf: Vec::new(),
            scratch: SchedScratch::default(),
            spare_ids: Vec::new(),
            spare_parts: Vec::new(),
            tracer: Tracer::default(),
        }
    }

    /// Run over a whole trace (fresh state each call).
    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let mut eng = Self::new(&self.cfg, self.flags);
        super::drive(&mut eng, trace, self.cfg.max_virtual_time)
    }

    fn slot(&mut self, id: usize) {
        if id >= self.states.len() {
            self.states.resize_with(id + 1, || None);
        }
    }

    /// Build, partition, and submit the next batch for one stream.
    fn schedule_stream(&mut self, stream: usize) -> Option<Iter> {
        let wall = Instant::now();
        let now = self.sim.now();

        let mut decode_ids = self.spare_ids.pop().unwrap_or_default();
        decode_ids.clear();
        let mut prefill_parts = self.spare_parts.pop().unwrap_or_default();
        prefill_parts.clear();
        self.ops_buf.clear();

        if stream == DECODE_STREAM {
            // FCFS decode: every running request contributes one token.
            let mut cand = std::mem::take(&mut self.cand_buf);
            cand.clear();
            cand.extend(self.running.iter().take(self.cfg.max_batch));
            for &id in &cand {
                loop {
                    if self.kv.try_reserve(id, 1) {
                        decode_ids.push(id);
                        break;
                    }
                    // Preempt the newest running request that is not `id`
                    // (ties break toward the latest-ordered entry, like the
                    // historical `Iterator::max_by` over the running vec).
                    let mut victim: Option<usize> = None;
                    let mut victim_arrival = f64::NEG_INFINITY;
                    for v in self.running.iter() {
                        if v == id {
                            continue;
                        }
                        let a = self.states[v].as_ref().unwrap().req.arrival;
                        if a >= victim_arrival {
                            victim_arrival = a;
                            victim = Some(v);
                        }
                    }
                    match victim {
                        Some(v) => {
                            self.kv.release(v);
                            self.running.remove(v);
                            decode_ids.retain(|&x| x != v);
                            self.states[v].as_mut().unwrap().restart_for_recompute(now);
                            self.waiting.insert(v);
                            self.metrics.recomputes += 1;
                            self.tracer.emit(
                                now,
                                EventKind::Preempt { req: v, kind: PreemptKind::Recompute },
                            );
                        }
                        None => break,
                    }
                }
            }
            self.cand_buf = cand;
            if decode_ids.is_empty() {
                self.spare_ids.push(decode_ids);
                self.spare_parts.push(prefill_parts);
                return None;
            }
            let ctx: f64 = decode_ids.iter().map(|&id| self.kv.tokens(id) as f64).sum();
            self.cfg.model.decode_ops_into(decode_ids.len(), ctx, &mut self.ops_buf);
        } else {
            // Prefill: SPF (Algorithm 2) or FCFS ablation, over the token
            // budget, chunking the head request if nothing fits whole.
            self.queue_buf.clear();
            {
                let queue_buf = &mut self.queue_buf;
                let states = &self.states;
                queue_buf.extend(self.waiting.iter().map(|id| {
                    let st = states[id].as_ref().unwrap();
                    PrefillItem {
                        id,
                        prompt_len: st.effective_prompt,
                        prefilled: st.prefilled,
                        arrival: st.req.arrival,
                    }
                }));
            }
            if self.queue_buf.is_empty() {
                self.spare_ids.push(decode_ids);
                self.spare_parts.push(prefill_parts);
                return None;
            }
            let mut picked = std::mem::take(&mut self.picked_buf);
            if self.flags.use_spf {
                spf_batch_into(
                    &self.queue_buf,
                    now,
                    self.cfg.token_budget,
                    self.cfg.gamma,
                    &mut self.scratch,
                    &mut picked,
                );
            } else {
                fcfs_batch_into(
                    &self.queue_buf,
                    self.cfg.token_budget,
                    true,
                    &mut self.scratch,
                    &mut picked,
                );
            }
            let mut left = self.cfg.token_budget;
            for &qidx in &picked {
                let item = self.queue_buf[qidx];
                let take = item.remaining().min(self.cfg.chunk_size).min(left);
                if take == 0 {
                    break;
                }
                if self.kv.try_reserve(item.id, take) {
                    prefill_parts.push((item.id, take));
                    left -= take;
                    if self.tracer.enabled() {
                        self.tracer.emit(
                            now,
                            EventKind::KvAlloc {
                                req: item.id,
                                tokens: take,
                                usage: self.kv.usage(),
                            },
                        );
                    }
                }
            }
            self.picked_buf = picked;
            if prefill_parts.is_empty() {
                self.spare_ids.push(decode_ids);
                self.spare_parts.push(prefill_parts);
                return None;
            }
            let n: usize = prefill_parts.iter().map(|&(_, t)| t).sum();
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            let mut finishing = 0usize;
            for &(id, take) in &prefill_parts {
                let st = self.states[id].as_ref().unwrap();
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                if st.prefilled + take >= st.effective_prompt {
                    finishing += 1;
                }
            }
            self.cfg.model.prefill_ops_into(n, pairs, kv_read, finishing, &mut self.ops_buf);
        }

        // Proactive per-batch partition decision (Algorithm 1). The other
        // phase's ops are estimated from its current queue/batch state.
        if self.flags.dynamic_sm {
            if stream == DECODE_STREAM {
                self.estimate_prefill_ops();
            } else {
                self.estimate_decode_ops();
            }
            let (pre_ops, dec_ops): (&[OpWork], &[OpWork]) = if stream == DECODE_STREAM {
                (&self.est_buf, &self.ops_buf)
            } else {
                (&self.ops_buf, &self.est_buf)
            };
            let batch = BatchState {
                prefill_ops: pre_ops,
                decode_ops: dec_ops,
                kv_usage: self.kv.usage(),
            };
            let decision = self.controller.decide(&self.cost, &batch);
            if decision.applied {
                self.sim.set_partition(PREFILL_STREAM, decision.r_p);
                self.sim.set_partition(DECODE_STREAM, decision.r_d);
                self.tracer.emit(
                    now,
                    EventKind::Repartition {
                        r_p: decision.r_p,
                        r_d: decision.r_d,
                        decode_mode: decision.mode == Mode::DecodePrioritized,
                    },
                );
            }
        }

        self.tag += 1;
        self.sim.submit(stream, &self.ops_buf, self.tag);
        if self.tracer.enabled() {
            let tokens: usize =
                decode_ids.len() + prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
            self.tracer.emit(
                now,
                EventKind::BatchStart {
                    phase: if stream == DECODE_STREAM {
                        TracePhase::Decode
                    } else {
                        TracePhase::Prefill
                    },
                    seqs: decode_ids.len() + prefill_parts.len(),
                    tokens,
                },
            );
        }

        let sched = wall.elapsed().as_secs_f64();
        let parts = decode_ids.len() + prefill_parts.len();
        let share = sched / parts.max(1) as f64;
        for &id in &decode_ids {
            self.states[id].as_mut().unwrap().sched_time += share;
        }
        for &(id, _) in &prefill_parts {
            self.states[id].as_mut().unwrap().sched_time += share;
        }

        Some(Iter { decode_ids, prefill_parts, start: now })
    }

    /// Estimate the next prefill batch's ops for the partition decision,
    /// writing into the reusable `est_buf`.
    fn estimate_prefill_ops(&mut self) {
        let mut out = std::mem::take(&mut self.est_buf);
        out.clear();
        if !self.waiting.is_empty() {
            let cfg = &self.cfg;
            let mut n = 0usize;
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            for id in self.waiting.iter() {
                let st = self.states[id].as_ref().unwrap();
                let take = (st.effective_prompt - st.prefilled)
                    .min(cfg.chunk_size)
                    .min(cfg.token_budget - n);
                if take == 0 {
                    break;
                }
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                n += take;
            }
            if n > 0 {
                cfg.model.prefill_ops_into(n, pairs, kv_read, 0, &mut out);
            }
        }
        self.est_buf = out;
    }

    /// Estimate the current decode batch's ops for the partition decision,
    /// writing into the reusable `est_buf`.
    fn estimate_decode_ops(&mut self) {
        let mut out = std::mem::take(&mut self.est_buf);
        out.clear();
        if !self.running.is_empty() {
            let n = self.running.len().min(self.cfg.max_batch);
            let ctx: f64 =
                self.running.iter().take(n).map(|id| self.kv.tokens(id) as f64).sum();
            self.cfg.model.decode_ops_into(n, ctx, &mut out);
        }
        self.est_buf = out;
    }
}

impl Engine for NexusEngine {
    fn kind(&self) -> EngineKind {
        match (self.flags.use_spf, self.flags.dynamic_sm) {
            (true, true) => EngineKind::Nexus,
            (true, false) => EngineKind::NexusWoSc,
            (false, false) => EngineKind::PfDfWoSc,
            (false, true) => EngineKind::PfDfWSc,
        }
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn next_event(&mut self) -> Option<f64> {
        self.sim.peek_next_completion()
    }

    fn inject_effective(&mut self, req: Request, eff: Option<usize>) {
        self.slot(req.id);
        let mut st = ReqState::new(req);
        if let Some(e) = eff {
            st.effective_prompt = e.max(1);
        }
        self.states[req.id] = Some(st);
        self.waiting.insert(req.id);
        self.injected += 1;
        self.tracer.emit(req.arrival, EventKind::Admit { req: req.id });
    }

    fn step(&mut self, t: f64) -> StepOutcome {
        // Time-weighted partition/KV trajectory accounting. The integrands
        // are piecewise-constant between engine events, so integrating at
        // every driver step (even foreign cluster events) is exact.
        if self.start_t.is_nan() {
            self.start_t = t;
            self.last_t = t;
        }
        let dt = (t - self.last_t).max(0.0);
        self.rp_time += self.controller.r_p * dt;
        self.kv_time += self.kv.usage() * dt;
        self.metrics.peak_kv_usage = self.metrics.peak_kv_usage.max(self.kv.usage());
        if self.controller.mode_for(self.kv.usage()) == Mode::DecodePrioritized {
            self.decode_mode_time += dt;
        }
        self.last_t = t;

        let mut comps = std::mem::take(&mut self.comp_buf);
        self.sim.advance_to_into(t + 1e-12, &mut comps);
        let mut finished = 0usize;
        for &c in &comps {
            let it = self.inflight[c.stream].take().expect("completion without inflight");
            let now = c.time;
            let dur = now - it.start;
            if self.tracer.enabled() {
                let tokens: usize =
                    it.decode_ids.len() + it.prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
                self.tracer.emit(
                    now,
                    EventKind::BatchEnd {
                        phase: if c.stream == DECODE_STREAM {
                            TracePhase::Decode
                        } else {
                            TracePhase::Prefill
                        },
                        seqs: it.decode_ids.len() + it.prefill_parts.len(),
                        tokens,
                        dur,
                    },
                );
            }
            for &id in &it.decode_ids {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.note_token(now, dur);
                if st.decode_done() {
                    let st = self.states[id].take().unwrap();
                    self.kv.release(id);
                    self.running.remove(id);
                    self.metrics.push(st.into_record(now));
                    self.done += 1;
                    finished += 1;
                    self.tracer.emit(now, EventKind::Complete { req: id });
                }
            }
            for &(id, take) in &it.prefill_parts {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.queue_time += (it.start - st.queue_since).max(0.0);
                st.queue_since = now;
                st.prefilled += take;
                let prefill_done = st.prefill_done();
                self.tracer.emit(
                    now,
                    EventKind::PrefillChunk { req: id, take, done: prefill_done, dur },
                );
                if prefill_done {
                    self.waiting.remove(id);
                    if st.generated > 0 {
                        self.running.insert(id); // resumed after recompute
                    } else {
                        st.note_first_token(now);
                        self.tracer.emit(now, EventKind::FirstToken { req: id });
                        if st.decode_done() {
                            let st = self.states[id].take().unwrap();
                            self.kv.release(id);
                            self.metrics.push(st.into_record(now));
                            self.done += 1;
                            finished += 1;
                            self.tracer.emit(now, EventKind::Complete { req: id });
                        } else {
                            self.running.insert(id);
                        }
                    }
                }
            }
            // Recycle the manifest's vectors for future iterations.
            self.spare_ids.push(it.decode_ids);
            self.spare_parts.push(it.prefill_parts);
        }
        self.comp_buf = comps;

        // Schedule idle streams. Decode first: it is latency-critical
        // and its batch state feeds the partition decision.
        for stream in [DECODE_STREAM, PREFILL_STREAM] {
            if self.inflight[stream].is_none() {
                self.inflight[stream] = self.schedule_stream(stream);
            }
        }

        StepOutcome {
            completed: finished,
            busy: self.inflight.iter().any(Option::is_some),
        }
    }

    fn pending(&self) -> usize {
        self.injected - self.done
    }

    fn completed(&self) -> usize {
        self.done
    }

    fn kv_usage(&self) -> f64 {
        self.kv.usage()
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            waiting: self.waiting.len(),
            running: self.running.len(),
            kv_usage: self.kv.usage(),
            sm_prefill: self.controller.r_p,
            inflight: self.inflight.iter().filter(|i| i.is_some()).count(),
        }
    }

    fn records(&self) -> &[crate::metrics::RequestRecord] {
        &self.metrics.records
    }

    fn take_metrics(&mut self) -> RunMetrics {
        self.metrics.repartitions = self.controller.applied_count;
        self.metrics.suppressed_repartitions = self.controller.suppressed_count;
        // Normalize over the engine's own lifetime (first step → last step)
        // so late-spawned cluster replicas report honest trajectory means.
        let span = self.last_t - self.start_t;
        if span.is_finite() && span > 0.0 {
            self.metrics.mean_rp = self.rp_time / span;
            self.metrics.decode_mode_frac = self.decode_mode_time / span;
            self.metrics.mean_kv_usage = self.kv_time / span;
        }
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::monolithic::MonolithicEngine;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace);
        assert_eq!(m.summary().completed, 40);
        assert_eq!(m.timeouts, 0);
    }

    #[test]
    fn beats_vllm_tbt_under_long_prompts() {
        // Phase isolation must beat mixed batching on decode latency when
        // long prefill chunks are in play (the paper's headline TBT claim).
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 40, 2.5, 11);
        let nexus = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace).summary();
        let vllm = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        assert!(
            nexus.mean_tbt < vllm.mean_tbt,
            "nexus TBT {} must beat vllm {}",
            nexus.mean_tbt,
            vllm.mean_tbt
        );
    }

    #[test]
    fn spf_improves_ttft_over_fcfs_variant() {
        let cfg = cfg();
        let trace = generate(Dataset::Mixed, 60, 3.0, 13);
        let spf = NexusEngine::new(&cfg, NexusFlags { use_spf: true, dynamic_sm: true })
            .run(&trace)
            .summary();
        let fcfs = NexusEngine::new(&cfg, NexusFlags { use_spf: false, dynamic_sm: true })
            .run(&trace)
            .summary();
        assert!(
            spf.mean_ttft < fcfs.mean_ttft,
            "SPF TTFT {} must beat FCFS {}",
            spf.mean_ttft,
            fcfs.mean_ttft
        );
    }

    #[test]
    fn repartitions_happen_and_hysteresis_suppresses() {
        let cfg = cfg();
        let trace = generate(Dataset::Mixed, 80, 4.0, 17);
        let m = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace);
        assert!(m.repartitions > 0, "dynamic workload must trigger repartitioning");
        assert!(
            m.suppressed_repartitions > 0,
            "hysteresis should suppress some proposals"
        );
    }

    #[test]
    fn static_split_never_repartitions() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 30, 3.0, 19);
        let m = NexusEngine::new(&cfg, NexusFlags { use_spf: true, dynamic_sm: false })
            .run(&trace);
        assert_eq!(m.repartitions, 0);
        assert_eq!(m.summary().completed, 30);
    }
}
