//! Nexus — proactive intra-GPU prefill/decode disaggregation (paper §4).
//!
//! Two concurrent streams on one GPU (green-context style), with:
//! * per-batch SM partitioning from the contention-aware cost model +
//!   greedy dual-objective search (Algorithm 1, [`crate::partition`]);
//! * hysteresis-buffered asynchronous switching (§4.2): partitions apply at
//!   the next kernel launch, small changes are suppressed;
//! * phase-specific schedulers (§4.3): Shortest-Prompt-First with age decay
//!   for prefill (Algorithm 2), FCFS for decode.
//!
//! Ablation flags reproduce the Fig.-13 variants: `use_spf = false` falls
//! back to FCFS prefill ("PF-DF"); `dynamic_sm = false` pins a static 50/50
//! split ("Wo-SC").

use super::common::{chunk_attn_pairs, ArrivalFeed, ReqState};
use super::EngineCfg;
use crate::costmodel::{calibrate, CostModel};
use crate::gpusim::Sim;
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::partition::{BatchState, PartitionController};
use crate::sched::{fcfs_batch, spf_batch, PrefillItem};
use crate::workload::Request;
use std::time::Instant;

const PREFILL_STREAM: usize = 0;
const DECODE_STREAM: usize = 1;

/// Nexus ablation switches (Fig. 13).
#[derive(Debug, Clone, Copy)]
pub struct NexusFlags {
    /// SPF prefill scheduling (false → FCFS, the "PF-DF" variants).
    pub use_spf: bool,
    /// Dynamic SM repartitioning (false → static 50/50, "Wo-SC").
    pub dynamic_sm: bool,
}

impl Default for NexusFlags {
    fn default() -> Self {
        NexusFlags { use_spf: true, dynamic_sm: true }
    }
}

struct Iter {
    /// Decode iteration: ids receiving one token. Prefill iteration: empty.
    decode_ids: Vec<usize>,
    prefill_parts: Vec<(usize, usize)>,
    start: f64,
}

pub struct NexusEngine<'c> {
    cfg: &'c EngineCfg,
    pub flags: NexusFlags,
}

impl<'c> NexusEngine<'c> {
    pub fn new(cfg: &'c EngineCfg, flags: NexusFlags) -> Self {
        NexusEngine { cfg, flags }
    }

    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let cfg = self.cfg;
        let cost: CostModel = calibrate(&cfg.gpu);
        let mut sim = Sim::new(cfg.gpu, 2);
        sim.set_partition(PREFILL_STREAM, 0.5);
        sim.set_partition(DECODE_STREAM, 0.5);
        let mut controller = PartitionController::new(cfg.partition);
        let mut kv = cfg.kv_cache();
        let mut metrics = RunMetrics::default();

        let mut states: Vec<Option<ReqState>> = vec![None; trace.len()];
        let mut waiting: Vec<usize> = Vec::new();
        let mut running: Vec<usize> = Vec::new();
        let mut inflight: [Option<Iter>; 2] = [None, None];
        let mut feed = ArrivalFeed::new(trace);
        let mut done = 0usize;
        let mut tag = 0u64;
        // Partition-trajectory accounting (time-weighted).
        let mut rp_time = 0.0f64;
        let mut decode_mode_time = 0.0f64;
        let mut kv_time = 0.0f64;
        let mut last_t = 0.0f64;

        while done < trace.len() {
            let t_arr = feed.peek_time();
            let t_sim = sim.peek_next_completion();
            let t = match (t_arr, t_sim) {
                (Some(a), Some(s)) => a.min(s),
                (Some(a), None) => a,
                (None, Some(s)) => s,
                (None, None) => sim.now(),
            };
            if t > cfg.max_virtual_time {
                metrics.timeouts = trace.len() - done;
                break;
            }
            let dt = (t - last_t).max(0.0);
            rp_time += controller.r_p * dt;
            kv_time += kv.usage() * dt;
            metrics.peak_kv_usage = metrics.peak_kv_usage.max(kv.usage());
            if controller.mode_for(kv.usage()) == crate::partition::Mode::DecodePrioritized {
                decode_mode_time += dt;
            }
            last_t = t;
            let completions = sim.advance_to(t + 1e-12);
            for r in feed.pop_until(t) {
                states[r.id] = Some(ReqState::new(*r));
                waiting.push(r.id);
            }
            for c in completions {
                let it = inflight[c.stream].take().expect("completion without inflight");
                let now = c.time;
                let dur = now - it.start;
                for id in it.decode_ids {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.note_token(now, dur);
                    if st.decode_done() {
                        let st = states[id].take().unwrap();
                        kv.release(id);
                        running.retain(|&x| x != id);
                        metrics.push(st.into_record(now));
                        done += 1;
                    }
                }
                for (id, take) in it.prefill_parts {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.queue_time += (it.start - st.queue_since).max(0.0);
                    st.queue_since = now;
                    st.prefilled += take;
                    if st.prefill_done() {
                        waiting.retain(|&x| x != id);
                        if st.generated > 0 {
                            running.push(id); // resumed after recompute
                        } else {
                            st.note_first_token(now);
                            if st.decode_done() {
                                let st = states[id].take().unwrap();
                                kv.release(id);
                                metrics.push(st.into_record(now));
                                done += 1;
                            } else {
                                running.push(id);
                            }
                        }
                    }
                }
            }

            // Schedule idle streams. Decode first: it is latency-critical
            // and its batch state feeds the partition decision.
            for stream in [DECODE_STREAM, PREFILL_STREAM] {
                if inflight[stream].is_none() {
                    inflight[stream] = self.schedule_stream(
                        stream, &mut sim, &cost, &mut controller, &mut kv, &mut states,
                        &mut waiting, &mut running, &mut metrics, &mut tag,
                    );
                }
            }

            if inflight.iter().all(Option::is_none) && feed.exhausted() && done < trace.len() {
                metrics.timeouts = trace.len() - done;
                break;
            }
        }
        metrics.repartitions = controller.applied_count;
        metrics.suppressed_repartitions = controller.suppressed_count;
        if last_t > 0.0 {
            metrics.mean_rp = rp_time / last_t;
            metrics.decode_mode_frac = decode_mode_time / last_t;
            metrics.mean_kv_usage = kv_time / last_t;
        }
        metrics
    }

    /// Build, partition, and submit the next batch for one stream.
    #[allow(clippy::too_many_arguments)]
    fn schedule_stream(
        &mut self,
        stream: usize,
        sim: &mut Sim,
        cost: &CostModel,
        controller: &mut PartitionController,
        kv: &mut KvCache,
        states: &mut [Option<ReqState>],
        waiting: &mut Vec<usize>,
        running: &mut Vec<usize>,
        metrics: &mut RunMetrics,
        tag: &mut u64,
    ) -> Option<Iter> {
        let wall = Instant::now();
        let cfg = self.cfg;
        let now = sim.now();

        let (decode_ids, prefill_parts, ops) = if stream == DECODE_STREAM {
            // FCFS decode: every running request contributes one token.
            let mut ids: Vec<usize> = running.clone();
            ids.truncate(cfg.max_batch);
            let mut decode_ids = Vec::with_capacity(ids.len());
            for id in ids {
                loop {
                    if kv.try_reserve(id, 1) {
                        decode_ids.push(id);
                        break;
                    }
                    let victim = running
                        .iter()
                        .copied()
                        .filter(|&v| v != id)
                        .max_by(|&a, &b| {
                            let aa = states[a].as_ref().unwrap().req.arrival;
                            let bb = states[b].as_ref().unwrap().req.arrival;
                            aa.partial_cmp(&bb).unwrap()
                        });
                    match victim {
                        Some(v) => {
                            kv.release(v);
                            running.retain(|&x| x != v);
                            decode_ids.retain(|&x| x != v);
                            states[v].as_mut().unwrap().restart_for_recompute(now);
                            waiting.push(v);
                            metrics.recomputes += 1;
                        }
                        None => break,
                    }
                }
            }
            if decode_ids.is_empty() {
                return None;
            }
            let ctx: f64 = decode_ids.iter().map(|&id| kv.tokens(id) as f64).sum();
            let ops = cfg.model.decode_ops(decode_ids.len(), ctx);
            (decode_ids, Vec::new(), ops)
        } else {
            // Prefill: SPF (Algorithm 2) or FCFS ablation, over the token
            // budget, chunking the head request if nothing fits whole.
            let queue: Vec<PrefillItem> = waiting
                .iter()
                .map(|&id| {
                    let st = states[id].as_ref().unwrap();
                    PrefillItem {
                        id,
                        prompt_len: st.effective_prompt,
                        prefilled: st.prefilled,
                        arrival: st.req.arrival,
                    }
                })
                .collect();
            if queue.is_empty() {
                return None;
            }
            let picked = if self.flags.use_spf {
                spf_batch(&queue, now, cfg.token_budget, cfg.gamma)
            } else {
                fcfs_batch(&queue, cfg.token_budget, true)
            };
            let mut prefill_parts: Vec<(usize, usize)> = Vec::new();
            let mut left = cfg.token_budget;
            for qidx in picked {
                let item = &queue[qidx];
                let take = item.remaining().min(cfg.chunk_size).min(left);
                if take == 0 {
                    break;
                }
                if kv.try_reserve(item.id, take) {
                    prefill_parts.push((item.id, take));
                    left -= take;
                }
            }
            if prefill_parts.is_empty() {
                return None;
            }
            let n: usize = prefill_parts.iter().map(|&(_, t)| t).sum();
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            let mut finishing = 0usize;
            for &(id, take) in &prefill_parts {
                let st = states[id].as_ref().unwrap();
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                if st.prefilled + take >= st.effective_prompt {
                    finishing += 1;
                }
            }
            let ops = cfg.model.prefill_ops(n, pairs, kv_read, finishing);
            (Vec::new(), prefill_parts, ops)
        };

        // Proactive per-batch partition decision (Algorithm 1). The other
        // phase's ops are estimated from its current queue/batch state.
        if self.flags.dynamic_sm {
            let other_ops = if stream == DECODE_STREAM {
                self.estimate_prefill_ops(states, waiting, cfg)
            } else {
                self.estimate_decode_ops(states, running, kv, cfg)
            };
            let (pre_ops, dec_ops): (&[OpWork], &[OpWork]) = if stream == DECODE_STREAM {
                (&other_ops, &ops)
            } else {
                (&ops, &other_ops)
            };
            let decision = controller.decide(
                cost,
                &BatchState { prefill_ops: pre_ops, decode_ops: dec_ops, kv_usage: kv.usage() },
            );
            if decision.applied {
                sim.set_partition(PREFILL_STREAM, decision.r_p);
                sim.set_partition(DECODE_STREAM, decision.r_d);
            }
        }

        *tag += 1;
        sim.submit(stream, &ops, *tag);

        let sched = wall.elapsed().as_secs_f64();
        let parts = decode_ids.len() + prefill_parts.len();
        let share = sched / parts.max(1) as f64;
        for &id in &decode_ids {
            states[id].as_mut().unwrap().sched_time += share;
        }
        for &(id, _) in &prefill_parts {
            states[id].as_mut().unwrap().sched_time += share;
        }

        Some(Iter { decode_ids, prefill_parts, start: now })
    }

    /// Estimate the next prefill batch's ops for the partition decision.
    fn estimate_prefill_ops(
        &self,
        states: &[Option<ReqState>],
        waiting: &[usize],
        cfg: &EngineCfg,
    ) -> Vec<OpWork> {
        if waiting.is_empty() {
            return Vec::new();
        }
        let mut n = 0usize;
        let mut pairs = 0.0;
        let mut kv_read = 0.0;
        for &id in waiting {
            let st = states[id].as_ref().unwrap();
            let take = (st.effective_prompt - st.prefilled)
                .min(cfg.chunk_size)
                .min(cfg.token_budget - n);
            if take == 0 {
                break;
            }
            pairs += chunk_attn_pairs(st.prefilled, take);
            kv_read += (st.prefilled + take) as f64;
            n += take;
        }
        if n == 0 {
            return Vec::new();
        }
        cfg.model.prefill_ops(n, pairs, kv_read, 0)
    }

    /// Estimate the current decode batch's ops for the partition decision.
    fn estimate_decode_ops(
        &self,
        states: &[Option<ReqState>],
        running: &[usize],
        kv: &KvCache,
        cfg: &EngineCfg,
    ) -> Vec<OpWork> {
        if running.is_empty() {
            return Vec::new();
        }
        let n = running.len().min(cfg.max_batch);
        let ctx: f64 = running.iter().take(n).map(|&id| kv.tokens(id) as f64).sum();
        let _ = states;
        cfg.model.decode_ops(n, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::monolithic::MonolithicEngine;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace);
        assert_eq!(m.summary().completed, 40);
        assert_eq!(m.timeouts, 0);
    }

    #[test]
    fn beats_vllm_tbt_under_long_prompts() {
        // Phase isolation must beat mixed batching on decode latency when
        // long prefill chunks are in play (the paper's headline TBT claim).
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 40, 2.5, 11);
        let nexus = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace).summary();
        let vllm = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        assert!(
            nexus.mean_tbt < vllm.mean_tbt,
            "nexus TBT {} must beat vllm {}",
            nexus.mean_tbt,
            vllm.mean_tbt
        );
    }

    #[test]
    fn spf_improves_ttft_over_fcfs_variant() {
        let cfg = cfg();
        let trace = generate(Dataset::Mixed, 60, 3.0, 13);
        let spf = NexusEngine::new(&cfg, NexusFlags { use_spf: true, dynamic_sm: true })
            .run(&trace)
            .summary();
        let fcfs = NexusEngine::new(&cfg, NexusFlags { use_spf: false, dynamic_sm: true })
            .run(&trace)
            .summary();
        assert!(
            spf.mean_ttft < fcfs.mean_ttft,
            "SPF TTFT {} must beat FCFS {}",
            spf.mean_ttft,
            fcfs.mean_ttft
        );
    }

    #[test]
    fn repartitions_happen_and_hysteresis_suppresses() {
        let cfg = cfg();
        let trace = generate(Dataset::Mixed, 80, 4.0, 17);
        let m = NexusEngine::new(&cfg, NexusFlags::default()).run(&trace);
        assert!(m.repartitions > 0, "dynamic workload must trigger repartitioning");
        assert!(
            m.suppressed_repartitions > 0,
            "hysteresis should suppress some proposals"
        );
    }

    #[test]
    fn static_split_never_repartitions() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 30, 3.0, 19);
        let m = NexusEngine::new(&cfg, NexusFlags { use_spf: true, dynamic_sm: false })
            .run(&trace);
        assert_eq!(m.repartitions, 0);
        assert_eq!(m.summary().completed, 30);
    }
}
