//! Monolithic chunked-prefill engine — the vLLM v1 / Sarathi-Serve baseline,
//! plus the SGLang variant (RadixAttention prefix-cache model).
//!
//! One GPU stream runs *mixed* batches: every running decode contributes one
//! token and the remaining token budget is filled with FCFS prefill chunks.
//! Because the whole iteration completes as a unit, lightweight decode
//! tokens experience the full mixed-iteration latency — the fine-grained
//! interference the paper measures in Fig. 4.

use super::common::{chunk_attn_pairs, ArrivalFeed, ReqState};
use super::EngineCfg;
use crate::gpusim::Sim;
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::sched::{mixed_batch, PrefillItem, RadixCache};
use crate::workload::Request;
use std::time::Instant;

/// In-flight mixed-iteration manifest.
struct Iter {
    decode_ids: Vec<usize>,
    /// (request id, prefill tokens taken this iteration).
    prefill_parts: Vec<(usize, usize)>,
    start: f64,
}

pub struct MonolithicEngine<'c> {
    cfg: &'c EngineCfg,
    /// SGLang mode: prefix cache shrinking effective prefill lengths.
    radix: Option<RadixCache>,
}

impl<'c> MonolithicEngine<'c> {
    pub fn vllm(cfg: &'c EngineCfg) -> Self {
        MonolithicEngine { cfg, radix: None }
    }

    pub fn sglang(cfg: &'c EngineCfg) -> Self {
        let (p, f) = cfg.radix;
        MonolithicEngine { cfg, radix: Some(RadixCache::new(p, f, cfg.seed ^ 0x5617)) }
    }

    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let cfg = self.cfg;
        let mut sim = Sim::new(cfg.gpu, 1);
        sim.set_partition(0, 1.0);
        let mut kv = cfg.kv_cache();
        let mut metrics = RunMetrics::default();

        let mut states: Vec<Option<ReqState>> = vec![None; trace.len()];
        let mut waiting: Vec<usize> = Vec::new();
        let mut running: Vec<usize> = Vec::new();
        let mut inflight: Option<Iter> = None;
        let mut feed = ArrivalFeed::new(trace);
        let mut done = 0usize;
        let mut tag = 0u64;

        while done < trace.len() {
            // Next event: arrival or iteration completion.
            let t_arr = feed.peek_time();
            let t_sim = if inflight.is_some() { sim.peek_next_completion() } else { None };
            let t = match (t_arr, t_sim) {
                (Some(a), Some(s)) => a.min(s),
                (Some(a), None) => a,
                (None, Some(s)) => s,
                (None, None) => {
                    // No arrivals, nothing in flight — but requests remain:
                    // schedule must make progress below from current queues.
                    sim.now()
                }
            };
            if t > cfg.max_virtual_time {
                metrics.timeouts = trace.len() - done;
                break;
            }
            let completions = sim.advance_to(t + 1e-12);
            for r in feed.pop_until(t) {
                let mut st = ReqState::new(*r);
                if let Some(radix) = &mut self.radix {
                    st.effective_prompt = radix.effective_prefill(r.prompt_len);
                }
                states[r.id] = Some(st);
                waiting.push(r.id);
            }
            for c in completions {
                let it = inflight.take().expect("completion without inflight iter");
                debug_assert_eq!(c.tag, tag);
                let now = c.time;
                let dur = now - it.start;
                // Decode tokens.
                for id in it.decode_ids {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.note_token(now, dur);
                    if st.decode_done() {
                        let st = states[id].take().unwrap();
                        kv.release(id);
                        running.retain(|&x| x != id);
                        metrics.push(st.into_record(now));
                        done += 1;
                    }
                }
                // Prefill chunks.
                for (id, take) in it.prefill_parts {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.queue_time += (it.start - st.queue_since).max(0.0);
                    st.queue_since = now;
                    st.prefilled += take;
                    if st.prefill_done() {
                        waiting.retain(|&x| x != id);
                        if st.generated > 0 {
                            // Recompute path: tokens already emitted; resume decode.
                            running.push(id);
                        } else {
                            st.note_first_token(now);
                            if st.decode_done() {
                                let st = states[id].take().unwrap();
                                kv.release(id);
                                metrics.push(st.into_record(now));
                                done += 1;
                            } else {
                                running.push(id);
                            }
                        }
                    }
                }
            }
            if inflight.is_none() {
                inflight = self.schedule(
                    &mut sim, &mut kv, &mut states, &mut waiting, &mut running, &mut metrics,
                    &mut tag,
                );
                if inflight.is_none() && feed.exhausted() && done < trace.len() {
                    // Nothing schedulable and nothing will arrive: requests
                    // whose KV can never fit. Mark the rest as timeouts.
                    metrics.timeouts = trace.len() - done;
                    break;
                }
            }
        }
        metrics
    }

    /// Build and submit the next mixed iteration. Returns its manifest.
    #[allow(clippy::too_many_arguments)]
    fn schedule(
        &mut self,
        sim: &mut Sim,
        kv: &mut KvCache,
        states: &mut [Option<ReqState>],
        waiting: &mut Vec<usize>,
        running: &mut Vec<usize>,
        metrics: &mut RunMetrics,
        tag: &mut u64,
    ) -> Option<Iter> {
        let wall = Instant::now();
        let cfg = self.cfg;
        let now = sim.now();

        // Continuous batching: every running decode joins (capped), each
        // reserving one more KV token. On OOM, vLLM preempts the most
        // recently arrived running request (recompute-on-resume).
        let mut decode_ids: Vec<usize> = Vec::new();
        let mut candidates = running.clone();
        candidates.truncate(cfg.max_batch);
        for id in candidates {
            loop {
                if kv.try_reserve(id, 1) {
                    decode_ids.push(id);
                    break;
                }
                // Preempt the newest running request that is not `id`.
                let victim = running
                    .iter()
                    .copied()
                    .filter(|&v| v != id)
                    .max_by(|&a, &b| {
                        let aa = states[a].as_ref().unwrap().req.arrival;
                        let bb = states[b].as_ref().unwrap().req.arrival;
                        aa.partial_cmp(&bb).unwrap()
                    });
                match victim {
                    Some(v) => {
                        kv.release(v);
                        running.retain(|&x| x != v);
                        decode_ids.retain(|&x| x != v);
                        let st = states[v].as_mut().unwrap();
                        st.restart_for_recompute(now);
                        waiting.push(v);
                        metrics.recomputes += 1;
                    }
                    None => break, // lone request can't grow: stall this tick
                }
            }
        }

        // FCFS prefill chunks fill the remaining token budget.
        let queue: Vec<PrefillItem> = waiting
            .iter()
            .map(|&id| {
                let st = states[id].as_ref().unwrap();
                PrefillItem {
                    id,
                    prompt_len: st.effective_prompt,
                    prefilled: st.prefilled,
                    arrival: st.req.arrival,
                }
            })
            .collect();
        let mixed = mixed_batch(&decode_ids, &queue, cfg.token_budget, cfg.chunk_size);

        let mut prefill_parts: Vec<(usize, usize)> = Vec::new();
        for (qidx, take) in mixed.prefill_parts {
            let id = queue[qidx].id;
            if kv.try_reserve(id, take) {
                prefill_parts.push((id, take));
            }
            // On reserve failure the chunk is dropped this iteration; decode
            // completions free blocks and the request retries next tick.
        }

        if decode_ids.is_empty() && prefill_parts.is_empty() {
            return None;
        }

        // Compose the iteration's operator list (decode + prefill share it —
        // that is exactly the interference mechanism).
        let mut ops: Vec<OpWork> = Vec::new();
        if !decode_ids.is_empty() {
            let ctx: f64 = decode_ids.iter().map(|&id| kv.tokens(id) as f64).sum();
            ops.extend(cfg.model.decode_ops(decode_ids.len(), ctx));
        }
        if !prefill_parts.is_empty() {
            let n: usize = prefill_parts.iter().map(|&(_, t)| t).sum();
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            let mut finishing = 0usize;
            for &(id, take) in &prefill_parts {
                let st = states[id].as_ref().unwrap();
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                if st.prefilled + take >= st.effective_prompt {
                    finishing += 1;
                }
            }
            ops.extend(cfg.model.prefill_ops(n, pairs, kv_read, finishing));
        }

        *tag += 1;
        sim.submit(0, &ops, *tag);

        // Attribute real scheduler wall time across participants (Fig. 12).
        let sched = wall.elapsed().as_secs_f64();
        let parts = decode_ids.len() + prefill_parts.len();
        if parts > 0 {
            let share = sched / parts as f64;
            for &id in &decode_ids {
                states[id].as_mut().unwrap().sched_time += share;
            }
            for &(id, _) in &prefill_parts {
                states[id].as_mut().unwrap().sched_time += share;
            }
        }

        Some(Iter { decode_ids, prefill_parts, start: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 40);
        assert_eq!(m.timeouts, 0);
    }

    #[test]
    fn ttft_after_arrival_and_ordered_tokens() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 20, 2.0, 3);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        for r in &m.records {
            assert!(r.first_token >= r.arrival, "ttft must be ≥ 0");
            assert!(r.finish >= r.first_token);
            assert_eq!(r.token_gaps.len(), r.output_len.saturating_sub(1));
            for g in &r.token_gaps {
                assert!(*g >= 0.0);
            }
        }
    }

    #[test]
    fn mixed_batches_inflate_decode_latency() {
        // The Fig.-4 mechanism: with long prompts arriving, decode gaps are
        // far larger than a pure decode iteration would be.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 30, 2.5, 11);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        let s = m.summary();
        // A pure decode-only iteration for this model is ~10-20 ms.
        assert!(s.mean_tbt > 0.030, "mean TBT {} should show interference", s.mean_tbt);
    }

    #[test]
    fn sglang_radix_beats_vllm_ttft_on_chat() {
        let mut cfg = cfg();
        cfg.radix = (0.6, 0.6);
        let trace = generate(Dataset::ShareGpt, 60, 6.0, 9);
        let v = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        let s = MonolithicEngine::sglang(&cfg).run(&trace).summary();
        assert!(
            s.mean_ttft < v.mean_ttft,
            "radix cache should cut TTFT: sglang {} vs vllm {}",
            s.mean_ttft,
            v.mean_ttft
        );
    }

    #[test]
    fn offline_batch_drains() {
        let cfg = cfg();
        let trace = crate::workload::offline(Dataset::ShareGpt, 30, 5);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 30);
        assert!(m.makespan > 0.0);
    }
}
