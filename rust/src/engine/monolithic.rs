//! Monolithic chunked-prefill engine — the vLLM v1 / Sarathi-Serve baseline,
//! plus the SGLang variant (RadixAttention prefix-cache model).
//!
//! One GPU stream runs *mixed* batches: every running decode contributes one
//! token and the remaining token budget is filled with FCFS prefill chunks.
//! Because the whole iteration completes as a unit, lightweight decode
//! tokens experience the full mixed-iteration latency — the fine-grained
//! interference the paper measures in Fig. 4.
//!
//! Hot-path layout (§Perf): `waiting` / `running` are insertion-ordered
//! indexed sets ([`OrderedIdSet`]) so membership updates are O(1) instead of
//! the historical `Vec::retain` scans, and every per-iteration collection
//! (candidate list, prefill queue, operator list, completion list, batch
//! manifests) draws from reusable buffers — steady-state batch assembly
//! performs zero allocations.

use super::common::{chunk_attn_pairs, ReqState};
use super::{Engine, EngineCfg, EngineKind, StepOutcome};
use crate::gpusim::{Completion, Sim};
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::sched::{mixed_batch_into, MixedBatch, PrefillItem, RadixCache, SchedScratch};
use crate::trace::{EngineSnapshot, EventKind, PreemptKind, TracePhase, Tracer};
use crate::util::OrderedIdSet;
use crate::workload::Request;
use std::time::Instant;

/// In-flight mixed-iteration manifest.
struct Iter {
    decode_ids: Vec<usize>,
    /// (request id, prefill tokens taken this iteration).
    prefill_parts: Vec<(usize, usize)>,
    start: f64,
}

pub struct MonolithicEngine {
    cfg: EngineCfg,
    /// SGLang mode: prefix cache shrinking effective prefill lengths.
    radix: Option<RadixCache>,
    sim: Sim,
    kv: KvCache,
    metrics: RunMetrics,
    states: Vec<Option<ReqState>>,
    waiting: OrderedIdSet,
    running: OrderedIdSet,
    inflight: Option<Iter>,
    injected: usize,
    done: usize,
    tag: u64,
    // Reusable hot-path buffers (§Perf).
    cand_buf: Vec<usize>,
    queue_buf: Vec<PrefillItem>,
    ops_buf: Vec<OpWork>,
    comp_buf: Vec<Completion>,
    mixed_buf: MixedBatch,
    scratch: SchedScratch,
    /// Recycled `Iter` vectors (returned on completion, reused on schedule).
    spare_ids: Vec<Vec<usize>>,
    spare_parts: Vec<Vec<(usize, usize)>>,
    tracer: Tracer,
}

impl MonolithicEngine {
    pub fn vllm(cfg: &EngineCfg) -> Self {
        Self::build(cfg, None)
    }

    pub fn sglang(cfg: &EngineCfg) -> Self {
        let (p, f) = cfg.radix;
        Self::build(cfg, Some(RadixCache::new(p, f, cfg.seed ^ 0x5617)))
    }

    fn build(cfg: &EngineCfg, radix: Option<RadixCache>) -> Self {
        let mut sim = Sim::new(cfg.gpu, 1);
        sim.set_partition(0, 1.0);
        let kv = cfg.kv_cache();
        MonolithicEngine {
            cfg: cfg.clone(),
            radix,
            sim,
            kv,
            metrics: RunMetrics::default(),
            states: Vec::new(),
            waiting: OrderedIdSet::new(),
            running: OrderedIdSet::new(),
            inflight: None,
            injected: 0,
            done: 0,
            tag: 0,
            cand_buf: Vec::new(),
            queue_buf: Vec::new(),
            ops_buf: Vec::new(),
            comp_buf: Vec::new(),
            mixed_buf: MixedBatch::default(),
            scratch: SchedScratch::default(),
            spare_ids: Vec::new(),
            spare_parts: Vec::new(),
            tracer: Tracer::default(),
        }
    }

    /// Run over a whole trace (fresh state each call).
    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let mut eng = if self.radix.is_some() {
            Self::sglang(&self.cfg)
        } else {
            Self::vllm(&self.cfg)
        };
        super::drive(&mut eng, trace, self.cfg.max_virtual_time)
    }

    fn slot(&mut self, id: usize) {
        if id >= self.states.len() {
            self.states.resize_with(id + 1, || None);
        }
    }

    /// Build and submit the next mixed iteration. Returns its manifest.
    fn schedule(&mut self) -> Option<Iter> {
        let wall = Instant::now();
        let now = self.sim.now();

        // Continuous batching: every running decode joins (capped), each
        // reserving one more KV token. On OOM, vLLM preempts the most
        // recently arrived running request (recompute-on-resume).
        let mut decode_ids = self.spare_ids.pop().unwrap_or_default();
        decode_ids.clear();
        let mut cand = std::mem::take(&mut self.cand_buf);
        cand.clear();
        cand.extend(self.running.iter().take(self.cfg.max_batch));
        for &id in &cand {
            loop {
                if self.kv.try_reserve(id, 1) {
                    decode_ids.push(id);
                    break;
                }
                // Preempt the newest running request that is not `id` (ties
                // break toward the latest-ordered entry, like the historical
                // `Iterator::max_by` over the running vec).
                let mut victim: Option<usize> = None;
                let mut victim_arrival = f64::NEG_INFINITY;
                for v in self.running.iter() {
                    if v == id {
                        continue;
                    }
                    let a = self.states[v].as_ref().unwrap().req.arrival;
                    if a >= victim_arrival {
                        victim_arrival = a;
                        victim = Some(v);
                    }
                }
                match victim {
                    Some(v) => {
                        self.kv.release(v);
                        self.running.remove(v);
                        decode_ids.retain(|&x| x != v);
                        let st = self.states[v].as_mut().unwrap();
                        st.restart_for_recompute(now);
                        self.waiting.insert(v);
                        self.metrics.recomputes += 1;
                        self.tracer.emit(
                            now,
                            EventKind::Preempt { req: v, kind: PreemptKind::Recompute },
                        );
                    }
                    None => break, // lone request can't grow: stall this tick
                }
            }
        }
        self.cand_buf = cand;

        // FCFS prefill chunks fill the remaining token budget.
        self.queue_buf.clear();
        {
            let queue_buf = &mut self.queue_buf;
            let states = &self.states;
            queue_buf.extend(self.waiting.iter().map(|id| {
                let st = states[id].as_ref().unwrap();
                PrefillItem {
                    id,
                    prompt_len: st.effective_prompt,
                    prefilled: st.prefilled,
                    arrival: st.req.arrival,
                }
            }));
        }
        mixed_batch_into(
            decode_ids.len(),
            &self.queue_buf,
            self.cfg.token_budget,
            self.cfg.chunk_size,
            &mut self.scratch,
            &mut self.mixed_buf,
        );

        let mixed = std::mem::take(&mut self.mixed_buf);
        let mut prefill_parts = self.spare_parts.pop().unwrap_or_default();
        prefill_parts.clear();
        for &(qidx, take) in &mixed.prefill_parts {
            let id = self.queue_buf[qidx].id;
            if self.kv.try_reserve(id, take) {
                prefill_parts.push((id, take));
                if self.tracer.enabled() {
                    self.tracer.emit(
                        now,
                        EventKind::KvAlloc { req: id, tokens: take, usage: self.kv.usage() },
                    );
                }
            }
            // On reserve failure the chunk is dropped this iteration; decode
            // completions free blocks and the request retries next tick.
        }
        self.mixed_buf = mixed;

        if decode_ids.is_empty() && prefill_parts.is_empty() {
            self.spare_ids.push(decode_ids);
            self.spare_parts.push(prefill_parts);
            return None;
        }

        // Compose the iteration's operator list (decode + prefill share it —
        // that is exactly the interference mechanism).
        self.ops_buf.clear();
        if !decode_ids.is_empty() {
            let ctx: f64 = decode_ids.iter().map(|&id| self.kv.tokens(id) as f64).sum();
            self.cfg.model.decode_ops_into(decode_ids.len(), ctx, &mut self.ops_buf);
        }
        if !prefill_parts.is_empty() {
            let n: usize = prefill_parts.iter().map(|&(_, t)| t).sum();
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            let mut finishing = 0usize;
            for &(id, take) in &prefill_parts {
                let st = self.states[id].as_ref().unwrap();
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                if st.prefilled + take >= st.effective_prompt {
                    finishing += 1;
                }
            }
            self.cfg.model.prefill_ops_into(n, pairs, kv_read, finishing, &mut self.ops_buf);
        }

        self.tag += 1;
        self.sim.submit(0, &self.ops_buf, self.tag);
        if self.tracer.enabled() {
            let tokens: usize =
                decode_ids.len() + prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
            self.tracer.emit(
                now,
                EventKind::BatchStart {
                    phase: TracePhase::of(decode_ids.len(), prefill_parts.len()),
                    seqs: decode_ids.len() + prefill_parts.len(),
                    tokens,
                },
            );
        }

        // Attribute real scheduler wall time across participants (Fig. 12).
        let sched = wall.elapsed().as_secs_f64();
        let parts = decode_ids.len() + prefill_parts.len();
        if parts > 0 {
            let share = sched / parts as f64;
            for &id in &decode_ids {
                self.states[id].as_mut().unwrap().sched_time += share;
            }
            for &(id, _) in &prefill_parts {
                self.states[id].as_mut().unwrap().sched_time += share;
            }
        }

        Some(Iter { decode_ids, prefill_parts, start: now })
    }
}

impl Engine for MonolithicEngine {
    fn kind(&self) -> EngineKind {
        if self.radix.is_some() {
            EngineKind::Sglang
        } else {
            EngineKind::Vllm
        }
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn next_event(&mut self) -> Option<f64> {
        if self.inflight.is_some() {
            self.sim.peek_next_completion()
        } else {
            None
        }
    }

    fn inject_effective(&mut self, req: Request, eff: Option<usize>) {
        let mut st = ReqState::new(req);
        match eff {
            // Cluster prefix tier already resolved the prefill length; the
            // radix RNG is deliberately not consumed.
            Some(e) => st.effective_prompt = e.max(1),
            None => {
                if let Some(radix) = &mut self.radix {
                    st.effective_prompt = radix.effective_prefill(req.plen());
                }
            }
        }
        self.slot(req.id);
        self.states[req.id] = Some(st);
        self.waiting.insert(req.id);
        self.injected += 1;
        self.tracer.emit(req.arrival, EventKind::Admit { req: req.id });
    }

    fn step(&mut self, t: f64) -> StepOutcome {
        let mut comps = std::mem::take(&mut self.comp_buf);
        self.sim.advance_to_into(t + 1e-12, &mut comps);
        let mut finished = 0usize;
        for &c in &comps {
            let it = self.inflight.take().expect("completion without inflight iter");
            debug_assert_eq!(c.tag, self.tag);
            let now = c.time;
            let dur = now - it.start;
            if self.tracer.enabled() {
                let tokens: usize =
                    it.decode_ids.len() + it.prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
                self.tracer.emit(
                    now,
                    EventKind::BatchEnd {
                        phase: TracePhase::of(it.decode_ids.len(), it.prefill_parts.len()),
                        seqs: it.decode_ids.len() + it.prefill_parts.len(),
                        tokens,
                        dur,
                    },
                );
            }
            // Decode tokens.
            for &id in &it.decode_ids {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.note_token(now, dur);
                if st.decode_done() {
                    let st = self.states[id].take().unwrap();
                    self.kv.release(id);
                    self.running.remove(id);
                    self.metrics.push(st.into_record(now));
                    self.done += 1;
                    finished += 1;
                    self.tracer.emit(now, EventKind::Complete { req: id });
                }
            }
            // Prefill chunks.
            for &(id, take) in &it.prefill_parts {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.queue_time += (it.start - st.queue_since).max(0.0);
                st.queue_since = now;
                st.prefilled += take;
                let prefill_done = st.prefill_done();
                self.tracer.emit(
                    now,
                    EventKind::PrefillChunk { req: id, take, done: prefill_done, dur },
                );
                if prefill_done {
                    self.waiting.remove(id);
                    if st.generated > 0 {
                        // Recompute path: tokens already emitted; resume decode.
                        self.running.insert(id);
                    } else {
                        st.note_first_token(now);
                        self.tracer.emit(now, EventKind::FirstToken { req: id });
                        if st.decode_done() {
                            let st = self.states[id].take().unwrap();
                            self.kv.release(id);
                            self.metrics.push(st.into_record(now));
                            self.done += 1;
                            finished += 1;
                            self.tracer.emit(now, EventKind::Complete { req: id });
                        } else {
                            self.running.insert(id);
                        }
                    }
                }
            }
            // Recycle the manifest's vectors for future iterations.
            self.spare_ids.push(it.decode_ids);
            self.spare_parts.push(it.prefill_parts);
        }
        self.comp_buf = comps;
        if self.inflight.is_none() {
            self.inflight = self.schedule();
        }
        StepOutcome { completed: finished, busy: self.inflight.is_some() }
    }

    fn pending(&self) -> usize {
        self.injected - self.done
    }

    fn completed(&self) -> usize {
        self.done
    }

    fn kv_usage(&self) -> f64 {
        self.kv.usage()
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            waiting: self.waiting.len(),
            running: self.running.len(),
            kv_usage: self.kv.usage(),
            sm_prefill: 1.0,
            inflight: usize::from(self.inflight.is_some()),
        }
    }

    fn records(&self) -> &[crate::metrics::RequestRecord] {
        &self.metrics.records
    }

    fn take_metrics(&mut self) -> RunMetrics {
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 40);
        assert_eq!(m.timeouts, 0);
    }

    #[test]
    fn ttft_after_arrival_and_ordered_tokens() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 20, 2.0, 3);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        for r in &m.records {
            assert!(r.first_token >= r.arrival, "ttft must be ≥ 0");
            assert!(r.finish >= r.first_token);
            assert_eq!(r.token_gaps.len(), r.output_len.saturating_sub(1));
            for g in &r.token_gaps {
                assert!(*g >= 0.0);
            }
        }
    }

    #[test]
    fn mixed_batches_inflate_decode_latency() {
        // The Fig.-4 mechanism: with long prompts arriving, decode gaps are
        // far larger than a pure decode iteration would be.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 30, 2.5, 11);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        let s = m.summary();
        // A pure decode-only iteration for this model is ~10-20 ms.
        assert!(s.mean_tbt > 0.030, "mean TBT {} should show interference", s.mean_tbt);
    }

    #[test]
    fn sglang_radix_beats_vllm_ttft_on_chat() {
        let mut cfg = cfg();
        cfg.radix = (0.6, 0.6);
        let trace = generate(Dataset::ShareGpt, 60, 6.0, 9);
        let v = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        let s = MonolithicEngine::sglang(&cfg).run(&trace).summary();
        assert!(
            s.mean_ttft < v.mean_ttft,
            "radix cache should cut TTFT: sglang {} vs vllm {}",
            s.mean_ttft,
            v.mean_ttft
        );
    }

    #[test]
    fn offline_batch_drains() {
        let cfg = cfg();
        let trace = crate::workload::offline(Dataset::ShareGpt, 30, 5);
        let m = MonolithicEngine::vllm(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 30);
        assert!(m.makespan > 0.0);
    }
}
