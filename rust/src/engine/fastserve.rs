//! FastServe baseline — skip-join MLFQ with CPU swap + recompute fallback.
//!
//! Reimplemented from the paper's description ([56]; no public code):
//! iteration-level scheduling from a multi-level feedback queue whose
//! levels have geometric token quanta. New requests *skip-join* the level
//! matching their prompt length; requests are demoted as they consume
//! service. Under KV pressure, low-priority requests are swapped to host
//! memory over PCIe; when swap-in fails, the KV is dropped and recomputed —
//! the collapse mode the paper observes under load (§6.2.1).
//!
//! Hot-path layout (§Perf): MLFQ levels are insertion-ordered indexed sets
//! with O(1) demotion/removal, and the per-iteration pick list, swap-victim
//! list, operator list, completion list, and batch manifests all reuse
//! engine-owned buffers.

use super::common::{chunk_attn_pairs, ReqState};
use super::{Engine, EngineCfg, EngineKind, StepOutcome};
use crate::gpusim::{Completion, Sim};
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::{OpClass, OpWork};
use crate::sched::Mlfq;
use crate::trace::{EngineSnapshot, EventKind, PreemptKind, TracePhase, Tracer};
use crate::workload::Request;
use std::time::Instant;

/// Swap out above this usage, stop below the low mark.
const SWAP_HIGH: f64 = 0.92;
const SWAP_LOW: f64 = 0.85;

struct Iter {
    decode_ids: Vec<usize>,
    prefill_parts: Vec<(usize, usize)>,
    start: f64,
}

pub struct FastServeEngine {
    cfg: EngineCfg,
    sim: Sim,
    kv: KvCache,
    mlfq: Mlfq,
    metrics: RunMetrics,
    states: Vec<Option<ReqState>>,
    inflight: Option<Iter>,
    injected: usize,
    done: usize,
    tag: u64,
    // Reusable hot-path buffers (§Perf).
    picked_buf: Vec<usize>,
    victims_buf: Vec<usize>,
    ops_buf: Vec<OpWork>,
    comp_buf: Vec<Completion>,
    /// Recycled `Iter` vectors (returned on completion, reused on schedule).
    spare_ids: Vec<Vec<usize>>,
    spare_parts: Vec<Vec<(usize, usize)>>,
    tracer: Tracer,
}

impl FastServeEngine {
    pub fn new(cfg: &EngineCfg) -> Self {
        let mut sim = Sim::new(cfg.gpu, 1);
        sim.set_partition(0, 1.0);
        let kv = cfg.kv_cache();
        let mlfq = Mlfq::new(cfg.chunk_size, 6);
        FastServeEngine {
            cfg: cfg.clone(),
            sim,
            kv,
            mlfq,
            metrics: RunMetrics::default(),
            states: Vec::new(),
            inflight: None,
            injected: 0,
            done: 0,
            tag: 0,
            picked_buf: Vec::new(),
            victims_buf: Vec::new(),
            ops_buf: Vec::new(),
            comp_buf: Vec::new(),
            spare_ids: Vec::new(),
            spare_parts: Vec::new(),
            tracer: Tracer::default(),
        }
    }

    /// Run over a whole trace (fresh state each call).
    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let mut eng = Self::new(&self.cfg);
        super::drive(&mut eng, trace, self.cfg.max_virtual_time)
    }

    fn slot(&mut self, id: usize) {
        if id >= self.states.len() {
            self.states.resize_with(id + 1, || None);
        }
    }

    fn schedule(&mut self) -> Option<Iter> {
        let wall = Instant::now();
        let now = self.sim.now();
        let mut pcie_bytes = 0.0;

        // Head-level requests, FIFO. Prefill requests run their whole
        // remaining prompt (FastServe predates chunked prefill).
        let mut picked = std::mem::take(&mut self.picked_buf);
        self.mlfq.pick_into(self.cfg.max_batch, &mut picked);
        let mut decode_ids = self.spare_ids.pop().unwrap_or_default();
        decode_ids.clear();
        let mut prefill_parts = self.spare_parts.pop().unwrap_or_default();
        prefill_parts.clear();
        let mut budget = self.cfg.token_budget;
        let mut reserve_failed = false;

        let in_batch = |decode_ids: &[usize], prefill_parts: &[(usize, usize)], id: usize| {
            decode_ids.contains(&id) || prefill_parts.iter().any(|&(p, _)| p == id)
        };
        for pick_idx in 0..picked.len() {
            let id = picked[pick_idx];
            let st = self.states[id].as_ref().unwrap();
            let needs_prefill = !st.prefill_done();
            let need_tokens = if needs_prefill { st.effective_prompt - st.prefilled } else { 1 };
            // FastServe does not chunk: an over-budget prompt may still run,
            // but at most one per iteration (joining the current decodes).
            if needs_prefill
                && need_tokens > budget
                && prefill_parts
                    .iter()
                    .any(|&(p, _)| !self.states[p].as_ref().unwrap().prefill_done())
            {
                continue;
            }
            // Bring swapped KV back before running.
            if self.kv.is_swapped(id) {
                match self.kv.swap_in(id) {
                    Some(bytes) => {
                        pcie_bytes += bytes;
                        self.metrics.swaps += 1;
                        self.tracer.emit(
                            now,
                            EventKind::Preempt { req: id, kind: PreemptKind::SwapIn },
                        );
                    }
                    None => {
                        // No room: drop and recompute later.
                        self.kv.evict(id);
                        let st = self.states[id].as_mut().unwrap();
                        st.restart_for_recompute(now);
                        self.metrics.recomputes += 1;
                        self.tracer.emit(
                            now,
                            EventKind::Preempt { req: id, kind: PreemptKind::Recompute },
                        );
                        continue;
                    }
                }
            }
            // On OOM, swap out strictly lower-priority residents (later in
            // the MLFQ pick order / unpicked) to make room.
            let mut reserved = self.kv.try_reserve(id, need_tokens);
            while !reserved {
                let victim = picked[pick_idx + 1..]
                    .iter()
                    .copied()
                    .rev() // deepest-priority first
                    .find(|&v| {
                        self.kv.tokens(v) > 0 && !in_batch(&decode_ids, &prefill_parts, v)
                    });
                match victim {
                    Some(v) => {
                        pcie_bytes += self.kv.swap_out(v);
                        self.metrics.swaps += 1;
                        self.tracer.emit(
                            now,
                            EventKind::Preempt { req: v, kind: PreemptKind::SwapOut },
                        );
                        reserved = self.kv.try_reserve(id, need_tokens);
                    }
                    None => break,
                }
            }
            if !reserved {
                reserve_failed = true;
                continue;
            }
            if needs_prefill {
                prefill_parts.push((id, need_tokens));
            } else {
                decode_ids.push(id);
            }
            budget = budget.saturating_sub(need_tokens.min(budget));
        }
        self.picked_buf = picked;

        // Proactive swap-out: push deep-level, non-batch requests to host
        // memory when usage crosses the high watermark or an admission
        // failed for lack of blocks.
        if self.kv.usage() > SWAP_HIGH || reserve_failed {
            let mut victims = std::mem::take(&mut self.victims_buf);
            victims.clear();
            victims.extend((0..self.states.len()).filter(|&id| {
                self.states[id].is_some()
                    && self.kv.tokens(id) > 0
                    && !decode_ids.contains(&id)
                    && !prefill_parts.iter().any(|&(p, _)| p == id)
            }));
            // Deepest MLFQ level (lowest priority) first.
            victims.sort_by_key(|&id| std::cmp::Reverse(self.mlfq.level_of(id).unwrap_or(0)));
            for &id in &victims {
                if self.kv.usage() <= SWAP_LOW {
                    break;
                }
                pcie_bytes += self.kv.swap_out(id);
                self.metrics.swaps += 1;
                self.tracer.emit(now, EventKind::Preempt { req: id, kind: PreemptKind::SwapOut });
            }
            self.victims_buf = victims;
        }

        if decode_ids.is_empty() && prefill_parts.is_empty() {
            self.spare_ids.push(decode_ids);
            self.spare_parts.push(prefill_parts);
            return None;
        }

        self.ops_buf.clear();
        // Swap traffic occupies PCIe and stalls the iteration.
        if pcie_bytes > 0.0 {
            self.ops_buf.push(OpWork { class: OpClass::Comm, flops: 0.0, bytes: pcie_bytes });
        }
        if !decode_ids.is_empty() {
            let ctx: f64 = decode_ids.iter().map(|&id| self.kv.tokens(id) as f64).sum();
            self.cfg.model.decode_ops_into(decode_ids.len(), ctx, &mut self.ops_buf);
        }
        if !prefill_parts.is_empty() {
            let n: usize = prefill_parts.iter().map(|&(_, t)| t).sum();
            let mut pairs = 0.0;
            let mut kv_read = 0.0;
            let mut finishing = 0usize;
            for &(id, take) in &prefill_parts {
                let st = self.states[id].as_ref().unwrap();
                pairs += chunk_attn_pairs(st.prefilled, take);
                kv_read += (st.prefilled + take) as f64;
                if st.prefilled + take >= st.effective_prompt {
                    finishing += 1;
                }
            }
            self.cfg.model.prefill_ops_into(n, pairs, kv_read, finishing, &mut self.ops_buf);
        }

        self.tag += 1;
        self.sim.submit(0, &self.ops_buf, self.tag);
        if self.tracer.enabled() {
            let tokens: usize =
                decode_ids.len() + prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
            self.tracer.emit(
                now,
                EventKind::BatchStart {
                    phase: TracePhase::of(decode_ids.len(), prefill_parts.len()),
                    seqs: decode_ids.len() + prefill_parts.len(),
                    tokens,
                },
            );
        }

        let sched = wall.elapsed().as_secs_f64();
        let parts = decode_ids.len() + prefill_parts.len();
        let share = sched / parts.max(1) as f64;
        for &id in &decode_ids {
            self.states[id].as_mut().unwrap().sched_time += share;
        }
        for &(id, _) in &prefill_parts {
            self.states[id].as_mut().unwrap().sched_time += share;
        }

        Some(Iter { decode_ids, prefill_parts, start: now })
    }
}

impl Engine for FastServeEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::FastServe
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn next_event(&mut self) -> Option<f64> {
        if self.inflight.is_some() {
            self.sim.peek_next_completion()
        } else {
            None
        }
    }

    fn inject_effective(&mut self, req: Request, eff: Option<usize>) {
        self.slot(req.id);
        let mut st = ReqState::new(req);
        if let Some(e) = eff {
            st.effective_prompt = e.max(1);
        }
        let prefill_len = st.effective_prompt;
        self.states[req.id] = Some(st);
        // Skip-join on the *effective* prefill length: a tier-shortened
        // prompt queues at the level its real work belongs to.
        self.mlfq.admit(req.id, prefill_len);
        self.injected += 1;
        self.tracer.emit(req.arrival, EventKind::Admit { req: req.id });
    }

    fn step(&mut self, t: f64) -> StepOutcome {
        let mut comps = std::mem::take(&mut self.comp_buf);
        self.sim.advance_to_into(t + 1e-12, &mut comps);
        let mut finished = 0usize;
        for &c in &comps {
            let it = self.inflight.take().expect("completion without inflight");
            debug_assert_eq!(c.tag, self.tag);
            let now = c.time;
            let dur = now - it.start;
            if self.tracer.enabled() {
                let tokens: usize = it.decode_ids.len()
                    + it.prefill_parts.iter().map(|&(_, t)| t).sum::<usize>();
                self.tracer.emit(
                    now,
                    EventKind::BatchEnd {
                        phase: TracePhase::of(it.decode_ids.len(), it.prefill_parts.len()),
                        seqs: it.decode_ids.len() + it.prefill_parts.len(),
                        tokens,
                        dur,
                    },
                );
            }
            for &id in &it.decode_ids {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.note_token(now, dur);
                self.mlfq.charge(id, 1);
                if st.decode_done() {
                    let st = self.states[id].take().unwrap();
                    self.kv.release(id);
                    self.mlfq.remove(id);
                    self.metrics.push(st.into_record(now));
                    self.done += 1;
                    finished += 1;
                    self.tracer.emit(now, EventKind::Complete { req: id });
                }
            }
            for &(id, take) in &it.prefill_parts {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.queue_time += (it.start - st.queue_since).max(0.0);
                st.queue_since = now;
                st.prefilled += take;
                self.mlfq.charge(id, take);
                let prefill_done = st.prefill_done();
                self.tracer.emit(
                    now,
                    EventKind::PrefillChunk { req: id, take, done: prefill_done, dur },
                );
                if prefill_done && st.generated == 0 {
                    st.note_first_token(now);
                    self.tracer.emit(now, EventKind::FirstToken { req: id });
                    if st.decode_done() {
                        let st = self.states[id].take().unwrap();
                        self.kv.release(id);
                        self.mlfq.remove(id);
                        self.metrics.push(st.into_record(now));
                        self.done += 1;
                        finished += 1;
                        self.tracer.emit(now, EventKind::Complete { req: id });
                    }
                }
            }
            // Recycle the manifest's vectors for future iterations.
            self.spare_ids.push(it.decode_ids);
            self.spare_parts.push(it.prefill_parts);
        }
        self.comp_buf = comps;
        if self.inflight.is_none() {
            self.inflight = self.schedule();
        }
        StepOutcome { completed: finished, busy: self.inflight.is_some() }
    }

    fn pending(&self) -> usize {
        self.injected - self.done
    }

    fn completed(&self) -> usize {
        self.done
    }

    fn kv_usage(&self) -> f64 {
        self.kv.usage()
    }

    fn records(&self) -> &[crate::metrics::RequestRecord] {
        &self.metrics.records
    }

    fn take_metrics(&mut self) -> RunMetrics {
        std::mem::take(&mut self.metrics)
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn snapshot(&self) -> EngineSnapshot {
        let waiting = self.states.iter().flatten().filter(|st| !st.prefill_done()).count();
        let total = self.states.iter().flatten().count();
        EngineSnapshot {
            waiting,
            running: total - waiting,
            kv_usage: self.kv.usage(),
            sm_prefill: 1.0,
            inflight: usize::from(self.inflight.is_some()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::monolithic::MonolithicEngine;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = FastServeEngine::new(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 40);
    }

    #[test]
    fn short_prompts_jump_the_queue() {
        // Skip-join MLFQ should beat plain FCFS mixing on mean TTFT when
        // prompt lengths are highly skewed (its design goal)...
        let cfg = cfg();
        let trace = generate(Dataset::Mixed, 50, 2.0, 23);
        let fs = FastServeEngine::new(&cfg).run(&trace).summary();
        let v = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        assert!(
            fs.mean_ttft < v.mean_ttft * 1.6,
            "fastserve mean TTFT {} should be competitive with vllm {}",
            fs.mean_ttft,
            v.mean_ttft
        );
        // ...at the cost of P95 (long prompts deprioritized).
        assert!(fs.p95_ttft > 0.0);
    }

    #[test]
    fn swaps_trigger_under_pressure() {
        // Mixed workload: short prompts (high MLFQ priority) must displace
        // long-decoding deep-level residents when the cache is tight.
        let mut cfg = cfg();
        cfg.kv_blocks_override = Some(3000);
        let trace = generate(Dataset::Mixed, 60, 5.0, 31);
        let m = FastServeEngine::new(&cfg).run(&trace);
        assert!(
            m.swaps + m.recomputes > 0,
            "tiny cache must force swap/recompute (swaps {}, recomputes {})",
            m.swaps,
            m.recomputes
        );
        // The run must still make progress.
        assert!(m.summary().completed + m.timeouts == 60);
    }
}
