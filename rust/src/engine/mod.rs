//! Serving engines: Nexus plus the four baselines of the paper's §6.1.
//!
//! | kind | paper baseline | mechanism |
//! |---|---|---|
//! | [`EngineKind::Vllm`] | vLLM v1-0.8.1 | monolithic chunked prefill, FCFS continuous batching |
//! | [`EngineKind::Sglang`] | SGLang v0.4.4 | monolithic + RadixAttention prefix-cache model |
//! | [`EngineKind::FastServe`] | FastServe | skip-join MLFQ, CPU swap + recompute |
//! | [`EngineKind::VllmPD`] | vLLM-P/D | engine-level disaggregation, 2 GPUs + transfer buffer |
//! | [`EngineKind::Nexus`] | this paper | intra-GPU disaggregation, Alg. 1 + SPF/FCFS |
//!
//! The `Nexus*` ablation variants reproduce Fig. 13.
//!
//! Every engine implements the incremental [`Engine`] stepping interface:
//! a single run is just [`drive`]-ing one engine over a whole trace, while
//! the [`crate::cluster`] layer interleaves many engine replicas in one
//! virtual-time loop by routing arrivals with [`Engine::inject`] and
//! advancing every replica to the global next event with [`Engine::step`].

pub mod common;
pub mod disagg;
pub mod fastserve;
pub mod monolithic;
pub mod nexus;

pub use nexus::NexusFlags;

use crate::engine::common::ArrivalFeed;
use crate::gpusim::GpuSpec;
use crate::kv::KvCache;
use crate::metrics::{RequestRecord, RunMetrics};
use crate::model::ModelConfig;
use crate::partition::PartitionConfig;
use crate::trace::{EngineSnapshot, EventKind, Sampler, Tracer};
use crate::workload::Request;

/// Engine selection, including the Fig.-13 ablation variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vllm,
    Sglang,
    FastServe,
    /// vLLM-P/D: engine-level disaggregation on two GPUs.
    VllmPD,
    Nexus,
    /// Nexus without dynamic SM changing (static 50/50) — "Nexus-Wo-SC".
    NexusWoSc,
    /// FCFS both phases, no SM changing — "PF-DF-Wo-SC".
    PfDfWoSc,
    /// FCFS both phases, with SM changing — "PF-DF-W-SC".
    PfDfWSc,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Vllm => "vLLM",
            EngineKind::Sglang => "SGLang",
            EngineKind::FastServe => "FastServe",
            EngineKind::VllmPD => "vLLM-P/D",
            EngineKind::Nexus => "Nexus",
            EngineKind::NexusWoSc => "Nexus-Wo-SC",
            EngineKind::PfDfWoSc => "PF-DF-Wo-SC",
            EngineKind::PfDfWSc => "PF-DF-W-SC",
        }
    }

    pub fn by_name(name: &str) -> Option<EngineKind> {
        match name.to_ascii_lowercase().as_str() {
            "vllm" => Some(EngineKind::Vllm),
            "sglang" => Some(EngineKind::Sglang),
            "fastserve" => Some(EngineKind::FastServe),
            "vllm-pd" | "vllmpd" | "pd" | "vllm-p/d" => Some(EngineKind::VllmPD),
            "nexus" => Some(EngineKind::Nexus),
            "nexus-wo-sc" => Some(EngineKind::NexusWoSc),
            "pf-df-wo-sc" => Some(EngineKind::PfDfWoSc),
            "pf-df-w-sc" => Some(EngineKind::PfDfWSc),
            _ => None,
        }
    }

    /// GPUs consumed (vLLM-P/D doubles hardware; TP multiplies it).
    pub fn gpus(&self, model: &ModelConfig) -> usize {
        let base = if *self == EngineKind::VllmPD { 2 } else { 1 };
        base * model.tp
    }

    pub fn all() -> &'static [EngineKind] {
        &[
            EngineKind::Vllm,
            EngineKind::Sglang,
            EngineKind::FastServe,
            EngineKind::VllmPD,
            EngineKind::Nexus,
        ]
    }
}

/// Shared engine configuration; defaults mirror the paper's §5 / §6.1 setup
/// (vLLM defaults for budgets, Nexus's α/β/δ/γ/KV_switch).
#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    /// Max batched tokens per iteration (vLLM `max_num_batched_tokens`).
    pub token_budget: usize,
    /// Chunked-prefill chunk size.
    pub chunk_size: usize,
    /// Max concurrent decode sequences.
    pub max_batch: usize,
    /// HBM fraction reserved for activations/workspace when sizing KV.
    pub activation_frac: f64,
    /// Override the KV block count (tests / pressure experiments).
    pub kv_blocks_override: Option<usize>,
    /// SGLang radix cache (hit probability, mean cached fraction).
    pub radix: (f64, f64),
    /// vLLM-P/D staging buffer as a fraction of HBM.
    pub transfer_buffer_frac: f64,
    /// Nexus partition-controller parameters (α, β, δ, KV_switch).
    pub partition: PartitionConfig,
    /// SPF age-decay γ (paper default 15).
    pub gamma: f64,
    /// Virtual-time ceiling: a run exceeding this marks the unfinished
    /// requests as timeouts (the "X" outcomes in Fig. 11) instead of
    /// simulating a livelocked system forever.
    pub max_virtual_time: f64,
    pub seed: u64,
}

impl EngineCfg {
    pub fn new(model: ModelConfig, seed: u64) -> Self {
        EngineCfg {
            model,
            gpu: GpuSpec::l20(),
            token_budget: 2048,
            chunk_size: 512,
            max_batch: 256,
            activation_frac: 0.10,
            kv_blocks_override: None,
            radix: (0.35, 0.5),
            transfer_buffer_frac: 0.15,
            partition: PartitionConfig::default(),
            gamma: 15.0,
            max_virtual_time: 14_400.0, // 4 virtual hours
            seed,
        }
    }

    /// Size the paged KV cache for this (model, GPU) pair. Under tensor
    /// parallelism the KV pool spans all `tp` GPUs.
    pub fn kv_cache(&self) -> KvCache {
        if let Some(blocks) = self.kv_blocks_override {
            return KvCache::new(blocks, 16, self.model.kv_bytes_per_token());
        }
        let hbm = self.gpu.hbm_bytes * self.model.tp as f64;
        KvCache::for_gpu(
            hbm,
            self.model.weights_bytes(),
            self.model.kv_bytes_per_token(),
            self.activation_frac,
            16,
        )
    }
}

/// Outcome of one [`Engine::step`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StepOutcome {
    /// Requests that finished during this step.
    pub completed: usize,
    /// True when work remains in flight after scheduling (a future
    /// [`Engine::next_event`] exists or is imminent).
    pub busy: bool,
}

/// Incremental stepping interface implemented by every serving engine.
///
/// The contract mirrors the engines' historical run loops, factored so that
/// an external driver owns the arrival feed and the event clock:
///
/// 1. the driver computes the global next event time `t` (earliest arrival
///    vs. every engine's [`Engine::next_event`]);
/// 2. it [`Engine::inject`]s all requests with `arrival ≤ t`;
/// 3. it calls [`Engine::step`]`(t)`, which advances the engine's substrate
///    to `t`, harvests batch completions, and schedules idle resources.
///
/// `t` must never overshoot any engine's pending event — the cluster layer
/// guarantees this by stepping every replica to the fleet-wide minimum.
///
/// `Send` is a supertrait so replicas (each owning a `Box<dyn Engine>`) can
/// be moved into per-shard worker threads by the parallel fleet loop
/// (`Cluster::run_parallel`); every built-in engine is plain owned data.
pub trait Engine: Send {
    /// Which engine this is (for tables and diagnostics).
    fn kind(&self) -> EngineKind;

    /// Current virtual time of the engine's substrate.
    fn now(&self) -> f64;

    /// Earliest pending internal event (batch completion, KV transfer,
    /// retry timer), if any work is in flight.
    fn next_event(&mut self) -> Option<f64>;

    /// Admit one request with an externally computed effective prefill
    /// length. `Some(eff)` pins the request's `effective_prompt` to `eff`
    /// tokens (the cluster prefix tier's local-hit/tier-fetch/miss outcome)
    /// without consuming any engine RNG; `None` leaves the engine to its own
    /// prefix model (e.g. SGLang's probabilistic radix draw).
    fn inject_effective(&mut self, req: Request, eff: Option<usize>);

    /// Admit one request (identified by its globally unique `id`; its
    /// `arrival` must be ≤ the next `step` target).
    fn inject(&mut self, req: Request) {
        self.inject_effective(req, None);
    }

    /// Advance virtual time to `t`: process completions, then schedule.
    fn step(&mut self, t: f64) -> StepOutcome;

    /// Requests admitted but not yet finished.
    fn pending(&self) -> usize;

    /// Requests finished so far.
    fn completed(&self) -> usize;

    /// Live KV-cache usage `KV_u` ∈ [0, 1] (max across devices for
    /// multi-GPU engines) — the router/autoscaler pressure signal.
    fn kv_usage(&self) -> f64;

    /// Completed-request records accumulated so far (appended in completion
    /// order). The cluster layer's WFQ front stage diffs this after each
    /// step to learn *which tenants* finished — a cursor into this slice is
    /// O(new completions) per step and free when multi-tenancy is off.
    fn records(&self) -> &[RequestRecord];

    /// Finalize run-level aggregates (partition trajectory means, makespan
    /// fixups) and hand the metrics over, leaving the engine drained.
    fn take_metrics(&mut self) -> RunMetrics;

    /// Attach a tracer for lifecycle-event emission. The default keeps the
    /// engine silent; all five built-in engines override it. Detaching is
    /// passing `Tracer::default()`.
    fn set_tracer(&mut self, _tracer: Tracer) {}

    /// Point-in-time state for the periodic telemetry sampler. The default
    /// reports only KV usage; engines with queues override.
    fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot { kv_usage: self.kv_usage(), sm_prefill: 1.0, ..Default::default() }
    }
}

/// Drive one engine over a whole time-sorted trace — the single-replica
/// serving loop, expressed against the stepping interface. Unfinished
/// requests (virtual-time ceiling exceeded, or unschedulable with no
/// arrivals left) are reported as timeouts.
pub fn drive(eng: &mut dyn Engine, trace: &[Request], max_virtual_time: f64) -> RunMetrics {
    drive_traced(eng, trace, max_virtual_time, &Tracer::default())
}

/// [`drive`] with a tracer: the engine gets the sink attached (as replica 0)
/// for lifecycle events, the loop emits `Arrival`s, and — when sampling is
/// enabled — periodic [`EngineSnapshot`] samples on the tracer's grid. With
/// a disabled tracer this is byte-identical to the untraced loop (pinned by
/// `tests/golden_trace.rs`).
pub fn drive_traced(
    eng: &mut dyn Engine,
    trace: &[Request],
    max_virtual_time: f64,
    tracer: &Tracer,
) -> RunMetrics {
    eng.set_tracer(tracer.for_replica(0));
    let mut sampler = Sampler::new(tracer);
    let mut feed = ArrivalFeed::new(trace);
    loop {
        if feed.exhausted() && eng.pending() == 0 {
            break;
        }
        let t = match (feed.peek_time(), eng.next_event()) {
            (Some(a), Some(e)) => a.min(e),
            (Some(a), None) => a,
            (None, Some(e)) => e,
            (None, None) => eng.now(),
        };
        if t > max_virtual_time {
            break;
        }
        if let Some(s) = sampler.as_mut() {
            s.due(t, |ts| {
                let snap = eng.snapshot();
                tracer.emit_for(
                    0,
                    ts,
                    EventKind::Sample {
                        kv_usage: snap.kv_usage,
                        waiting: snap.waiting,
                        running: snap.running,
                        pending: eng.pending(),
                        sm_prefill: snap.sm_prefill,
                        inflight: snap.inflight,
                    },
                );
            });
        }
        for r in feed.pop_until(t) {
            tracer.emit(r.arrival, EventKind::Arrival { req: r.id });
            eng.inject(*r);
        }
        let out = eng.step(t);
        if !out.busy && feed.exhausted() && eng.pending() > 0 {
            // Nothing schedulable and nothing will arrive: requests whose
            // KV can never fit (or a recompute livelock). Stop here.
            break;
        }
    }
    eng.set_tracer(Tracer::default());
    let mut m = eng.take_metrics();
    m.timeouts = trace.len() - m.records.len();
    m
}

/// Instantiate a fresh engine of the given kind.
pub fn build_engine(kind: EngineKind, cfg: &EngineCfg) -> Box<dyn Engine> {
    match kind {
        EngineKind::Vllm => Box::new(monolithic::MonolithicEngine::vllm(cfg)),
        EngineKind::Sglang => Box::new(monolithic::MonolithicEngine::sglang(cfg)),
        EngineKind::FastServe => Box::new(fastserve::FastServeEngine::new(cfg)),
        EngineKind::VllmPD => Box::new(disagg::DisaggEngine::new(cfg)),
        EngineKind::Nexus => Box::new(nexus::NexusEngine::new(
            cfg,
            NexusFlags { use_spf: true, dynamic_sm: true },
        )),
        EngineKind::NexusWoSc => Box::new(nexus::NexusEngine::new(
            cfg,
            NexusFlags { use_spf: true, dynamic_sm: false },
        )),
        EngineKind::PfDfWoSc => Box::new(nexus::NexusEngine::new(
            cfg,
            NexusFlags { use_spf: false, dynamic_sm: false },
        )),
        EngineKind::PfDfWSc => Box::new(nexus::NexusEngine::new(
            cfg,
            NexusFlags { use_spf: false, dynamic_sm: true },
        )),
    }
}

/// Run one engine over a trace.
pub fn run_engine(kind: EngineKind, cfg: &EngineCfg, trace: &[Request]) -> RunMetrics {
    let mut eng = build_engine(kind, cfg);
    drive(eng.as_mut(), trace, cfg.max_virtual_time)
}

/// [`run_engine`] with a trace handle attached; drain events afterwards
/// with [`Tracer::take`].
pub fn run_engine_traced(
    kind: EngineKind,
    cfg: &EngineCfg,
    trace: &[Request],
    tracer: &Tracer,
) -> RunMetrics {
    let mut eng = build_engine(kind, cfg);
    drive_traced(eng.as_mut(), trace, cfg.max_virtual_time, tracer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, Dataset};

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            EngineKind::Vllm,
            EngineKind::Sglang,
            EngineKind::FastServe,
            EngineKind::VllmPD,
            EngineKind::Nexus,
            EngineKind::NexusWoSc,
            EngineKind::PfDfWoSc,
            EngineKind::PfDfWSc,
        ] {
            assert_eq!(EngineKind::by_name(k.name()), Some(k));
        }
        assert!(EngineKind::by_name("orca").is_none());
    }

    #[test]
    fn gpu_accounting() {
        let m = ModelConfig::qwen14b().with_tp(2);
        assert_eq!(EngineKind::Nexus.gpus(&m), 2);
        assert_eq!(EngineKind::VllmPD.gpus(&ModelConfig::qwen3b()), 2);
        assert_eq!(EngineKind::Vllm.gpus(&ModelConfig::qwen3b()), 1);
    }

    #[test]
    fn kv_cache_sizing_sane() {
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 1);
        let kv = cfg.kv_cache();
        // L20: 48 GB − weights (~6 GB) − 10% activations → millions of tokens.
        let tokens = kv.total_blocks * kv.block_tokens;
        assert!(tokens > 500_000, "kv tokens {tokens}");
        let cfg_tp = EngineCfg::new(ModelConfig::qwen14b().with_tp(2), 1);
        assert!(cfg_tp.kv_cache().total_blocks > kv.total_blocks / 4);
    }

    #[test]
    fn every_engine_kind_completes_a_small_trace() {
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 42);
        let trace = generate(Dataset::ShareGpt, 15, 3.0, 3);
        for &k in EngineKind::all() {
            let m = run_engine(k, &cfg, &trace);
            assert_eq!(m.summary().completed, 15, "{} dropped requests", k.name());
        }
    }

    #[test]
    fn stepping_api_reports_progress() {
        // Drive an engine by hand through the trait and check the
        // bookkeeping surface the cluster layer relies on.
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 7);
        let trace = generate(Dataset::ShareGpt, 8, 4.0, 11);
        let mut eng = build_engine(EngineKind::Vllm, &cfg);
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.completed(), 0);
        assert!(eng.next_event().is_none());
        let mut t = 0.0;
        for r in &trace {
            eng.inject(*r);
            t = r.arrival;
        }
        assert_eq!(eng.pending(), 8);
        let out = eng.step(t);
        assert!(out.busy, "injected work must schedule");
        // Advance until drained.
        let mut guard = 0;
        while eng.pending() > 0 {
            let next = eng.next_event().expect("busy engine must expose an event");
            assert!(next >= t - 1e-9, "events must be monotone");
            t = next;
            eng.step(t);
            guard += 1;
            assert!(guard < 100_000, "engine failed to drain");
        }
        assert_eq!(eng.completed(), 8);
        let m = eng.take_metrics();
        assert_eq!(m.records.len(), 8);
        assert!((0.0..=1.0).contains(&eng.kv_usage()));
    }

    #[test]
    fn drive_is_deterministic_per_seed() {
        // Two drives of a fresh engine over the same trace are identical —
        // no wall-clock or iteration-order leakage into virtual time. (The
        // stronger behavior-preservation check — 1-replica cluster ==
        // run_engine — lives in cluster::tests and tests/prop_cluster.rs,
        // since run_engine is itself built on drive.)
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 5);
        let trace = generate(Dataset::Mixed, 20, 3.0, 9);
        let a = run_engine(EngineKind::Nexus, &cfg, &trace);
        let mut eng = build_engine(EngineKind::Nexus, &cfg);
        let b = drive(eng.as_mut(), &trace, cfg.max_virtual_time);
        let (sa, sb) = (a.summary(), b.summary());
        assert_eq!(sa.completed, sb.completed);
        assert!((sa.mean_ttft - sb.mean_ttft).abs() < 1e-12);
        assert!((sa.mean_tbt - sb.mean_tbt).abs() < 1e-12);
        assert_eq!(a.repartitions, b.repartitions);
    }
}
