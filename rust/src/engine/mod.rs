//! Serving engines: Nexus plus the four baselines of the paper's §6.1.
//!
//! | kind | paper baseline | mechanism |
//! |---|---|---|
//! | [`EngineKind::Vllm`] | vLLM v1-0.8.1 | monolithic chunked prefill, FCFS continuous batching |
//! | [`EngineKind::Sglang`] | SGLang v0.4.4 | monolithic + RadixAttention prefix-cache model |
//! | [`EngineKind::FastServe`] | FastServe | skip-join MLFQ, CPU swap + recompute |
//! | [`EngineKind::VllmPD`] | vLLM-P/D | engine-level disaggregation, 2 GPUs + transfer buffer |
//! | [`EngineKind::Nexus`] | this paper | intra-GPU disaggregation, Alg. 1 + SPF/FCFS |
//!
//! The `Nexus*` ablation variants reproduce Fig. 13.

pub mod common;
pub mod disagg;
pub mod fastserve;
pub mod monolithic;
pub mod nexus;

pub use nexus::NexusFlags;

use crate::gpusim::GpuSpec;
use crate::kv::KvCache;
use crate::metrics::RunMetrics;
use crate::model::ModelConfig;
use crate::partition::PartitionConfig;
use crate::workload::Request;

/// Engine selection, including the Fig.-13 ablation variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vllm,
    Sglang,
    FastServe,
    /// vLLM-P/D: engine-level disaggregation on two GPUs.
    VllmPD,
    Nexus,
    /// Nexus without dynamic SM changing (static 50/50) — "Nexus-Wo-SC".
    NexusWoSc,
    /// FCFS both phases, no SM changing — "PF-DF-Wo-SC".
    PfDfWoSc,
    /// FCFS both phases, with SM changing — "PF-DF-W-SC".
    PfDfWSc,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Vllm => "vLLM",
            EngineKind::Sglang => "SGLang",
            EngineKind::FastServe => "FastServe",
            EngineKind::VllmPD => "vLLM-P/D",
            EngineKind::Nexus => "Nexus",
            EngineKind::NexusWoSc => "Nexus-Wo-SC",
            EngineKind::PfDfWoSc => "PF-DF-Wo-SC",
            EngineKind::PfDfWSc => "PF-DF-W-SC",
        }
    }

    pub fn by_name(name: &str) -> Option<EngineKind> {
        match name.to_ascii_lowercase().as_str() {
            "vllm" => Some(EngineKind::Vllm),
            "sglang" => Some(EngineKind::Sglang),
            "fastserve" => Some(EngineKind::FastServe),
            "vllm-pd" | "vllmpd" | "pd" | "vllm-p/d" => Some(EngineKind::VllmPD),
            "nexus" => Some(EngineKind::Nexus),
            "nexus-wo-sc" => Some(EngineKind::NexusWoSc),
            "pf-df-wo-sc" => Some(EngineKind::PfDfWoSc),
            "pf-df-w-sc" => Some(EngineKind::PfDfWSc),
            _ => None,
        }
    }

    /// GPUs consumed (vLLM-P/D doubles hardware; TP multiplies it).
    pub fn gpus(&self, model: &ModelConfig) -> usize {
        let base = if *self == EngineKind::VllmPD { 2 } else { 1 };
        base * model.tp
    }

    pub fn all() -> &'static [EngineKind] {
        &[
            EngineKind::Vllm,
            EngineKind::Sglang,
            EngineKind::FastServe,
            EngineKind::VllmPD,
            EngineKind::Nexus,
        ]
    }
}

/// Shared engine configuration; defaults mirror the paper's §5 / §6.1 setup
/// (vLLM defaults for budgets, Nexus's α/β/δ/γ/KV_switch).
#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    /// Max batched tokens per iteration (vLLM `max_num_batched_tokens`).
    pub token_budget: usize,
    /// Chunked-prefill chunk size.
    pub chunk_size: usize,
    /// Max concurrent decode sequences.
    pub max_batch: usize,
    /// HBM fraction reserved for activations/workspace when sizing KV.
    pub activation_frac: f64,
    /// Override the KV block count (tests / pressure experiments).
    pub kv_blocks_override: Option<usize>,
    /// SGLang radix cache (hit probability, mean cached fraction).
    pub radix: (f64, f64),
    /// vLLM-P/D staging buffer as a fraction of HBM.
    pub transfer_buffer_frac: f64,
    /// Nexus partition-controller parameters (α, β, δ, KV_switch).
    pub partition: PartitionConfig,
    /// SPF age-decay γ (paper default 15).
    pub gamma: f64,
    /// Virtual-time ceiling: a run exceeding this marks the unfinished
    /// requests as timeouts (the "X" outcomes in Fig. 11) instead of
    /// simulating a livelocked system forever.
    pub max_virtual_time: f64,
    pub seed: u64,
}

impl EngineCfg {
    pub fn new(model: ModelConfig, seed: u64) -> Self {
        EngineCfg {
            model,
            gpu: GpuSpec::l20(),
            token_budget: 2048,
            chunk_size: 512,
            max_batch: 256,
            activation_frac: 0.10,
            kv_blocks_override: None,
            radix: (0.35, 0.5),
            transfer_buffer_frac: 0.15,
            partition: PartitionConfig::default(),
            gamma: 15.0,
            max_virtual_time: 14_400.0, // 4 virtual hours
            seed,
        }
    }

    /// Size the paged KV cache for this (model, GPU) pair. Under tensor
    /// parallelism the KV pool spans all `tp` GPUs.
    pub fn kv_cache(&self) -> KvCache {
        if let Some(blocks) = self.kv_blocks_override {
            return KvCache::new(blocks, 16, self.model.kv_bytes_per_token());
        }
        let hbm = self.gpu.hbm_bytes * self.model.tp as f64;
        KvCache::for_gpu(
            hbm,
            self.model.weights_bytes(),
            self.model.kv_bytes_per_token(),
            self.activation_frac,
            16,
        )
    }
}

/// Run one engine over a trace.
pub fn run_engine(kind: EngineKind, cfg: &EngineCfg, trace: &[Request]) -> RunMetrics {
    match kind {
        EngineKind::Vllm => monolithic::MonolithicEngine::vllm(cfg).run(trace),
        EngineKind::Sglang => monolithic::MonolithicEngine::sglang(cfg).run(trace),
        EngineKind::FastServe => fastserve::FastServeEngine::new(cfg).run(trace),
        EngineKind::VllmPD => disagg::DisaggEngine::new(cfg).run(trace),
        EngineKind::Nexus => {
            nexus::NexusEngine::new(cfg, NexusFlags { use_spf: true, dynamic_sm: true })
                .run(trace)
        }
        EngineKind::NexusWoSc => {
            nexus::NexusEngine::new(cfg, NexusFlags { use_spf: true, dynamic_sm: false })
                .run(trace)
        }
        EngineKind::PfDfWoSc => {
            nexus::NexusEngine::new(cfg, NexusFlags { use_spf: false, dynamic_sm: false })
                .run(trace)
        }
        EngineKind::PfDfWSc => {
            nexus::NexusEngine::new(cfg, NexusFlags { use_spf: false, dynamic_sm: true })
                .run(trace)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, Dataset};

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            EngineKind::Vllm,
            EngineKind::Sglang,
            EngineKind::FastServe,
            EngineKind::VllmPD,
            EngineKind::Nexus,
            EngineKind::NexusWoSc,
            EngineKind::PfDfWoSc,
            EngineKind::PfDfWSc,
        ] {
            assert_eq!(EngineKind::by_name(k.name()), Some(k));
        }
        assert!(EngineKind::by_name("orca").is_none());
    }

    #[test]
    fn gpu_accounting() {
        let m = ModelConfig::qwen14b().with_tp(2);
        assert_eq!(EngineKind::Nexus.gpus(&m), 2);
        assert_eq!(EngineKind::VllmPD.gpus(&ModelConfig::qwen3b()), 2);
        assert_eq!(EngineKind::Vllm.gpus(&ModelConfig::qwen3b()), 1);
    }

    #[test]
    fn kv_cache_sizing_sane() {
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 1);
        let kv = cfg.kv_cache();
        // L20: 48 GB − weights (~6 GB) − 10% activations → millions of tokens.
        let tokens = kv.total_blocks * kv.block_tokens;
        assert!(tokens > 500_000, "kv tokens {tokens}");
        let cfg_tp = EngineCfg::new(ModelConfig::qwen14b().with_tp(2), 1);
        assert!(cfg_tp.kv_cache().total_blocks > kv.total_blocks / 4);
    }

    #[test]
    fn every_engine_kind_completes_a_small_trace() {
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 42);
        let trace = generate(Dataset::ShareGpt, 15, 3.0, 3);
        for &k in EngineKind::all() {
            let m = run_engine(k, &cfg, &trace);
            assert_eq!(m.summary().completed, 15, "{} dropped requests", k.name());
        }
    }
}
