//! Engine-level P/D disaggregation baseline (vLLM-P/D via LMCache-style
//! KV hand-off): one prefill GPU + one decode GPU, a finite staging buffer
//! between them, and a PCIe-class transfer link.
//!
//! Reproduces the §6.2.2 failure mode: an aggressive prefill side can
//! overrun the transfer buffer, forcing evictions whose KV must be
//! recomputed — under bursty load the system livelocks on recompute.
//!
//! Hot-path layout (§Perf): `waiting` / `running` are insertion-ordered
//! indexed sets with O(1) membership updates; in-flight transfers are
//! compacted in place instead of rebuilt; batch assembly reuses
//! engine-owned buffers throughout.

use super::common::{chunk_attn_pairs, ReqState};
use super::{Engine, EngineCfg, EngineKind, StepOutcome};
use crate::gpusim::{Completion, Sim};
use crate::kv::{KvCache, TransferBuffer};
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::sched::{fcfs_batch_into, PrefillItem, SchedScratch};
use crate::trace::{EngineSnapshot, EventKind, PreemptKind, TracePhase, Tracer};
use crate::util::OrderedIdSet;
use crate::workload::Request;
use std::time::Instant;

struct PrefillIter {
    parts: Vec<(usize, usize)>,
    start: f64,
}

struct DecodeIter {
    ids: Vec<usize>,
    start: f64,
}

/// A finished prefill whose KV is streaming to the decode GPU.
#[derive(Debug, Clone, Copy)]
struct InTransfer {
    id: usize,
    ready_at: f64,
    #[allow(dead_code)]
    bytes: f64,
}

pub struct DisaggEngine {
    cfg: EngineCfg,
    // Two physical GPUs: independent simulators (no shared bandwidth).
    psim: Sim,
    dsim: Sim,
    pkv: KvCache,
    dkv: KvCache,
    buffer: TransferBuffer,
    metrics: RunMetrics,
    states: Vec<Option<ReqState>>,
    waiting: OrderedIdSet, // prefill queue
    transfers: Vec<InTransfer>,
    running: OrderedIdSet, // decoding on GPU 1
    p_inflight: Option<PrefillIter>,
    d_inflight: Option<DecodeIter>,
    /// Requests evicted from the buffer retry prefill after a backoff.
    retry_at: Vec<(usize, f64)>,
    injected: usize,
    done: usize,
    tag: u64,
    // Reusable hot-path buffers (§Perf).
    cand_buf: Vec<usize>,
    queue_buf: Vec<PrefillItem>,
    picked_buf: Vec<usize>,
    ops_buf: Vec<OpWork>,
    p_comp_buf: Vec<Completion>,
    d_comp_buf: Vec<Completion>,
    scratch: SchedScratch,
    /// Recycled iteration vectors (returned on completion, reused on schedule).
    spare_ids: Vec<Vec<usize>>,
    spare_parts: Vec<Vec<(usize, usize)>>,
    tracer: Tracer,
}

impl DisaggEngine {
    pub fn new(cfg: &EngineCfg) -> Self {
        let mut psim = Sim::new(cfg.gpu, 1);
        let mut dsim = Sim::new(cfg.gpu, 1);
        psim.set_partition(0, 1.0);
        dsim.set_partition(0, 1.0);
        let pkv = cfg.kv_cache();
        let dkv = cfg.kv_cache();
        let buffer = TransferBuffer::new(cfg.gpu.hbm_bytes * cfg.transfer_buffer_frac);
        DisaggEngine {
            cfg: cfg.clone(),
            psim,
            dsim,
            pkv,
            dkv,
            buffer,
            metrics: RunMetrics::default(),
            states: Vec::new(),
            waiting: OrderedIdSet::new(),
            transfers: Vec::new(),
            running: OrderedIdSet::new(),
            p_inflight: None,
            d_inflight: None,
            retry_at: Vec::new(),
            injected: 0,
            done: 0,
            tag: 0,
            cand_buf: Vec::new(),
            queue_buf: Vec::new(),
            picked_buf: Vec::new(),
            ops_buf: Vec::new(),
            p_comp_buf: Vec::new(),
            d_comp_buf: Vec::new(),
            scratch: SchedScratch::default(),
            spare_ids: Vec::new(),
            spare_parts: Vec::new(),
            tracer: Tracer::default(),
        }
    }

    /// Run over a whole trace (fresh state each call).
    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let mut eng = Self::new(&self.cfg);
        super::drive(&mut eng, trace, self.cfg.max_virtual_time)
    }

    fn slot(&mut self, id: usize) {
        if id >= self.states.len() {
            self.states.resize_with(id + 1, || None);
        }
    }

    fn schedule_prefill(&mut self) -> Option<PrefillIter> {
        let wall = Instant::now();
        let now = self.psim.now();
        self.queue_buf.clear();
        {
            let queue_buf = &mut self.queue_buf;
            let states = &self.states;
            queue_buf.extend(self.waiting.iter().map(|id| {
                let st = states[id].as_ref().unwrap();
                PrefillItem {
                    id,
                    prompt_len: st.effective_prompt,
                    prefilled: st.prefilled,
                    arrival: st.req.arrival,
                }
            }));
        }
        if self.queue_buf.is_empty() {
            return None;
        }
        let mut picked = std::mem::take(&mut self.picked_buf);
        fcfs_batch_into(
            &self.queue_buf,
            self.cfg.token_budget,
            true,
            &mut self.scratch,
            &mut picked,
        );
        let mut parts = self.spare_parts.pop().unwrap_or_default();
        parts.clear();
        let mut left = self.cfg.token_budget;
        for &qidx in &picked {
            let item = self.queue_buf[qidx];
            let take = item.remaining().min(self.cfg.chunk_size).min(left);
            if take == 0 {
                break;
            }
            if self.pkv.try_reserve(item.id, take) {
                parts.push((item.id, take));
                left -= take;
                if self.tracer.enabled() {
                    self.tracer.emit(
                        now,
                        EventKind::KvAlloc {
                            req: item.id,
                            tokens: take,
                            usage: self.pkv.usage(),
                        },
                    );
                }
            }
        }
        self.picked_buf = picked;
        if parts.is_empty() {
            self.spare_parts.push(parts);
            return None;
        }
        let n: usize = parts.iter().map(|&(_, t)| t).sum();
        let mut pairs = 0.0;
        let mut kv_read = 0.0;
        let mut finishing = 0usize;
        for &(id, take) in &parts {
            let st = self.states[id].as_ref().unwrap();
            pairs += chunk_attn_pairs(st.prefilled, take);
            kv_read += (st.prefilled + take) as f64;
            if st.prefilled + take >= st.effective_prompt {
                finishing += 1;
            }
        }
        self.ops_buf.clear();
        self.cfg.model.prefill_ops_into(n, pairs, kv_read, finishing, &mut self.ops_buf);
        self.tag += 1;
        self.psim.submit(0, &self.ops_buf, self.tag);
        if self.tracer.enabled() {
            self.tracer.emit(
                now,
                EventKind::BatchStart { phase: TracePhase::Prefill, seqs: parts.len(), tokens: n },
            );
        }
        let share = wall.elapsed().as_secs_f64() / parts.len() as f64;
        for &(id, _) in &parts {
            self.states[id].as_mut().unwrap().sched_time += share;
        }
        Some(PrefillIter { parts, start: now })
    }

    fn schedule_decode(&mut self) -> Option<DecodeIter> {
        let wall = Instant::now();
        let now = self.dsim.now();
        let mut cand = std::mem::take(&mut self.cand_buf);
        cand.clear();
        cand.extend(self.running.iter().take(self.cfg.max_batch));
        let mut decode_ids = self.spare_ids.pop().unwrap_or_default();
        decode_ids.clear();
        for &id in &cand {
            loop {
                if self.dkv.try_reserve(id, 1) {
                    decode_ids.push(id);
                    break;
                }
                // Preempt the newest running request that is not `id` (ties
                // break toward the latest-ordered entry, like the historical
                // `Iterator::max_by` over the running vec).
                let mut victim: Option<usize> = None;
                let mut victim_arrival = f64::NEG_INFINITY;
                for v in self.running.iter() {
                    if v == id {
                        continue;
                    }
                    let a = self.states[v].as_ref().unwrap().req.arrival;
                    if a >= victim_arrival {
                        victim_arrival = a;
                        victim = Some(v);
                    }
                }
                match victim {
                    Some(v) => {
                        self.dkv.release(v);
                        self.running.remove(v);
                        decode_ids.retain(|&x| x != v);
                        self.states[v].as_mut().unwrap().restart_for_recompute(now);
                        self.waiting.insert(v);
                        self.metrics.recomputes += 1;
                        self.tracer.emit(
                            now,
                            EventKind::Preempt { req: v, kind: PreemptKind::Recompute },
                        );
                    }
                    None => break,
                }
            }
        }
        self.cand_buf = cand;
        if decode_ids.is_empty() {
            self.spare_ids.push(decode_ids);
            return None;
        }
        let ctx: f64 = decode_ids.iter().map(|&id| self.dkv.tokens(id) as f64).sum();
        self.ops_buf.clear();
        self.cfg.model.decode_ops_into(decode_ids.len(), ctx, &mut self.ops_buf);
        self.tag += 1;
        self.dsim.submit(0, &self.ops_buf, self.tag);
        if self.tracer.enabled() {
            self.tracer.emit(
                now,
                EventKind::BatchStart {
                    phase: TracePhase::Decode,
                    seqs: decode_ids.len(),
                    tokens: decode_ids.len(),
                },
            );
        }
        let share = wall.elapsed().as_secs_f64() / decode_ids.len() as f64;
        for &id in &decode_ids {
            self.states[id].as_mut().unwrap().sched_time += share;
        }
        Some(DecodeIter { ids: decode_ids, start: now })
    }
}

impl Engine for DisaggEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::VllmPD
    }

    fn now(&self) -> f64 {
        self.psim.now().max(self.dsim.now())
    }

    fn next_event(&mut self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if self.p_inflight.is_some() {
            if let Some(s) = self.psim.peek_next_completion() {
                t = t.min(s);
            }
        }
        if self.d_inflight.is_some() {
            if let Some(s) = self.dsim.peek_next_completion() {
                t = t.min(s);
            }
        }
        for tr in &self.transfers {
            t = t.min(tr.ready_at);
        }
        for &(_, at) in &self.retry_at {
            t = t.min(at);
        }
        t.is_finite().then_some(t)
    }

    fn inject_effective(&mut self, req: Request, eff: Option<usize>) {
        self.slot(req.id);
        let mut st = ReqState::new(req);
        if let Some(e) = eff {
            st.effective_prompt = e.max(1);
        }
        self.states[req.id] = Some(st);
        self.waiting.insert(req.id);
        self.injected += 1;
        self.tracer.emit(req.arrival, EventKind::Admit { req: req.id });
    }

    fn step(&mut self, t: f64) -> StepOutcome {
        // Advance both GPUs to the global event time.
        let now = t.max(self.psim.now()).max(self.dsim.now());
        let mut p_done = std::mem::take(&mut self.p_comp_buf);
        self.psim.advance_to_into(now + 1e-12, &mut p_done);
        let mut d_done = std::mem::take(&mut self.d_comp_buf);
        self.dsim.advance_to_into(now + 1e-12, &mut d_done);
        let mut finished = 0usize;

        // Buffer-evicted requests rejoin the prefill queue.
        let waiting = &mut self.waiting;
        self.retry_at.retain(|&(id, at)| {
            if at <= now {
                waiting.insert(id);
                false
            } else {
                true
            }
        });

        // Prefill GPU completions → stage KV into the transfer buffer.
        for &c in &p_done {
            let it = self.p_inflight.take().expect("prefill completion w/o inflight");
            let end = c.time;
            let dur = end - it.start;
            if self.tracer.enabled() {
                let tokens: usize = it.parts.iter().map(|&(_, t)| t).sum();
                self.tracer.emit(
                    end,
                    EventKind::BatchEnd {
                        phase: TracePhase::Prefill,
                        seqs: it.parts.len(),
                        tokens,
                        dur,
                    },
                );
            }
            for &(id, take) in &it.parts {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.queue_time += (it.start - st.queue_since).max(0.0);
                st.queue_since = end;
                st.prefilled += take;
                let prefill_done = st.prefill_done();
                self.tracer.emit(
                    end,
                    EventKind::PrefillChunk { req: id, take, done: prefill_done, dur },
                );
                if prefill_done {
                    self.waiting.remove(id);
                    if st.generated == 0 {
                        st.note_first_token(end);
                        self.tracer.emit(end, EventKind::FirstToken { req: id });
                    }
                    if st.decode_done() {
                        let st = self.states[id].take().unwrap();
                        self.pkv.release(id);
                        self.metrics.push(st.into_record(end));
                        self.done += 1;
                        finished += 1;
                        self.tracer.emit(end, EventKind::Complete { req: id });
                        continue;
                    }
                    let bytes = self.pkv.tokens(id) as f64 * self.pkv.bytes_per_token;
                    self.pkv.release(id);
                    if self.buffer.push(id, bytes) {
                        self.transfers.push(InTransfer {
                            id,
                            ready_at: end + bytes / self.cfg.gpu.link_bw,
                            bytes,
                        });
                        self.tracer.emit(
                            end,
                            EventKind::Transfer {
                                req: id,
                                bytes,
                                dur: bytes / self.cfg.gpu.link_bw,
                            },
                        );
                    } else {
                        // §6.2.2: buffer overrun → evict + recompute.
                        self.metrics.recomputes += 1;
                        let st = self.states[id].as_mut().unwrap();
                        st.restart_for_recompute(end);
                        self.retry_at.push((id, end + 0.25));
                        self.tracer.emit(
                            end,
                            EventKind::Preempt { req: id, kind: PreemptKind::BufferEvict },
                        );
                    }
                }
            }
            self.spare_parts.push(it.parts);
        }
        self.p_comp_buf = p_done;

        // Completed transfers → admit on the decode GPU (in-place
        // compaction; relative order of still-pending transfers preserved).
        let mut keep = 0usize;
        for i in 0..self.transfers.len() {
            let mut tr = self.transfers[i];
            if tr.ready_at <= now {
                let st = self.states[tr.id].as_ref().unwrap();
                let ctx = st.req.plen() + st.generated;
                if self.dkv.try_reserve(tr.id, ctx) {
                    self.buffer.pop(tr.id);
                    self.running.insert(tr.id);
                    if self.tracer.enabled() {
                        self.tracer.emit(
                            now,
                            EventKind::KvAlloc {
                                req: tr.id,
                                tokens: ctx,
                                usage: self.dkv.usage(),
                            },
                        );
                    }
                    continue;
                }
                // Decode side full: KV waits in the buffer.
                tr.ready_at = now + 0.05;
            }
            self.transfers[keep] = tr;
            keep += 1;
        }
        self.transfers.truncate(keep);

        // Decode GPU completions.
        for &c in &d_done {
            let it = self.d_inflight.take().expect("decode completion w/o inflight");
            let end = c.time;
            let dur = end - it.start;
            if self.tracer.enabled() {
                self.tracer.emit(
                    end,
                    EventKind::BatchEnd {
                        phase: TracePhase::Decode,
                        seqs: it.ids.len(),
                        tokens: it.ids.len(),
                        dur,
                    },
                );
            }
            for &id in &it.ids {
                let st = self.states[id].as_mut().unwrap();
                st.exec_time += dur;
                st.note_token(end, dur);
                if st.decode_done() {
                    let st = self.states[id].take().unwrap();
                    self.dkv.release(id);
                    self.running.remove(id);
                    self.metrics.push(st.into_record(end));
                    self.done += 1;
                    finished += 1;
                    self.tracer.emit(end, EventKind::Complete { req: id });
                }
            }
            self.spare_ids.push(it.ids);
        }
        self.d_comp_buf = d_done;

        // Schedule prefill GPU (FCFS chunked, prefill-only batches).
        if self.p_inflight.is_none() {
            self.p_inflight = self.schedule_prefill();
        }
        // Schedule decode GPU (FCFS decode-only batches).
        if self.d_inflight.is_none() {
            self.d_inflight = self.schedule_decode();
        }

        let busy = self.p_inflight.is_some()
            || self.d_inflight.is_some()
            || !self.transfers.is_empty()
            || !self.retry_at.is_empty();
        StepOutcome { completed: finished, busy }
    }

    fn pending(&self) -> usize {
        self.injected - self.done
    }

    fn completed(&self) -> usize {
        self.done
    }

    fn kv_usage(&self) -> f64 {
        self.dkv.usage().max(self.pkv.usage())
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            waiting: self.waiting.len(),
            running: self.running.len(),
            kv_usage: self.dkv.usage().max(self.pkv.usage()),
            sm_prefill: 1.0,
            inflight: usize::from(self.p_inflight.is_some())
                + usize::from(self.d_inflight.is_some()),
        }
    }

    fn records(&self) -> &[crate::metrics::RequestRecord] {
        &self.metrics.records
    }

    fn take_metrics(&mut self) -> RunMetrics {
        self.metrics.makespan = self.metrics.makespan.max(self.psim.now()).max(self.dsim.now());
        std::mem::take(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::monolithic::MonolithicEngine;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = DisaggEngine::new(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 40);
    }

    #[test]
    fn best_tbt_by_full_isolation() {
        // With a whole GPU for decode, vLLM-P/D should post the lowest TBT
        // (the paper's Fig. 9 columns 5–6 finding) vs the monolithic engine.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 40, 2.5, 11);
        let pd = DisaggEngine::new(&cfg).run(&trace).summary();
        let v = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        assert!(
            pd.mean_tbt < v.mean_tbt,
            "P/D TBT {} must beat monolithic {}",
            pd.mean_tbt,
            v.mean_tbt
        );
    }

    #[test]
    fn small_buffer_forces_recomputes() {
        let mut cfg = cfg();
        cfg.transfer_buffer_frac = 2e-4; // ~10 MB: overruns immediately
        let trace = generate(Dataset::LongData, 25, 4.0, 13);
        let m = DisaggEngine::new(&cfg).run(&trace);
        assert!(m.recomputes > 0, "tiny buffer must evict (got {})", m.recomputes);
        assert_eq!(m.summary().completed + m.timeouts, 25);
    }

    #[test]
    fn transfer_delay_shows_in_first_gap() {
        // The first decode token waits for the PCIe KV transfer, so the
        // first inter-token gap must exceed the link transfer time.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 5, 0.5, 17);
        let m = DisaggEngine::new(&cfg).run(&trace);
        for r in &m.records {
            if r.token_gaps.is_empty() {
                continue;
            }
            let kv_bytes = r.prompt_len as f64 * cfg.model.kv_bytes_per_token();
            let link_time = kv_bytes / cfg.gpu.link_bw;
            assert!(
                r.token_gaps[0] >= link_time * 0.9,
                "first gap {} must include transfer {}",
                r.token_gaps[0],
                link_time
            );
        }
    }
}
