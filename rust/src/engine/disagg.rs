//! Engine-level P/D disaggregation baseline (vLLM-P/D via LMCache-style
//! KV hand-off): one prefill GPU + one decode GPU, a finite staging buffer
//! between them, and a PCIe-class transfer link.
//!
//! Reproduces the §6.2.2 failure mode: an aggressive prefill side can
//! overrun the transfer buffer, forcing evictions whose KV must be
//! recomputed — under bursty load the system livelocks on recompute.

use super::common::{chunk_attn_pairs, ArrivalFeed, ReqState};
use super::EngineCfg;
use crate::gpusim::Sim;
use crate::kv::{KvCache, TransferBuffer};
use crate::metrics::RunMetrics;
use crate::model::OpWork;
use crate::sched::{fcfs_batch, PrefillItem};
use crate::workload::Request;
use std::time::Instant;

struct PrefillIter {
    parts: Vec<(usize, usize)>,
    start: f64,
}

struct DecodeIter {
    ids: Vec<usize>,
    start: f64,
}

/// A finished prefill whose KV is streaming to the decode GPU.
#[derive(Debug, Clone, Copy)]
struct InTransfer {
    id: usize,
    ready_at: f64,
    #[allow(dead_code)]
    bytes: f64,
}

pub struct DisaggEngine<'c> {
    cfg: &'c EngineCfg,
}

impl<'c> DisaggEngine<'c> {
    pub fn new(cfg: &'c EngineCfg) -> Self {
        DisaggEngine { cfg }
    }

    pub fn run(&mut self, trace: &[Request]) -> RunMetrics {
        let cfg = self.cfg;
        // Two physical GPUs: independent simulators (no shared bandwidth).
        let mut psim = Sim::new(cfg.gpu, 1);
        let mut dsim = Sim::new(cfg.gpu, 1);
        psim.set_partition(0, 1.0);
        dsim.set_partition(0, 1.0);
        let mut pkv = cfg.kv_cache();
        let mut dkv = cfg.kv_cache();
        let mut buffer = TransferBuffer::new(cfg.gpu.hbm_bytes * cfg.transfer_buffer_frac);
        let mut metrics = RunMetrics::default();

        let mut states: Vec<Option<ReqState>> = vec![None; trace.len()];
        let mut waiting: Vec<usize> = Vec::new(); // prefill queue
        let mut transfers: Vec<InTransfer> = Vec::new();
        let mut running: Vec<usize> = Vec::new(); // decoding on GPU 1
        let mut p_inflight: Option<PrefillIter> = None;
        let mut d_inflight: Option<DecodeIter> = None;
        let mut feed = ArrivalFeed::new(trace);
        let mut done = 0usize;
        let mut tag = 0u64;
        // Requests evicted from the buffer retry prefill after a backoff.
        let mut retry_at: Vec<(usize, f64)> = Vec::new();

        while done < trace.len() {
            let mut t = f64::INFINITY;
            if let Some(a) = feed.peek_time() {
                t = t.min(a);
            }
            if p_inflight.is_some() {
                if let Some(s) = psim.peek_next_completion() {
                    t = t.min(s);
                }
            }
            if d_inflight.is_some() {
                if let Some(s) = dsim.peek_next_completion() {
                    t = t.min(s);
                }
            }
            for tr in &transfers {
                t = t.min(tr.ready_at);
            }
            for &(_, at) in &retry_at {
                t = t.min(at);
            }
            if !t.is_finite() {
                t = psim.now().max(dsim.now());
            }
            if t > cfg.max_virtual_time {
                // Livelocked (e.g. buffer-overrun recompute storm, §6.2.2).
                metrics.timeouts = trace.len() - done;
                break;
            }

            // Advance both GPUs to the global event time.
            let now = t.max(psim.now()).max(dsim.now());
            let p_done = psim.advance_to(now + 1e-12);
            let d_done = dsim.advance_to(now + 1e-12);

            for r in feed.pop_until(now) {
                states[r.id] = Some(ReqState::new(*r));
                waiting.push(r.id);
            }
            // Buffer-evicted requests rejoin the prefill queue.
            retry_at.retain(|&(id, at)| {
                if at <= now {
                    waiting.push(id);
                    false
                } else {
                    true
                }
            });

            // Prefill GPU completions → stage KV into the transfer buffer.
            for c in p_done {
                let it = p_inflight.take().expect("prefill completion w/o inflight");
                let end = c.time;
                let dur = end - it.start;
                for (id, take) in it.parts {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.queue_time += (it.start - st.queue_since).max(0.0);
                    st.queue_since = end;
                    st.prefilled += take;
                    if st.prefill_done() {
                        waiting.retain(|&x| x != id);
                        if st.generated == 0 {
                            st.note_first_token(end);
                        }
                        if st.decode_done() {
                            let st = states[id].take().unwrap();
                            pkv.release(id);
                            metrics.push(st.into_record(end));
                            done += 1;
                            continue;
                        }
                        let bytes = pkv.tokens(id) as f64 * pkv.bytes_per_token;
                        pkv.release(id);
                        if buffer.push(id, bytes) {
                            transfers.push(InTransfer {
                                id,
                                ready_at: end + bytes / cfg.gpu.link_bw,
                                bytes,
                            });
                        } else {
                            // §6.2.2: buffer overrun → evict + recompute.
                            metrics.recomputes += 1;
                            let st = states[id].as_mut().unwrap();
                            st.restart_for_recompute(end);
                            retry_at.push((id, end + 0.25));
                        }
                    }
                }
            }

            // Completed transfers → admit on the decode GPU.
            let mut still: Vec<InTransfer> = Vec::new();
            for tr in transfers.drain(..) {
                if tr.ready_at <= now {
                    let st = states[tr.id].as_ref().unwrap();
                    let ctx = st.req.prompt_len + st.generated;
                    if dkv.try_reserve(tr.id, ctx) {
                        buffer.pop(tr.id);
                        running.push(tr.id);
                    } else {
                        // Decode side full: KV waits in the buffer.
                        let mut tr = tr;
                        tr.ready_at = now + 0.05;
                        still.push(tr);
                    }
                } else {
                    still.push(tr);
                }
            }
            transfers = still;

            // Decode GPU completions.
            for c in d_done {
                let it = d_inflight.take().expect("decode completion w/o inflight");
                let end = c.time;
                let dur = end - it.start;
                for id in it.ids {
                    let st = states[id].as_mut().unwrap();
                    st.exec_time += dur;
                    st.note_token(end, dur);
                    if st.decode_done() {
                        let st = states[id].take().unwrap();
                        dkv.release(id);
                        running.retain(|&x| x != id);
                        metrics.push(st.into_record(end));
                        done += 1;
                    }
                }
            }

            // Schedule prefill GPU (FCFS chunked, prefill-only batches).
            if p_inflight.is_none() {
                p_inflight = self.schedule_prefill(
                    &mut psim, &mut pkv, &mut states, &waiting, &mut tag,
                );
            }
            // Schedule decode GPU (FCFS decode-only batches).
            if d_inflight.is_none() {
                d_inflight = self.schedule_decode(
                    &mut dsim, &mut dkv, &mut states, &mut running, &mut waiting, &mut metrics,
                    &mut tag,
                );
            }

            if p_inflight.is_none()
                && d_inflight.is_none()
                && transfers.is_empty()
                && retry_at.is_empty()
                && feed.exhausted()
                && done < trace.len()
            {
                metrics.timeouts = trace.len() - done;
                break;
            }
        }
        metrics.makespan = metrics.makespan.max(psim.now()).max(dsim.now());
        metrics
    }

    fn schedule_prefill(
        &self,
        sim: &mut Sim,
        kv: &mut KvCache,
        states: &mut [Option<ReqState>],
        waiting: &[usize],
        tag: &mut u64,
    ) -> Option<PrefillIter> {
        let wall = Instant::now();
        let cfg = self.cfg;
        let now = sim.now();
        let queue: Vec<PrefillItem> = waiting
            .iter()
            .map(|&id| {
                let st = states[id].as_ref().unwrap();
                PrefillItem {
                    id,
                    prompt_len: st.effective_prompt,
                    prefilled: st.prefilled,
                    arrival: st.req.arrival,
                }
            })
            .collect();
        if queue.is_empty() {
            return None;
        }
        let picked = fcfs_batch(&queue, cfg.token_budget, true);
        let mut parts: Vec<(usize, usize)> = Vec::new();
        let mut left = cfg.token_budget;
        for qidx in picked {
            let item = &queue[qidx];
            let take = item.remaining().min(cfg.chunk_size).min(left);
            if take == 0 {
                break;
            }
            if kv.try_reserve(item.id, take) {
                parts.push((item.id, take));
                left -= take;
            }
        }
        if parts.is_empty() {
            return None;
        }
        let n: usize = parts.iter().map(|&(_, t)| t).sum();
        let mut pairs = 0.0;
        let mut kv_read = 0.0;
        let mut finishing = 0usize;
        for &(id, take) in &parts {
            let st = states[id].as_ref().unwrap();
            pairs += chunk_attn_pairs(st.prefilled, take);
            kv_read += (st.prefilled + take) as f64;
            if st.prefilled + take >= st.effective_prompt {
                finishing += 1;
            }
        }
        let ops: Vec<OpWork> = cfg.model.prefill_ops(n, pairs, kv_read, finishing);
        *tag += 1;
        sim.submit(0, &ops, *tag);
        let share = wall.elapsed().as_secs_f64() / parts.len() as f64;
        for &(id, _) in &parts {
            states[id].as_mut().unwrap().sched_time += share;
        }
        Some(PrefillIter { parts, start: now })
    }

    #[allow(clippy::too_many_arguments)]
    fn schedule_decode(
        &self,
        sim: &mut Sim,
        kv: &mut KvCache,
        states: &mut [Option<ReqState>],
        running: &mut Vec<usize>,
        waiting: &mut Vec<usize>,
        metrics: &mut RunMetrics,
        tag: &mut u64,
    ) -> Option<DecodeIter> {
        let wall = Instant::now();
        let cfg = self.cfg;
        let now = sim.now();
        let mut ids: Vec<usize> = running.clone();
        ids.truncate(cfg.max_batch);
        let mut decode_ids = Vec::with_capacity(ids.len());
        for id in ids {
            loop {
                if kv.try_reserve(id, 1) {
                    decode_ids.push(id);
                    break;
                }
                let victim = running
                    .iter()
                    .copied()
                    .filter(|&v| v != id)
                    .max_by(|&a, &b| {
                        let aa = states[a].as_ref().unwrap().req.arrival;
                        let bb = states[b].as_ref().unwrap().req.arrival;
                        aa.partial_cmp(&bb).unwrap()
                    });
                match victim {
                    Some(v) => {
                        kv.release(v);
                        running.retain(|&x| x != v);
                        decode_ids.retain(|&x| x != v);
                        states[v].as_mut().unwrap().restart_for_recompute(now);
                        waiting.push(v);
                        metrics.recomputes += 1;
                    }
                    None => break,
                }
            }
        }
        if decode_ids.is_empty() {
            return None;
        }
        let ctx: f64 = decode_ids.iter().map(|&id| kv.tokens(id) as f64).sum();
        let ops = cfg.model.decode_ops(decode_ids.len(), ctx);
        *tag += 1;
        sim.submit(0, &ops, *tag);
        let share = wall.elapsed().as_secs_f64() / decode_ids.len() as f64;
        for &id in &decode_ids {
            states[id].as_mut().unwrap().sched_time += share;
        }
        Some(DecodeIter { ids: decode_ids, start: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::monolithic::MonolithicEngine;
    use crate::engine::EngineCfg;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn cfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = cfg();
        let trace = generate(Dataset::ShareGpt, 40, 4.0, 7);
        let m = DisaggEngine::new(&cfg).run(&trace);
        assert_eq!(m.summary().completed, 40);
    }

    #[test]
    fn best_tbt_by_full_isolation() {
        // With a whole GPU for decode, vLLM-P/D should post the lowest TBT
        // (the paper's Fig. 9 columns 5–6 finding) vs the monolithic engine.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 40, 2.5, 11);
        let pd = DisaggEngine::new(&cfg).run(&trace).summary();
        let v = MonolithicEngine::vllm(&cfg).run(&trace).summary();
        assert!(
            pd.mean_tbt < v.mean_tbt,
            "P/D TBT {} must beat monolithic {}",
            pd.mean_tbt,
            v.mean_tbt
        );
    }

    #[test]
    fn small_buffer_forces_recomputes() {
        let mut cfg = cfg();
        cfg.transfer_buffer_frac = 2e-4; // ~10 MB: overruns immediately
        let trace = generate(Dataset::LongData, 25, 4.0, 13);
        let m = DisaggEngine::new(&cfg).run(&trace);
        assert!(m.recomputes > 0, "tiny buffer must evict (got {})", m.recomputes);
        assert_eq!(m.summary().completed + m.timeouts, 25);
    }

    #[test]
    fn transfer_delay_shows_in_first_gap() {
        // The first decode token waits for the PCIe KV transfer, so the
        // first inter-token gap must exceed the link transfer time.
        let cfg = cfg();
        let trace = generate(Dataset::LongData, 5, 0.5, 17);
        let m = DisaggEngine::new(&cfg).run(&trace);
        for r in &m.records {
            if r.token_gaps.is_empty() {
                continue;
            }
            let kv_bytes = r.prompt_len as f64 * cfg.model.kv_bytes_per_token();
            let link_time = kv_bytes / cfg.gpu.link_bw;
            assert!(
                r.token_gaps[0] >= link_time * 0.9,
                "first gap {} must include transfer {}",
                r.token_gaps[0],
                link_time
            );
        }
    }
}
