//! Shared request-lifecycle bookkeeping for all serving engines.

use crate::metrics::RequestRecord;
use crate::workload::Request;

/// Mutable per-request state while a request is in flight.
#[derive(Debug, Clone)]
pub struct ReqState {
    pub req: Request,
    /// Prompt tokens that still need prefill (radix caching / recompute may
    /// change this relative to `req.prompt_len`).
    pub effective_prompt: usize,
    pub prefilled: usize,
    /// Output tokens produced so far (the first comes from prefill).
    pub generated: usize,
    pub first_token: f64,
    pub last_token: f64,
    pub gaps: Vec<f64>,
    /// Time this request (re-)entered a wait queue.
    pub queue_since: f64,
    pub queue_time: f64,
    pub sched_time: f64,
    pub exec_time: f64,
}

impl ReqState {
    pub fn new(req: Request) -> Self {
        ReqState {
            req,
            effective_prompt: req.plen(),
            prefilled: 0,
            generated: 0,
            first_token: f64::NAN,
            last_token: f64::NAN,
            gaps: Vec::new(),
            queue_since: req.arrival,
            queue_time: 0.0,
            sched_time: 0.0,
            exec_time: 0.0,
        }
    }

    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.effective_prompt
    }

    pub fn decode_done(&self) -> bool {
        self.generated >= self.req.olen()
    }

    /// Record the first output token (end of prefill).
    pub fn note_first_token(&mut self, now: f64) {
        debug_assert!(self.first_token.is_nan(), "first token recorded twice");
        self.first_token = now;
        self.last_token = now;
        self.generated = 1;
    }

    /// Record one decode token; `exec` is the iteration duration, used to
    /// split the inter-token gap into execution vs queueing.
    pub fn note_token(&mut self, now: f64, exec: f64) {
        let gap = now - self.last_token;
        self.gaps.push(gap);
        self.queue_time += (gap - exec).max(0.0);
        self.last_token = now;
        self.generated += 1;
    }

    /// Requeue for (re-)prefill after eviction: everything already emitted
    /// must be recomputed into KV before decoding can continue.
    pub fn restart_for_recompute(&mut self, now: f64) {
        self.effective_prompt = self.req.plen() + self.generated;
        self.prefilled = 0;
        self.queue_since = now;
    }

    pub fn into_record(self, finish: f64) -> RequestRecord {
        RequestRecord {
            id: self.req.id,
            tenant: self.req.tenant,
            arrival: self.req.arrival,
            first_token: if self.first_token.is_nan() { finish } else { self.first_token },
            finish,
            prompt_len: self.req.plen(),
            output_len: self.req.olen(),
            token_gaps: self.gaps,
            sched_time: self.sched_time,
            queue_time: self.queue_time,
            exec_time: self.exec_time,
        }
    }
}

/// Cursor over a time-sorted arrival trace.
#[derive(Debug, Clone)]
pub struct ArrivalFeed<'a> {
    trace: &'a [Request],
    next: usize,
}

impl<'a> ArrivalFeed<'a> {
    pub fn new(trace: &'a [Request]) -> Self {
        debug_assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        ArrivalFeed { trace, next: 0 }
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.trace.get(self.next).map(|r| r.arrival)
    }

    /// Pop every request with `arrival ≤ t`.
    pub fn pop_until(&mut self, t: f64) -> &'a [Request] {
        let start = self.next;
        while self.next < self.trace.len() && self.trace[self.next].arrival <= t {
            self.next += 1;
        }
        &self.trace[start..self.next]
    }

    pub fn exhausted(&self) -> bool {
        self.next >= self.trace.len()
    }

    pub fn remaining(&self) -> usize {
        self.trace.len() - self.next
    }
}

/// Causal attention token-pairs for a prefill chunk: `take` new tokens
/// attending to `prior` cached tokens plus themselves (triangular).
pub fn chunk_attn_pairs(prior: usize, take: usize) -> f64 {
    take as f64 * prior as f64 + take as f64 * (take as f64 + 1.0) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival: f64, p: u32, o: u32) -> Request {
        Request { id, arrival, prompt_len: p, output_len: o, tenant: 0, prefix: 0, shared_len: 0 }
    }

    #[test]
    fn lifecycle_ttft_and_gaps() {
        let mut st = ReqState::new(req(0, 1.0, 100, 3));
        st.prefilled = 100;
        assert!(st.prefill_done());
        st.note_first_token(2.0);
        assert_eq!(st.generated, 1);
        st.note_token(2.05, 0.03);
        st.note_token(2.10, 0.05);
        assert!(st.decode_done());
        let r = st.into_record(2.10);
        assert!((r.ttft() - 1.0).abs() < 1e-12);
        assert_eq!(r.token_gaps.len(), 2);
        // First gap 0.05 with 0.03 exec → 0.02 queued.
        assert!((r.queue_time - 0.02).abs() < 1e-12);
    }

    #[test]
    fn recompute_restart_extends_prompt() {
        let mut st = ReqState::new(req(1, 0.0, 50, 10));
        st.prefilled = 50;
        st.note_first_token(1.0);
        st.note_token(1.1, 0.1);
        st.restart_for_recompute(2.0);
        assert_eq!(st.effective_prompt, 52);
        assert_eq!(st.prefilled, 0);
        assert!(!st.prefill_done());
        assert_eq!(st.generated, 2, "emitted tokens are kept");
    }

    #[test]
    fn arrival_feed_pops_in_order() {
        let tr = vec![req(0, 1.0, 1, 1), req(1, 2.0, 1, 1), req(2, 2.0, 1, 1), req(3, 5.0, 1, 1)];
        let mut feed = ArrivalFeed::new(&tr);
        assert_eq!(feed.peek_time(), Some(1.0));
        assert_eq!(feed.pop_until(0.5).len(), 0);
        assert_eq!(feed.pop_until(2.0).len(), 3);
        assert_eq!(feed.peek_time(), Some(5.0));
        assert!(!feed.exhausted());
        assert_eq!(feed.pop_until(10.0).len(), 1);
        assert!(feed.exhausted());
    }

    #[test]
    fn attn_pairs_triangular() {
        // First chunk of 4 tokens, no prior: 1+2+3+4 = 10.
        assert_eq!(chunk_attn_pairs(0, 4), 10.0);
        // 2 tokens after 100 cached: 2·100 + 1+2 = 203.
        assert_eq!(chunk_attn_pairs(100, 2), 203.0);
    }
}
