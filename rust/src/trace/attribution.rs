//! Per-phase latency attribution derived from a trace.
//!
//! Splits each completed request's end-to-end latency into four phases and
//! reports per-request means alongside `RunMetrics`:
//!
//! * **queueing** — arrival → first prefill execution (admission queues,
//!   KV-pressure stalls, router-to-engine hand-off);
//! * **prefill** — GPU time spent executing the request's prefill chunks
//!   (summed batch durations of iterations carrying its chunks);
//! * **decode** — GPU execution time attributed to decode
//!   (`exec_time − prefill`, the engine's own accounting);
//! * **interference** — the remainder of the decode span
//!   (`first_token → finish`) not covered by decode execution: time the
//!   request sat scheduled-out, preempted, or waiting on a shared stream —
//!   the contention Nexus's repartitioning targets.
//!
//! Each component is clamped at 0, so the four means sum to ≈ mean e2e
//! latency (exactly, when no clamp fires).

use std::collections::HashMap;

use super::{EventKind, TraceEvent};
use crate::metrics::RunMetrics;

/// Mean seconds per request spent in each phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseAttribution {
    /// Completed requests the means are taken over.
    pub requests: usize,
    pub queueing: f64,
    pub prefill: f64,
    pub interference: f64,
    pub decode: f64,
}

impl PhaseAttribution {
    /// Sum of the four phase means (≈ mean end-to-end latency).
    pub fn total(&self) -> f64 {
        self.queueing + self.prefill + self.interference + self.decode
    }
}

impl std::fmt::Display for PhaseAttribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "phase attribution over {} requests (mean s/req): queueing {:.4}  prefill {:.4}  interference {:.4}  decode {:.4}  (sum {:.4})",
            self.requests,
            self.queueing,
            self.prefill,
            self.interference,
            self.decode,
            self.total()
        )
    }
}

/// Attribute per-phase latency from a recorded trace plus the run's
/// per-request records. Only requests present in `metrics.records`
/// (i.e. completed) are attributed.
pub fn attribute(events: &[TraceEvent], metrics: &RunMetrics) -> PhaseAttribution {
    // Per-request prefill execution: sum of the batch durations of every
    // iteration that carried one of its prefill chunks.
    let mut prefill_exec: HashMap<usize, f64> = HashMap::new();
    for ev in events {
        if let EventKind::PrefillChunk { req, dur, .. } = &ev.kind {
            *prefill_exec.entry(*req).or_insert(0.0) += *dur;
        }
    }
    let mut out = PhaseAttribution::default();
    for r in &metrics.records {
        let ttft = (r.first_token - r.arrival).max(0.0);
        // Clamp to TTFT: a chunk's batch duration can slightly exceed the
        // request's own share when the batch carried other work too.
        let prefill = prefill_exec.get(&r.id).copied().unwrap_or(0.0).min(ttft);
        let queueing = (ttft - prefill).max(0.0);
        let decode = (r.exec_time - prefill).max(0.0);
        let decode_span = (r.finish - r.first_token).max(0.0);
        let interference = (decode_span - decode).max(0.0);
        out.requests += 1;
        out.queueing += queueing;
        out.prefill += prefill;
        out.decode += decode;
        out.interference += interference;
    }
    if out.requests > 0 {
        let n = out.requests as f64;
        out.queueing /= n;
        out.prefill /= n;
        out.decode /= n;
        out.interference /= n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;
    use crate::metrics::RequestRecord;

    fn record(id: usize, arrival: f64, first: f64, finish: f64, exec: f64) -> RequestRecord {
        RequestRecord {
            id,
            tenant: 0,
            arrival,
            first_token: first,
            finish,
            prompt_len: 128,
            output_len: 8,
            token_gaps: vec![],
            sched_time: 0.0,
            queue_time: 0.0,
            exec_time: exec,
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let a = attribute(&[], &RunMetrics::default());
        assert_eq!(a.requests, 0);
        assert_eq!(a.total(), 0.0);
    }

    #[test]
    fn phases_sum_to_e2e_without_clamping() {
        // Request 5: arrives 0.0, prefill chunk runs 0.3 inside TTFT 0.5,
        // exec 1.1 (0.3 prefill + 0.8 decode), finishes at 2.5.
        let t = Tracer::recording().for_replica(0);
        t.emit(0.5, EventKind::PrefillChunk { req: 5, take: 128, done: true, dur: 0.3 });
        let evs = t.take();
        let mut m = RunMetrics::default();
        m.push(record(5, 0.0, 0.5, 2.5, 1.1));
        let a = attribute(&evs, &m);
        assert_eq!(a.requests, 1);
        assert!((a.prefill - 0.3).abs() < 1e-12);
        assert!((a.queueing - 0.2).abs() < 1e-12);
        assert!((a.decode - 0.8).abs() < 1e-12);
        assert!((a.interference - 1.2).abs() < 1e-12);
        assert!((a.total() - 2.5).abs() < 1e-12, "phases must sum to e2e");
    }

    #[test]
    fn untraced_request_is_all_queueing_before_first_token() {
        let mut m = RunMetrics::default();
        m.push(record(1, 0.0, 0.4, 1.0, 0.6));
        let a = attribute(&[], &m);
        assert!((a.queueing - 0.4).abs() < 1e-12);
        assert_eq!(a.prefill, 0.0);
        assert!((a.decode - 0.6).abs() < 1e-12);
        assert!((a.interference - 0.0).abs() < 1e-12);
    }

    #[test]
    fn means_are_per_request() {
        let t = Tracer::recording().for_replica(0);
        t.emit(0.2, EventKind::PrefillChunk { req: 0, take: 64, done: true, dur: 0.2 });
        t.emit(0.4, EventKind::PrefillChunk { req: 1, take: 64, done: true, dur: 0.4 });
        let evs = t.take();
        let mut m = RunMetrics::default();
        m.push(record(0, 0.0, 0.2, 1.0, 0.2));
        m.push(record(1, 0.0, 0.4, 2.0, 0.4));
        let a = attribute(&evs, &m);
        assert_eq!(a.requests, 2);
        assert!((a.prefill - 0.3).abs() < 1e-12, "mean of 0.2 and 0.4");
    }
}
