//! Zero-cost tracing + telemetry: typed request-lifecycle events, periodic
//! fleet time-series samples, and exporters (Chrome/Perfetto, JSONL).
//!
//! The design goal is that the *disabled* path costs nothing on the serving
//! hot loops: every instrumentation site goes through a [`Tracer`] handle
//! whose sink is an `Option` — emitting with no sink attached is a single
//! branch on an `Option` discriminant, no allocation, no virtual call, and
//! the event payload is never constructed (arguments to `emit` are built
//! inside `if let` only when a sink is present is *not* required because
//! construction of an [`EventKind`] is a few scalar moves; the branch
//! predictor eats the check). `tests/golden_trace.rs` pins that a disabled
//! tracer leaves `RunMetrics::digest` byte-identical, and that a *recording*
//! tracer is purely observational: the optimized and reference fleet loops
//! emit identical event sequences, and enabling the sampler does not perturb
//! the run digest.
//!
//! Time is virtual-time seconds throughout, quantized to 1 ns by
//! [`TraceEvent::canonical`] for sequence comparison — the same tolerance
//! contract as `RunMetrics::digest` / `deviation` (see `tests/golden_digest.rs`).

mod attribution;
mod export;

pub use attribution::{attribute, PhaseAttribution};
pub use export::{chrome_trace, event_json, to_jsonl};

use crate::util::f64_total_key;
use std::sync::{Arc, Mutex};

/// Replica id used for fleet-level events (routing, autoscale) that are not
/// attributable to a single replica.
pub const FLEET: u32 = u32::MAX;

/// Which streams a batch occupied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    Prefill,
    Decode,
    Mixed,
}

impl TracePhase {
    /// Classify a batch by its decode-sequence and prefill-chunk counts.
    pub fn of(decode_seqs: usize, prefill_chunks: usize) -> TracePhase {
        match (decode_seqs > 0, prefill_chunks > 0) {
            (true, true) => TracePhase::Mixed,
            (false, true) => TracePhase::Prefill,
            _ => TracePhase::Decode,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TracePhase::Prefill => "prefill",
            TracePhase::Decode => "decode",
            TracePhase::Mixed => "mixed",
        }
    }
}

/// Why a request was preempted / had KV state moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptKind {
    /// KV freed; prefill will be recomputed on re-admission.
    Recompute,
    /// KV swapped out to host memory (FastServe).
    SwapOut,
    /// KV swapped back in from host memory (FastServe).
    SwapIn,
    /// Staging-buffer overrun forced a recompute (vLLM-P/D).
    BufferEvict,
}

impl PreemptKind {
    pub fn name(self) -> &'static str {
        match self {
            PreemptKind::Recompute => "recompute",
            PreemptKind::SwapOut => "swap-out",
            PreemptKind::SwapIn => "swap-in",
            PreemptKind::BufferEvict => "buffer-evict",
        }
    }
}

/// A typed lifecycle / telemetry event.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request entered the system (fleet-level, at its arrival time).
    Arrival { req: usize },
    /// Router decision: where the request went and what the policy saw.
    Route { req: usize, target: usize, policy: &'static str, pending: usize, kv_usage: f64 },
    /// Engine accepted the request into its waiting queue.
    Admit { req: usize },
    /// A batch was submitted to the GPU simulator.
    BatchStart { phase: TracePhase, seqs: usize, tokens: usize },
    /// A batch iteration completed; `dur` is its execution time.
    BatchEnd { phase: TracePhase, seqs: usize, tokens: usize, dur: f64 },
    /// `take` prompt tokens of `req` were prefilled in an iteration that ran
    /// for `dur` seconds; `done` marks the final chunk.
    PrefillChunk { req: usize, take: usize, done: bool, dur: f64 },
    /// First output token produced (end of prefill).
    FirstToken { req: usize },
    /// Request preempted / KV moved; see [`PreemptKind`].
    Preempt { req: usize, kind: PreemptKind },
    /// KV cache reserved for `req`; `usage` is post-allocation occupancy.
    KvAlloc { req: usize, tokens: usize, usage: f64 },
    /// SM repartition applied (Nexus): new prefill/decode split.
    Repartition { r_p: f64, r_d: f64, decode_mode: bool },
    /// Prefill→decode KV handoff through the staging buffer (vLLM-P/D).
    Transfer { req: usize, bytes: f64, dur: f64 },
    /// Autoscaler decision: fleet resizing from → to replicas.
    Scale { from: usize, to: usize },
    /// Replica entered service.
    ReplicaStart,
    /// Replica began draining (no new admissions).
    ReplicaDrain,
    /// Replica left service.
    ReplicaRetire,
    /// The parallel fleet loop migrated this replica between worker shards
    /// (work stealing). Purely observational: migration never changes what
    /// the replica computes, only which thread steps it, so traces with and
    /// without rebalancing differ exactly by these events
    /// (`tests/golden_trace.rs` pins this).
    ShardRebalance { from_shard: usize, to_shard: usize },
    /// WFQ front stage dispatched a tenant's request to the routing stage
    /// (quota and capacity permitted it). Fleet-level, at dispatch time.
    TenantAdmit { req: usize, tenant: usize },
    /// WFQ front stage held a tenant's request back (quota or capacity
    /// exhausted); `queued` is the tenant's backlog depth after the hold.
    TenantThrottle { req: usize, tenant: usize, queued: usize },
    /// Routed request found its full shared prefix resident on the target
    /// replica; `saved` prompt tokens skip prefill. Fleet-level, at route time.
    PrefixHit { req: usize, replica: usize, saved: usize },
    /// Shared prefix fetched from the fleet cache tier (another replica had
    /// published it); `saved` is the net prompt-token saving after paying the
    /// transfer cost. Fleet-level, at route time.
    PrefixFetch { req: usize, replica: usize, saved: usize },
    /// Request carried a shared prefix but neither the target replica nor the
    /// tier could serve it — full prefill. Fleet-level, at route time.
    PrefixMiss { req: usize, replica: usize },
    /// Admitting a prefix evicted `evicted` LRU chains from the target
    /// replica's prefix store. Fleet-level, at route time.
    PrefixEvict { replica: usize, evicted: usize },
    /// Request finished its last token.
    Complete { req: usize },
    /// Periodic time-series sample of one replica's state.
    Sample {
        kv_usage: f64,
        waiting: usize,
        running: usize,
        pending: usize,
        sm_prefill: f64,
        inflight: usize,
    },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrival { .. } => "arrival",
            EventKind::Route { .. } => "route",
            EventKind::Admit { .. } => "admit",
            EventKind::BatchStart { .. } => "batch-start",
            EventKind::BatchEnd { .. } => "batch-end",
            EventKind::PrefillChunk { .. } => "prefill-chunk",
            EventKind::FirstToken { .. } => "first-token",
            EventKind::Preempt { .. } => "preempt",
            EventKind::KvAlloc { .. } => "kv-alloc",
            EventKind::Repartition { .. } => "repartition",
            EventKind::Transfer { .. } => "transfer",
            EventKind::Scale { .. } => "scale",
            EventKind::ReplicaStart => "replica-start",
            EventKind::ReplicaDrain => "replica-drain",
            EventKind::ReplicaRetire => "replica-retire",
            EventKind::ShardRebalance { .. } => "shard-rebalance",
            EventKind::TenantAdmit { .. } => "tenant-admit",
            EventKind::TenantThrottle { .. } => "tenant-throttle",
            EventKind::PrefixHit { .. } => "prefix-hit",
            EventKind::PrefixFetch { .. } => "prefix-fetch",
            EventKind::PrefixMiss { .. } => "prefix-miss",
            EventKind::PrefixEvict { .. } => "prefix-evict",
            EventKind::Complete { .. } => "complete",
            EventKind::Sample { .. } => "sample",
        }
    }
}

/// One trace event: virtual time, owning replica ([`FLEET`] for fleet-level
/// events), and the typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub time: f64,
    pub replica: u32,
    pub kind: EventKind,
}

/// Quantize a virtual time / ratio to integer nanoseconds — the same
/// contract as `RunMetrics::digest`.
fn q(x: f64) -> i64 {
    (x * 1e9).round() as i64
}

impl TraceEvent {
    /// Canonical 1 ns-quantized string form, used by the golden trace tests
    /// to compare event *sequences* across loop implementations whose float
    /// noise is ≪ 1 ns.
    pub fn canonical(&self) -> String {
        let mut s = String::with_capacity(64);
        if self.replica == FLEET {
            s.push_str("fleet");
        } else {
            s.push('r');
            s.push_str(&self.replica.to_string());
        }
        s.push_str(&format!(" @{} {}", q(self.time), self.kind.name()));
        let detail = match &self.kind {
            EventKind::Arrival { req } | EventKind::Admit { req } => format!(" req={req}"),
            EventKind::Route { req, target, policy, pending, kv_usage } => {
                format!(" req={req} target={target} policy={policy} pending={pending} kv={}", q(*kv_usage))
            }
            EventKind::BatchStart { phase, seqs, tokens } => {
                format!(" phase={} seqs={seqs} tokens={tokens}", phase.name())
            }
            EventKind::BatchEnd { phase, seqs, tokens, dur } => {
                format!(" phase={} seqs={seqs} tokens={tokens} dur={}", phase.name(), q(*dur))
            }
            EventKind::PrefillChunk { req, take, done, dur } => {
                format!(" req={req} take={take} done={done} dur={}", q(*dur))
            }
            EventKind::FirstToken { req } | EventKind::Complete { req } => format!(" req={req}"),
            EventKind::Preempt { req, kind } => format!(" req={req} kind={}", kind.name()),
            EventKind::KvAlloc { req, tokens, usage } => {
                format!(" req={req} tokens={tokens} usage={}", q(*usage))
            }
            EventKind::Repartition { r_p, r_d, decode_mode } => {
                format!(" r_p={} r_d={} decode_mode={decode_mode}", q(*r_p), q(*r_d))
            }
            EventKind::Transfer { req, bytes, dur } => {
                format!(" req={req} bytes={} dur={}", q(*bytes), q(*dur))
            }
            EventKind::Scale { from, to } => format!(" from={from} to={to}"),
            EventKind::ShardRebalance { from_shard, to_shard } => {
                format!(" from_shard={from_shard} to_shard={to_shard}")
            }
            EventKind::TenantAdmit { req, tenant } => format!(" req={req} tenant={tenant}"),
            EventKind::TenantThrottle { req, tenant, queued } => {
                format!(" req={req} tenant={tenant} queued={queued}")
            }
            EventKind::PrefixHit { req, replica, saved }
            | EventKind::PrefixFetch { req, replica, saved } => {
                format!(" req={req} replica={replica} saved={saved}")
            }
            EventKind::PrefixMiss { req, replica } => format!(" req={req} replica={replica}"),
            EventKind::PrefixEvict { replica, evicted } => {
                format!(" replica={replica} evicted={evicted}")
            }
            EventKind::Sample { kv_usage, waiting, running, pending, sm_prefill, inflight } => {
                format!(
                    " kv={} waiting={waiting} running={running} pending={pending} sm_prefill={} inflight={inflight}",
                    q(*kv_usage),
                    q(*sm_prefill)
                )
            }
            EventKind::ReplicaStart | EventKind::ReplicaDrain | EventKind::ReplicaRetire => {
                String::new()
            }
        };
        s.push_str(&detail);
        s
    }

    /// Structural equality with a tolerance on float fields — the sequence
    /// analogue of `RunMetrics::deviation`. Replica, variant, and all integer
    /// fields must match exactly; `time` and float payloads may differ by up
    /// to `tol`. Use this (not [`TraceEvent::canonical`]) when comparing
    /// traces from *different* loop implementations, where float noise can
    /// straddle a quantization-bucket boundary.
    pub fn approx_eq(&self, other: &TraceEvent, tol: f64) -> bool {
        let close = |a: f64, b: f64| (a - b).abs() <= tol;
        if self.replica != other.replica || !close(self.time, other.time) {
            return false;
        }
        use EventKind as K;
        match (&self.kind, &other.kind) {
            (K::Arrival { req: a }, K::Arrival { req: b })
            | (K::Admit { req: a }, K::Admit { req: b })
            | (K::FirstToken { req: a }, K::FirstToken { req: b })
            | (K::Complete { req: a }, K::Complete { req: b }) => a == b,
            (
                K::Route { req: ra, target: ta, policy: pa, pending: na, kv_usage: ka },
                K::Route { req: rb, target: tb, policy: pb, pending: nb, kv_usage: kb },
            ) => ra == rb && ta == tb && pa == pb && na == nb && close(*ka, *kb),
            (
                K::BatchStart { phase: pa, seqs: sa, tokens: ta },
                K::BatchStart { phase: pb, seqs: sb, tokens: tb },
            ) => pa == pb && sa == sb && ta == tb,
            (
                K::BatchEnd { phase: pa, seqs: sa, tokens: ta, dur: da },
                K::BatchEnd { phase: pb, seqs: sb, tokens: tb, dur: db },
            ) => pa == pb && sa == sb && ta == tb && close(*da, *db),
            (
                K::PrefillChunk { req: ra, take: ta, done: fa, dur: da },
                K::PrefillChunk { req: rb, take: tb, done: fb, dur: db },
            ) => ra == rb && ta == tb && fa == fb && close(*da, *db),
            (K::Preempt { req: ra, kind: ka }, K::Preempt { req: rb, kind: kb }) => {
                ra == rb && ka == kb
            }
            (
                K::KvAlloc { req: ra, tokens: ta, usage: ua },
                K::KvAlloc { req: rb, tokens: tb, usage: ub },
            ) => ra == rb && ta == tb && close(*ua, *ub),
            (
                K::Repartition { r_p: pa, r_d: da, decode_mode: ma },
                K::Repartition { r_p: pb, r_d: db, decode_mode: mb },
            ) => ma == mb && close(*pa, *pb) && close(*da, *db),
            (
                K::Transfer { req: ra, bytes: ba, dur: da },
                K::Transfer { req: rb, bytes: bb, dur: db },
            ) => ra == rb && close(*ba, *bb) && close(*da, *db),
            (K::Scale { from: fa, to: ta }, K::Scale { from: fb, to: tb }) => {
                fa == fb && ta == tb
            }
            (
                K::ShardRebalance { from_shard: fa, to_shard: ta },
                K::ShardRebalance { from_shard: fb, to_shard: tb },
            ) => fa == fb && ta == tb,
            (
                K::TenantAdmit { req: ra, tenant: ta },
                K::TenantAdmit { req: rb, tenant: tb },
            ) => ra == rb && ta == tb,
            (
                K::TenantThrottle { req: ra, tenant: ta, queued: qa },
                K::TenantThrottle { req: rb, tenant: tb, queued: qb },
            ) => ra == rb && ta == tb && qa == qb,
            (
                K::PrefixHit { req: ra, replica: pa, saved: sa },
                K::PrefixHit { req: rb, replica: pb, saved: sb },
            )
            | (
                K::PrefixFetch { req: ra, replica: pa, saved: sa },
                K::PrefixFetch { req: rb, replica: pb, saved: sb },
            ) => ra == rb && pa == pb && sa == sb,
            (
                K::PrefixMiss { req: ra, replica: pa },
                K::PrefixMiss { req: rb, replica: pb },
            ) => ra == rb && pa == pb,
            (
                K::PrefixEvict { replica: pa, evicted: ea },
                K::PrefixEvict { replica: pb, evicted: eb },
            ) => pa == pb && ea == eb,
            (K::ReplicaStart, K::ReplicaStart)
            | (K::ReplicaDrain, K::ReplicaDrain)
            | (K::ReplicaRetire, K::ReplicaRetire) => true,
            (
                K::Sample {
                    kv_usage: ka,
                    waiting: wa,
                    running: ra,
                    pending: na,
                    sm_prefill: sa,
                    inflight: ia,
                },
                K::Sample {
                    kv_usage: kb,
                    waiting: wb,
                    running: rb,
                    pending: nb,
                    sm_prefill: sb,
                    inflight: ib,
                },
            ) => wa == wb && ra == rb && na == nb && ia == ib && close(*ka, *kb) && close(*sa, *sb),
            _ => false,
        }
    }
}

/// Consumer of trace events. The default implementation drops everything,
/// so a sink that only cares about a subset overrides selectively.
pub trait TraceSink {
    fn record(&mut self, _ev: TraceEvent) {}
}

/// The zero-cost default: ignores every event.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {}

/// In-memory sink capturing every event in emission order.
#[derive(Debug, Clone, Default)]
pub struct RecordingSink {
    pub events: Vec<TraceEvent>,
}

impl TraceSink for RecordingSink {
    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// Point-in-time state snapshot used by the periodic sampler. Engines fill
/// what they track; the defaults are safe for engines without queues.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineSnapshot {
    /// Requests admitted but not yet prefill-complete / not yet scheduled.
    pub waiting: usize,
    /// Requests actively decoding.
    pub running: usize,
    /// KV-cache occupancy in `[0, 1]` (max across pools for split-KV engines).
    pub kv_usage: f64,
    /// Prefill SM share `r_p` (1.0 for engines without SM partitioning).
    pub sm_prefill: f64,
    /// Batches currently in flight on the GPU simulator(s).
    pub inflight: usize,
}

/// Cheap cloneable handle threaded through engines and the cluster loop.
///
/// Two-state dispatch: `sink == None` is the disabled path (one branch per
/// hook, nothing else); `Some` shares a [`RecordingSink`] across all clones,
/// so the fleet loop, router, autoscaler, and every engine append to one
/// ordered stream. Each clone carries the replica id it stamps on events.
#[derive(Debug, Clone)]
pub struct Tracer {
    sink: Option<Arc<Mutex<RecordingSink>>>,
    sample_interval: f64,
    replica: u32,
}

impl Default for Tracer {
    /// A disabled tracer: every `emit` is a no-op.
    fn default() -> Tracer {
        Tracer { sink: None, sample_interval: 0.0, replica: FLEET }
    }
}

impl Tracer {
    /// Disabled tracer (alias for `Tracer::default()`).
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// Recording tracer with a fresh shared sink (no periodic sampling).
    pub fn recording() -> Tracer {
        Tracer {
            sink: Some(Arc::new(Mutex::new(RecordingSink::default()))),
            sample_interval: 0.0,
            replica: FLEET,
        }
    }

    /// A tracer with a *fresh* sink but this tracer's sampling interval and
    /// enablement: disabled stays disabled; recording forks an independent
    /// stream. Used by the parallel fleet loop to give each worker shard its
    /// own sink (no cross-thread contention on the hot path); the per-shard
    /// streams are recombined with [`merge_streams`] at the end of the run.
    pub fn fork_sink(&self) -> Tracer {
        Tracer {
            sink: self.sink.as_ref().map(|_| Arc::new(Mutex::new(RecordingSink::default()))),
            sample_interval: self.sample_interval,
            replica: self.replica,
        }
    }

    /// Enable the periodic time-series sampler at `dt` virtual seconds.
    pub fn with_sampling(mut self, dt: f64) -> Tracer {
        self.sample_interval = dt;
        self
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Sampling interval, `None` when disabled or when no sink is attached.
    pub fn sample_interval(&self) -> Option<f64> {
        if self.sink.is_some() && self.sample_interval > 0.0 {
            Some(self.sample_interval)
        } else {
            None
        }
    }

    /// A clone stamping events with replica `id` (sharing the same sink).
    pub fn for_replica(&self, id: u32) -> Tracer {
        Tracer { sink: self.sink.clone(), sample_interval: self.sample_interval, replica: id }
    }

    /// Emit an event at virtual time `time`, stamped with this handle's
    /// replica. Disabled path: a single `Option` branch.
    #[inline]
    pub fn emit(&self, time: f64, kind: EventKind) {
        if let Some(sink) = &self.sink {
            sink.lock().unwrap().record(TraceEvent { time, replica: self.replica, kind });
        }
    }

    /// Emit stamped with an explicit replica id (fleet loop emitting
    /// per-replica samples through its own handle).
    #[inline]
    pub fn emit_for(&self, replica: u32, time: f64, kind: EventKind) {
        if let Some(sink) = &self.sink {
            sink.lock().unwrap().record(TraceEvent { time, replica, kind });
        }
    }

    /// Drain all recorded events (empty for a disabled tracer).
    pub fn take(&self) -> Vec<TraceEvent> {
        match &self.sink {
            Some(sink) => std::mem::take(&mut sink.lock().unwrap().events),
            None => Vec::new(),
        }
    }

    /// Re-emit a batch of already-stamped events into this tracer's sink
    /// (no-op when disabled). Used to fold merged per-shard streams back
    /// into the cluster's canonical tracer.
    pub fn absorb(&self, events: Vec<TraceEvent>) {
        if let Some(sink) = &self.sink {
            sink.lock().unwrap().events.extend(events);
        }
    }
}

/// Merge several per-shard trace streams into one canonical sequence,
/// stably sorted by `(time, replica)` with ties broken by within-stream
/// emission order. Each shard's stream is internally time-ordered, and
/// fleet-level events ([`FLEET`] = `u32::MAX`) sort after replica events at
/// the same instant; the stable sort therefore yields one deterministic
/// sequence independent of how replicas were sharded across threads.
pub fn merge_streams(streams: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = streams.into_iter().flatten().collect();
    all.sort_by_key(|e| (f64_total_key(e.time), e.replica));
    all
}

/// Canonically order one trace stream by `(time, replica)`, preserving
/// within-key emission order — the comparison form used by the parallel
/// determinism tests (the sequential loop interleaves shards differently
/// than the merged parallel stream, but both sort to the same sequence).
pub fn canonical_order(events: &mut [TraceEvent]) {
    events.sort_by_key(|e| (f64_total_key(e.time), e.replica));
}

/// Periodic virtual-time sampler: tracks the next due sample point on a
/// fixed `dt` grid (first sample at `dt`, not 0). Purely observational —
/// the serving loops call [`Sampler::due`] with each iteration's event time
/// and emit samples for every grid point crossed since the last call, so no
/// artificial events are injected into the loops and run behavior (digests,
/// event counts) is untouched.
#[derive(Debug, Clone)]
pub struct Sampler {
    dt: f64,
    next: f64,
}

impl Sampler {
    /// `None` when the tracer has no sink or sampling is off.
    pub fn new(tracer: &Tracer) -> Option<Sampler> {
        tracer.sample_interval().map(|dt| Sampler { dt, next: dt })
    }

    /// Invoke `f` for every due grid point `ts ≤ t`, in order.
    pub fn due(&mut self, t: f64, mut f: impl FnMut(f64)) {
        while self.next <= t {
            f(self.next);
            self.next += self.dt;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::default();
        assert!(!t.enabled());
        assert_eq!(t.sample_interval(), None);
        t.emit(1.0, EventKind::Arrival { req: 0 });
        assert!(t.take().is_empty());
        assert!(Sampler::new(&t).is_none());
    }

    #[test]
    fn recording_tracer_shares_one_sink_across_clones() {
        let t = Tracer::recording();
        let r0 = t.for_replica(0);
        let r1 = t.for_replica(1);
        t.emit(0.5, EventKind::Arrival { req: 7 });
        r0.emit(1.0, EventKind::Admit { req: 7 });
        r1.emit(1.5, EventKind::Complete { req: 7 });
        let evs = t.take();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].replica, FLEET);
        assert_eq!(evs[1].replica, 0);
        assert_eq!(evs[2].replica, 1);
        // Drained: subsequent take is empty.
        assert!(t.take().is_empty());
    }

    #[test]
    fn canonical_quantizes_to_ns() {
        let a = TraceEvent {
            time: 1.0,
            replica: 3,
            kind: EventKind::BatchEnd { phase: TracePhase::Mixed, seqs: 4, tokens: 260, dur: 0.25 },
        };
        let mut b = a.clone();
        b.time += 3e-13; // sub-ns drift must not change the canonical form
        assert_eq!(a.canonical(), b.canonical());
        let mut c = a.clone();
        c.time += 1e-3;
        assert_ne!(a.canonical(), c.canonical());
        assert!(a.canonical().starts_with("r3 @1000000000 batch-end"));
    }

    #[test]
    fn sampler_emits_every_grid_point_once() {
        let t = Tracer::recording().with_sampling(0.5);
        let mut s = Sampler::new(&t).expect("sampling enabled");
        let mut points = Vec::new();
        s.due(0.4, |ts| points.push(ts)); // nothing due before first grid point
        assert!(points.is_empty());
        s.due(1.6, |ts| points.push(ts));
        s.due(1.6, |ts| points.push(ts)); // same t again: nothing new
        s.due(2.0, |ts| points.push(ts));
        let want = [0.5, 1.0, 1.5, 2.0];
        assert_eq!(points.len(), want.len());
        for (p, w) in points.iter().zip(want.iter()) {
            assert!((p - w).abs() < 1e-12);
        }
    }

    #[test]
    fn approx_eq_tolerates_float_noise_only() {
        let a = TraceEvent {
            time: 1.0,
            replica: 2,
            kind: EventKind::BatchEnd { phase: TracePhase::Decode, seqs: 8, tokens: 8, dur: 0.1 },
        };
        let mut b = a.clone();
        b.time += 5e-10; // within tol
        if let EventKind::BatchEnd { dur, .. } = &mut b.kind {
            *dur -= 5e-10;
        }
        assert!(a.approx_eq(&b, 1e-9));
        // Integer fields are exact.
        let mut c = a.clone();
        if let EventKind::BatchEnd { seqs, .. } = &mut c.kind {
            *seqs = 9;
        }
        assert!(!a.approx_eq(&c, 1e-9));
        // Different variants never match.
        let d = TraceEvent { time: 1.0, replica: 2, kind: EventKind::Complete { req: 1 } };
        assert!(!a.approx_eq(&d, 1e-9));
        // Replica must match exactly.
        let mut e = a.clone();
        e.replica = 3;
        assert!(!a.approx_eq(&e, 1e-9));
    }

    #[test]
    fn fork_sink_is_independent_and_merge_is_canonical() {
        let t = Tracer::recording().with_sampling(0.5);
        let shard = t.fork_sink();
        assert!(shard.enabled());
        assert_eq!(shard.sample_interval(), Some(0.5));
        // Shard events do not land in the parent sink.
        shard.emit_for(1, 2.0, EventKind::Complete { req: 9 });
        shard.emit_for(0, 1.0, EventKind::Admit { req: 9 });
        t.emit_for(FLEET, 1.0, EventKind::Arrival { req: 9 });
        assert_eq!(t.take().len(), 1);
        // Disabled parents fork disabled children.
        assert!(!Tracer::default().fork_sink().enabled());
        // Merge orders by (time, replica): r0@1.0, fleet@1.0, r1@2.0.
        let merged = merge_streams(vec![
            shard.take(),
            vec![TraceEvent { time: 1.0, replica: FLEET, kind: EventKind::Arrival { req: 9 } }],
        ]);
        let key: Vec<(i64, u32)> = merged.iter().map(|e| (q(e.time), e.replica)).collect();
        assert_eq!(key, vec![(1_000_000_000, 0), (1_000_000_000, FLEET), (2_000_000_000, 1)]);
    }

    #[test]
    fn phase_classification() {
        assert_eq!(TracePhase::of(0, 3), TracePhase::Prefill);
        assert_eq!(TracePhase::of(5, 0), TracePhase::Decode);
        assert_eq!(TracePhase::of(5, 3), TracePhase::Mixed);
    }
}
