//! Trace exporters: Chrome/Perfetto `trace_event` JSON and compact JSONL.
//!
//! The Chrome format (loadable at `ui.perfetto.dev` or `chrome://tracing`)
//! maps the fleet onto processes: pid 0 is the fleet (routing, autoscale),
//! pid `replica + 1` is one replica. Batch executions become `ph:"X"`
//! complete events on per-phase threads, requests become async spans
//! (`b`/`n`/`e`) so queueing + prefill + decode of one request reads as a
//! single track, and the periodic samples become `ph:"C"` counter tracks
//! (KV occupancy, queue depths, SM split). High-frequency events that would
//! drown the UI (per-chunk prefill progress, KV allocations, batch starts)
//! are JSONL-only.

use std::collections::BTreeSet;

use super::{EventKind, TraceEvent, FLEET};
use crate::util::json::Json;

/// Pid for a replica id in the Chrome export (fleet sentinel → 0).
fn pid_of(replica: u32) -> usize {
    if replica == FLEET {
        0
    } else {
        replica as usize + 1
    }
}

/// Microseconds, the Chrome trace time unit.
fn us(t: f64) -> f64 {
    t * 1e6
}

fn row(pid: usize, tid: usize, ph: &str, name: &str, ts: f64, args: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("pid", Json::from(pid)),
        ("tid", Json::from(tid)),
        ("ph", Json::from(ph)),
        ("name", Json::from(name)),
        ("ts", Json::from(us(ts))),
    ];
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    Json::obj(fields)
}

fn instant(pid: usize, name: &str, ts: f64, args: Vec<(&str, Json)>) -> Json {
    let mut v = row(pid, 0, "i", name, ts, args);
    if let Json::Obj(o) = &mut v {
        o.insert("s".to_string(), Json::from("p")); // process-scoped instant
    }
    v
}

fn counter(pid: usize, name: &str, ts: f64, args: Vec<(&str, Json)>) -> Json {
    row(pid, 0, "C", name, ts, args)
}

/// Async-span row (`ph` = "b" begin / "n" instant / "e" end), one span id
/// per request so its lifecycle renders as a single track.
fn async_row(pid: usize, ph: &str, req: usize, ts: f64) -> Json {
    let mut v = row(pid, 0, ph, &format!("req {req}"), ts, Vec::new());
    if let Json::Obj(o) = &mut v {
        o.insert("cat".to_string(), Json::from("request"));
        o.insert("id".to_string(), Json::from(req));
    }
    v
}

fn metadata(pid: usize, tid: Option<usize>, what: &str, value: &str) -> Json {
    let mut fields = vec![
        ("pid", Json::from(pid)),
        ("ph", Json::from("M")),
        ("name", Json::from(what)),
        ("args", Json::obj(vec![("name", Json::from(value))])),
    ];
    if let Some(t) = tid {
        fields.push(("tid", Json::from(t)));
    }
    Json::obj(fields)
}

/// Convert a trace to a Chrome/Perfetto `trace_event` JSON document.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut rows: Vec<Json> = Vec::new();
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    for ev in events {
        pids.insert(ev.replica);
    }
    for &r in &pids {
        let pid = pid_of(r);
        if r == FLEET {
            rows.push(metadata(pid, None, "process_name", "fleet"));
        } else {
            rows.push(metadata(pid, None, "process_name", &format!("replica {r}")));
            rows.push(metadata(pid, Some(0), "thread_name", "events"));
            rows.push(metadata(pid, Some(1), "thread_name", "prefill batches"));
            rows.push(metadata(pid, Some(2), "thread_name", "decode batches"));
            rows.push(metadata(pid, Some(3), "thread_name", "mixed batches"));
        }
    }
    for ev in events {
        let pid = pid_of(ev.replica);
        match &ev.kind {
            // JSONL-only (too chatty for the timeline UI):
            EventKind::Arrival { .. }
            | EventKind::BatchStart { .. }
            | EventKind::PrefillChunk { .. }
            | EventKind::KvAlloc { .. } => {}
            EventKind::Route { req, target, policy, pending, kv_usage } => {
                rows.push(instant(
                    pid,
                    &format!("route req {req} -> r{target}"),
                    ev.time,
                    vec![
                        ("policy", Json::from(*policy)),
                        ("target_pending", Json::from(*pending)),
                        ("target_kv_usage", Json::from(*kv_usage)),
                    ],
                ));
            }
            EventKind::Admit { req } => rows.push(async_row(pid, "b", *req, ev.time)),
            EventKind::FirstToken { req } => rows.push(async_row(pid, "n", *req, ev.time)),
            EventKind::Complete { req } => rows.push(async_row(pid, "e", *req, ev.time)),
            EventKind::BatchEnd { phase, seqs, tokens, dur } => {
                let tid = match phase {
                    super::TracePhase::Prefill => 1,
                    super::TracePhase::Decode => 2,
                    super::TracePhase::Mixed => 3,
                };
                let mut v = row(
                    pid,
                    tid,
                    "X",
                    &format!("{} batch", phase.name()),
                    ev.time - dur,
                    vec![("seqs", Json::from(*seqs)), ("tokens", Json::from(*tokens))],
                );
                if let Json::Obj(o) = &mut v {
                    o.insert("dur".to_string(), Json::from(us(*dur)));
                }
                rows.push(v);
            }
            EventKind::Preempt { req, kind } => {
                rows.push(instant(
                    pid,
                    &format!("preempt req {req}"),
                    ev.time,
                    vec![("kind", Json::from(kind.name()))],
                ));
            }
            EventKind::Repartition { r_p, r_d, decode_mode } => {
                rows.push(instant(
                    pid,
                    "repartition",
                    ev.time,
                    vec![
                        ("r_p", Json::from(*r_p)),
                        ("r_d", Json::from(*r_d)),
                        ("decode_mode", Json::from(*decode_mode)),
                    ],
                ));
                rows.push(counter(
                    pid,
                    "sm_split",
                    ev.time,
                    vec![("prefill", Json::from(*r_p)), ("decode", Json::from(*r_d))],
                ));
            }
            EventKind::Transfer { req, bytes, dur } => {
                rows.push(instant(
                    pid,
                    &format!("kv transfer req {req}"),
                    ev.time,
                    vec![("bytes", Json::from(*bytes)), ("dur_s", Json::from(*dur))],
                ));
            }
            EventKind::Scale { from, to } => {
                rows.push(instant(
                    pid,
                    &format!("scale {from} -> {to}"),
                    ev.time,
                    vec![("from", Json::from(*from)), ("to", Json::from(*to))],
                ));
                rows.push(counter(pid, "replicas", ev.time, vec![("count", Json::from(*to))]));
            }
            EventKind::ShardRebalance { from_shard, to_shard } => {
                rows.push(instant(
                    pid,
                    &format!("shard rebalance {from_shard} -> {to_shard}"),
                    ev.time,
                    vec![
                        ("from_shard", Json::from(*from_shard)),
                        ("to_shard", Json::from(*to_shard)),
                    ],
                ));
            }
            EventKind::TenantAdmit { req, tenant } => {
                rows.push(instant(
                    pid,
                    &format!("tenant {tenant} admit req {req}"),
                    ev.time,
                    vec![("req", Json::from(*req)), ("tenant", Json::from(*tenant))],
                ));
            }
            EventKind::TenantThrottle { req, tenant, queued } => {
                rows.push(instant(
                    pid,
                    &format!("tenant {tenant} throttle req {req}"),
                    ev.time,
                    vec![
                        ("req", Json::from(*req)),
                        ("tenant", Json::from(*tenant)),
                        ("queued", Json::from(*queued)),
                    ],
                ));
            }
            EventKind::PrefixHit { req, replica, saved }
            | EventKind::PrefixFetch { req, replica, saved } => {
                let what = if matches!(ev.kind, EventKind::PrefixHit { .. }) {
                    "prefix hit"
                } else {
                    "prefix fetch"
                };
                rows.push(instant(
                    pid,
                    &format!("{what} req {req} @ r{replica}"),
                    ev.time,
                    vec![
                        ("req", Json::from(*req)),
                        ("replica", Json::from(*replica)),
                        ("saved_tokens", Json::from(*saved)),
                    ],
                ));
            }
            EventKind::PrefixMiss { req, replica } => {
                rows.push(instant(
                    pid,
                    &format!("prefix miss req {req} @ r{replica}"),
                    ev.time,
                    vec![("req", Json::from(*req)), ("replica", Json::from(*replica))],
                ));
            }
            EventKind::PrefixEvict { replica, evicted } => {
                rows.push(instant(
                    pid,
                    &format!("prefix evict r{replica}"),
                    ev.time,
                    vec![("replica", Json::from(*replica)), ("evicted", Json::from(*evicted))],
                ));
            }
            EventKind::ReplicaStart => rows.push(instant(pid, "replica start", ev.time, vec![])),
            EventKind::ReplicaDrain => rows.push(instant(pid, "replica drain", ev.time, vec![])),
            EventKind::ReplicaRetire => rows.push(instant(pid, "replica retire", ev.time, vec![])),
            EventKind::Sample { kv_usage, waiting, running, pending, sm_prefill, inflight } => {
                rows.push(counter(pid, "kv_usage", ev.time, vec![("kv", Json::from(*kv_usage))]));
                rows.push(counter(
                    pid,
                    "queues",
                    ev.time,
                    vec![
                        ("waiting", Json::from(*waiting)),
                        ("running", Json::from(*running)),
                        ("pending", Json::from(*pending)),
                        ("inflight", Json::from(*inflight)),
                    ],
                ));
                rows.push(counter(
                    pid,
                    "sm_split",
                    ev.time,
                    vec![
                        ("prefill", Json::from(*sm_prefill)),
                        ("decode", Json::from(1.0 - *sm_prefill)),
                    ],
                ));
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(rows)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

/// One event as a flat JSON object (the JSONL record).
pub fn event_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("t", Json::from(ev.time)), ("ev", Json::from(ev.kind.name()))];
    if ev.replica == FLEET {
        fields.push(("replica", Json::from("fleet")));
    } else {
        fields.push(("replica", Json::from(ev.replica as usize)));
    }
    match &ev.kind {
        EventKind::Arrival { req }
        | EventKind::Admit { req }
        | EventKind::FirstToken { req }
        | EventKind::Complete { req } => fields.push(("req", Json::from(*req))),
        EventKind::Route { req, target, policy, pending, kv_usage } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("target", Json::from(*target)));
            fields.push(("policy", Json::from(*policy)));
            fields.push(("pending", Json::from(*pending)));
            fields.push(("kv_usage", Json::from(*kv_usage)));
        }
        EventKind::BatchStart { phase, seqs, tokens } => {
            fields.push(("phase", Json::from(phase.name())));
            fields.push(("seqs", Json::from(*seqs)));
            fields.push(("tokens", Json::from(*tokens)));
        }
        EventKind::BatchEnd { phase, seqs, tokens, dur } => {
            fields.push(("phase", Json::from(phase.name())));
            fields.push(("seqs", Json::from(*seqs)));
            fields.push(("tokens", Json::from(*tokens)));
            fields.push(("dur", Json::from(*dur)));
        }
        EventKind::PrefillChunk { req, take, done, dur } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("take", Json::from(*take)));
            fields.push(("done", Json::from(*done)));
            fields.push(("dur", Json::from(*dur)));
        }
        EventKind::Preempt { req, kind } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("kind", Json::from(kind.name())));
        }
        EventKind::KvAlloc { req, tokens, usage } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("tokens", Json::from(*tokens)));
            fields.push(("usage", Json::from(*usage)));
        }
        EventKind::Repartition { r_p, r_d, decode_mode } => {
            fields.push(("r_p", Json::from(*r_p)));
            fields.push(("r_d", Json::from(*r_d)));
            fields.push(("decode_mode", Json::from(*decode_mode)));
        }
        EventKind::Transfer { req, bytes, dur } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("bytes", Json::from(*bytes)));
            fields.push(("dur", Json::from(*dur)));
        }
        EventKind::Scale { from, to } => {
            fields.push(("from", Json::from(*from)));
            fields.push(("to", Json::from(*to)));
        }
        EventKind::ShardRebalance { from_shard, to_shard } => {
            fields.push(("from_shard", Json::from(*from_shard)));
            fields.push(("to_shard", Json::from(*to_shard)));
        }
        EventKind::TenantAdmit { req, tenant } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("tenant", Json::from(*tenant)));
        }
        EventKind::TenantThrottle { req, tenant, queued } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("tenant", Json::from(*tenant)));
            fields.push(("queued", Json::from(*queued)));
        }
        EventKind::PrefixHit { req, replica, saved }
        | EventKind::PrefixFetch { req, replica, saved } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("target", Json::from(*replica)));
            fields.push(("saved", Json::from(*saved)));
        }
        EventKind::PrefixMiss { req, replica } => {
            fields.push(("req", Json::from(*req)));
            fields.push(("target", Json::from(*replica)));
        }
        EventKind::PrefixEvict { replica, evicted } => {
            fields.push(("target", Json::from(*replica)));
            fields.push(("evicted", Json::from(*evicted)));
        }
        EventKind::Sample { kv_usage, waiting, running, pending, sm_prefill, inflight } => {
            fields.push(("kv_usage", Json::from(*kv_usage)));
            fields.push(("waiting", Json::from(*waiting)));
            fields.push(("running", Json::from(*running)));
            fields.push(("pending", Json::from(*pending)));
            fields.push(("sm_prefill", Json::from(*sm_prefill)));
            fields.push(("inflight", Json::from(*inflight)));
        }
        EventKind::ReplicaStart | EventKind::ReplicaDrain | EventKind::ReplicaRetire => {}
    }
    Json::obj(fields)
}

/// Compact JSONL event log: one JSON object per line, every event included.
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{PreemptKind, TracePhase, Tracer};
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        let t = Tracer::recording();
        let r0 = t.for_replica(0);
        t.emit(0.0, EventKind::Arrival { req: 1 });
        t.emit(0.0, EventKind::Route { req: 1, target: 0, policy: "jsq", pending: 0, kv_usage: 0.0 });
        r0.emit(0.0, EventKind::Admit { req: 1 });
        r0.emit(0.1, EventKind::BatchStart { phase: TracePhase::Prefill, seqs: 1, tokens: 256 });
        r0.emit(0.4, EventKind::BatchEnd { phase: TracePhase::Prefill, seqs: 1, tokens: 256, dur: 0.3 });
        r0.emit(0.4, EventKind::PrefillChunk { req: 1, take: 256, done: true, dur: 0.3 });
        r0.emit(0.4, EventKind::FirstToken { req: 1 });
        r0.emit(0.5, EventKind::Preempt { req: 1, kind: PreemptKind::Recompute });
        r0.emit(0.6, EventKind::Repartition { r_p: 0.4, r_d: 0.6, decode_mode: true });
        t.emit(1.0, EventKind::Scale { from: 1, to: 2 });
        r0.emit(
            1.0,
            EventKind::Sample { kv_usage: 0.25, waiting: 2, running: 1, pending: 3, sm_prefill: 0.4, inflight: 1 },
        );
        r0.emit(1.5, EventKind::Complete { req: 1 });
        t.take()
    }

    #[test]
    fn chrome_trace_is_valid_json_with_expected_rows() {
        let evs = sample_events();
        let doc = chrome_trace(&evs);
        let parsed = Json::parse(&doc.to_string()).expect("chrome trace must be valid JSON");
        let rows = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!rows.is_empty());
        let phases: Vec<&str> =
            rows.iter().filter_map(|r| r.get("ph").and_then(|p| p.as_str())).collect();
        for want in ["M", "i", "b", "n", "e", "X", "C"] {
            assert!(phases.contains(&want), "missing ph {want:?}");
        }
        // The complete event's ts must be start-of-batch (end - dur), in µs.
        let x = rows
            .iter()
            .find(|r| r.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .expect("one X row");
        assert!((x.get("ts").unwrap().as_f64().unwrap() - 0.1e6).abs() < 1e-6);
        assert!((x.get("dur").unwrap().as_f64().unwrap() - 0.3e6).abs() < 1e-6);
        // Replica 0 renders as pid 1; the fleet as pid 0.
        assert!(rows.iter().any(|r| r.get("pid").and_then(|p| p.as_f64()) == Some(0.0)));
        assert!(rows.iter().any(|r| r.get("pid").and_then(|p| p.as_f64()) == Some(1.0)));
    }

    #[test]
    fn jsonl_round_trips_every_event() {
        let evs = sample_events();
        let text = to_jsonl(&evs);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), evs.len());
        for (line, ev) in lines.iter().zip(&evs) {
            let v = Json::parse(line).expect("each JSONL line parses");
            assert_eq!(v.get("ev").unwrap().as_str(), Some(ev.kind.name()));
            assert!((v.get("t").unwrap().as_f64().unwrap() - ev.time).abs() < 1e-12);
        }
        // Chatty kinds are present in JSONL even though Chrome skips them.
        assert!(text.contains("\"ev\":\"prefill-chunk\""));
        assert!(text.contains("\"ev\":\"batch-start\""));
    }
}
