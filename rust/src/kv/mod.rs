//! Paged KV-cache manager (vLLM-style block allocator).
//!
//! Tracks per-request token counts in fixed-size blocks, exposes the live
//! usage ratio `KV_u` that drives Nexus's objective-mode switching
//! (paper §4.1.2), and models CPU swap / recompute (FastServe) and the
//! finite KV-transfer buffer of engine-level P/D disaggregation (§6.2.2).

use std::collections::HashMap;

/// Block-granular KV allocator for one GPU.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Tokens per block (vLLM default 16).
    pub block_tokens: usize,
    /// Total blocks available on the device.
    pub total_blocks: usize,
    used_blocks: usize,
    /// req id -> (tokens, blocks) resident on GPU.
    resident: HashMap<usize, (usize, usize)>,
    /// req id -> tokens swapped out to host memory.
    swapped: HashMap<usize, usize>,
    /// KV bytes per token for the model this cache serves.
    pub bytes_per_token: f64,
    /// Cumulative swap traffic (bytes) for metrics.
    pub swap_out_bytes: f64,
    pub swap_in_bytes: f64,
}

impl KvCache {
    pub fn new(total_blocks: usize, block_tokens: usize, bytes_per_token: f64) -> Self {
        assert!(total_blocks > 0 && block_tokens > 0);
        KvCache {
            block_tokens,
            total_blocks,
            used_blocks: 0,
            resident: HashMap::new(),
            swapped: HashMap::new(),
            bytes_per_token,
            swap_out_bytes: 0.0,
            swap_in_bytes: 0.0,
        }
    }

    /// Size the cache from GPU memory left after weights, reserving
    /// `activation_frac` of HBM for activations/workspace.
    pub fn for_gpu(
        hbm_bytes: f64,
        weights_bytes: f64,
        bytes_per_token: f64,
        activation_frac: f64,
        block_tokens: usize,
    ) -> Self {
        let avail = (hbm_bytes * (1.0 - activation_frac) - weights_bytes).max(0.0);
        let tokens = (avail / bytes_per_token) as usize;
        let blocks = (tokens / block_tokens).max(1);
        KvCache::new(blocks, block_tokens, bytes_per_token)
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        (tokens + self.block_tokens - 1) / self.block_tokens
    }

    /// Grow request `id` by `new_tokens`; fails (allocating nothing) if the
    /// device lacks free blocks.
    pub fn try_reserve(&mut self, id: usize, new_tokens: usize) -> bool {
        let (cur_tokens, cur_blocks) = self.resident.get(&id).copied().unwrap_or((0, 0));
        let need_blocks = self.blocks_for(cur_tokens + new_tokens);
        let extra = need_blocks.saturating_sub(cur_blocks);
        if self.used_blocks + extra > self.total_blocks {
            return false;
        }
        self.used_blocks += extra;
        self.resident.insert(id, (cur_tokens + new_tokens, need_blocks));
        true
    }

    /// Free every block of a finished request.
    pub fn release(&mut self, id: usize) {
        if let Some((_, blocks)) = self.resident.remove(&id) {
            self.used_blocks -= blocks;
        }
        self.swapped.remove(&id);
    }

    /// Live usage ratio `KV_u` ∈ [0, 1].
    pub fn usage(&self) -> f64 {
        self.used_blocks as f64 / self.total_blocks as f64
    }

    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.used_blocks
    }

    /// Resident token count of a request (0 if absent/swapped).
    pub fn tokens(&self, id: usize) -> usize {
        self.resident.get(&id).map(|&(t, _)| t).unwrap_or(0)
    }

    /// Total resident tokens across all requests.
    pub fn total_tokens(&self) -> usize {
        self.resident.values().map(|&(t, _)| t).sum()
    }

    pub fn resident_requests(&self) -> usize {
        self.resident.len()
    }

    /// Move a request's KV to host memory; returns bytes transferred.
    pub fn swap_out(&mut self, id: usize) -> f64 {
        if let Some((tokens, blocks)) = self.resident.remove(&id) {
            self.used_blocks -= blocks;
            self.swapped.insert(id, tokens);
            let bytes = tokens as f64 * self.bytes_per_token;
            self.swap_out_bytes += bytes;
            bytes
        } else {
            0.0
        }
    }

    /// Bring a swapped request back; returns bytes transferred, or `None`
    /// if there is no room (caller must evict or recompute).
    pub fn swap_in(&mut self, id: usize) -> Option<f64> {
        let tokens = *self.swapped.get(&id)?;
        let blocks = self.blocks_for(tokens);
        if self.used_blocks + blocks > self.total_blocks {
            return None;
        }
        self.swapped.remove(&id);
        self.used_blocks += blocks;
        self.resident.insert(id, (tokens, blocks));
        let bytes = tokens as f64 * self.bytes_per_token;
        self.swap_in_bytes += bytes;
        Some(bytes)
    }

    pub fn is_swapped(&self, id: usize) -> bool {
        self.swapped.contains_key(&id)
    }

    pub fn swapped_tokens(&self, id: usize) -> usize {
        self.swapped.get(&id).copied().unwrap_or(0)
    }

    /// Drop a request's KV entirely (eviction → recompute path).
    pub fn evict(&mut self, id: usize) -> usize {
        let tokens = self.tokens(id).max(self.swapped_tokens(id));
        self.release(id);
        tokens
    }
}

/// Finite staging buffer between a prefill engine and a decode engine
/// (vLLM-P/D). When full, new KV hand-offs force evictions on the prefill
/// side, which the decode side must recompute — the §6.2.2 failure mode.
///
/// Staged entries are keyed by request id: the decode side pulls each
/// request's KV individually (completion order follows the per-request
/// transfer timers, not buffer order), so [`TransferBuffer::pop`] is an
/// O(1) map removal rather than the historical O(n) scan + `Vec::remove`.
#[derive(Debug, Clone)]
pub struct TransferBuffer {
    pub capacity_bytes: f64,
    pub used_bytes: f64,
    /// req id -> staged bytes.
    staged: HashMap<usize, f64>,
    pub evictions: usize,
}

impl TransferBuffer {
    pub fn new(capacity_bytes: f64) -> Self {
        TransferBuffer {
            capacity_bytes,
            used_bytes: 0.0,
            staged: HashMap::new(),
            evictions: 0,
        }
    }

    /// Stage a finished prefill's KV. Returns `false` (and records an
    /// eviction) if the buffer cannot hold it.
    pub fn push(&mut self, id: usize, bytes: f64) -> bool {
        if self.used_bytes + bytes > self.capacity_bytes {
            self.evictions += 1;
            return false;
        }
        self.used_bytes += bytes;
        self.staged.insert(id, bytes);
        true
    }

    /// Remove a request's staged KV once the decode side pulled it.
    pub fn pop(&mut self, id: usize) -> Option<f64> {
        let bytes = self.staged.remove(&id)?;
        self.used_bytes -= bytes;
        Some(bytes)
    }

    /// Number of requests currently staged.
    pub fn len(&self) -> usize {
        self.staged.len()
    }

    pub fn is_empty(&self) -> bool {
        self.staged.is_empty()
    }

    pub fn occupancy(&self) -> f64 {
        if self.capacity_bytes <= 0.0 {
            1.0
        } else {
            self.used_bytes / self.capacity_bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(100, 16, 1000.0)
    }

    #[test]
    fn reserve_rounds_to_blocks() {
        let mut kv = cache();
        assert!(kv.try_reserve(1, 17)); // 2 blocks
        assert_eq!(kv.free_blocks(), 98);
        assert!(kv.try_reserve(1, 15)); // 32 tokens → still 2 blocks
        assert_eq!(kv.free_blocks(), 98);
        assert!(kv.try_reserve(1, 1)); // 33 tokens → 3 blocks
        assert_eq!(kv.free_blocks(), 97);
        assert_eq!(kv.tokens(1), 33);
    }

    #[test]
    fn reserve_fails_when_full_and_is_atomic() {
        let mut kv = KvCache::new(2, 16, 1.0);
        assert!(kv.try_reserve(1, 32));
        let before = kv.usage();
        assert!(!kv.try_reserve(2, 1));
        assert_eq!(kv.usage(), before, "failed reserve must not leak");
        kv.release(1);
        assert_eq!(kv.usage(), 0.0);
        assert!(kv.try_reserve(2, 1));
    }

    #[test]
    fn usage_tracks_blocks() {
        let mut kv = cache();
        kv.try_reserve(1, 160); // 10 blocks
        assert!((kv.usage() - 0.1).abs() < 1e-12);
        kv.try_reserve(2, 320);
        assert!((kv.usage() - 0.3).abs() < 1e-12);
        kv.release(1);
        assert!((kv.usage() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn swap_roundtrip() {
        let mut kv = cache();
        kv.try_reserve(1, 64);
        let out = kv.swap_out(1);
        assert_eq!(out, 64.0 * 1000.0);
        assert!(kv.is_swapped(1));
        assert_eq!(kv.tokens(1), 0);
        assert_eq!(kv.usage(), 0.0);
        let back = kv.swap_in(1).unwrap();
        assert_eq!(back, out);
        assert_eq!(kv.tokens(1), 64);
        assert!(!kv.is_swapped(1));
    }

    #[test]
    fn swap_in_fails_when_full() {
        let mut kv = KvCache::new(4, 16, 1.0);
        kv.try_reserve(1, 64); // all 4 blocks
        kv.swap_out(1);
        kv.try_reserve(2, 64);
        assert!(kv.swap_in(1).is_none());
        assert!(kv.is_swapped(1));
    }

    #[test]
    fn evict_clears_both_states() {
        let mut kv = cache();
        kv.try_reserve(1, 50);
        assert_eq!(kv.evict(1), 50);
        assert_eq!(kv.tokens(1), 0);
        kv.try_reserve(2, 30);
        kv.swap_out(2);
        assert_eq!(kv.evict(2), 30);
        assert!(!kv.is_swapped(2));
    }

    #[test]
    fn for_gpu_sizing() {
        // 48 GB HBM, 6 GB weights, 10% activations, 128 KB/token.
        let kv = KvCache::for_gpu(48e9, 6e9, 131072.0, 0.1, 16);
        let expect_tokens = ((48e9 * 0.9 - 6e9) / 131072.0) as usize;
        assert_eq!(kv.total_blocks, expect_tokens / 16);
    }

    #[test]
    fn transfer_buffer_eviction() {
        let mut tb = TransferBuffer::new(100.0);
        assert!(tb.push(1, 60.0));
        assert!(!tb.push(2, 60.0));
        assert_eq!(tb.evictions, 1);
        assert_eq!(tb.pop(1), Some(60.0));
        assert!(tb.push(2, 60.0));
        assert!((tb.occupancy() - 0.6).abs() < 1e-12);
        assert_eq!(tb.pop(99), None);
    }
}
