//! Analytical transformer model: per-operator FLOPs and memory traffic.
//!
//! Implements the operator cost accounting of paper §2.2–§2.3 for
//! decoder-only LLMs with GQA: Q/K/V projections (`O(n·d²)`), attention
//! (`O(n·L·d)` prefill / `O(L·d)` GEMV decode), output projection, and the
//! SwiGLU FFN (`O(n·d·d_ff)`). Dense-op memory traffic includes the *weight
//! read*, which is what makes small-batch decode memory-bound: every decode
//! iteration streams the full model weights plus the KV cache.
//!
//! These per-operator `(flops, bytes)` pairs are consumed by two layers:
//! the GPU simulator ([`crate::gpusim`]) executes them as kernels, and the
//! cost model ([`crate::costmodel`]) predicts their latency analytically
//! (paper Eq. 5–9).

use std::fmt;

/// Operator classes distinguished by the paper's breakdowns (Fig. 4b/5b/5c).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    /// Q/K/V linear projections (compute-bound).
    Qkv,
    /// Prefill self-attention (matrix-matrix, compute-bound).
    AttnPrefill,
    /// Decode self-attention (GEMV over the KV cache, memory-bound).
    AttnDecode,
    /// Attention output projection (compute-bound).
    AttnLinear,
    /// Feed-forward network (most FLOP-intensive dense op).
    Ffn,
    /// LM head / logits projection.
    LmHead,
    /// Inter-GPU collective (tensor-parallel allreduce).
    Comm,
}

pub const DENSE_CLASSES: [OpClass; 4] =
    [OpClass::Qkv, OpClass::AttnLinear, OpClass::Ffn, OpClass::LmHead];

/// Flash-attention q-tile height: each tile of query rows re-streams the
/// full attended KV from HBM (SRAM can't hold it), so prefill-attention
/// memory traffic is `ceil(n / FLASH_QTILE) × kv_bytes`.
pub const FLASH_QTILE: usize = 64;

/// Paged-KV gather inefficiency: the KV cache is read in 16-token blocks
/// scattered across HBM (PagedAttention), so effective DRAM traffic per
/// useful KV byte is ~2× a contiguous stream. Weights stream contiguously
/// (factor 1). This is what makes attention the high-pressure bandwidth
/// window of §3.3.
pub const KV_GATHER_OVERHEAD: f64 = 2.0;

impl OpClass {
    pub fn name(&self) -> &'static str {
        match self {
            OpClass::Qkv => "kqv_linear",
            OpClass::AttnPrefill => "prefill_attn",
            OpClass::AttnDecode => "decode_attn",
            OpClass::AttnLinear => "attn_linear",
            OpClass::Ffn => "ffn",
            OpClass::LmHead => "lm_head",
            OpClass::Comm => "comm",
        }
    }

    pub fn all() -> &'static [OpClass] {
        &[
            OpClass::Qkv,
            OpClass::AttnPrefill,
            OpClass::AttnDecode,
            OpClass::AttnLinear,
            OpClass::Ffn,
            OpClass::LmHead,
            OpClass::Comm,
        ]
    }
}

impl fmt::Display for OpClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One schedulable unit of GPU work: aggregate over all layers of a model
/// for one operator class within one phase iteration.
#[derive(Debug, Clone, Copy)]
pub struct OpWork {
    pub class: OpClass,
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes moved to/from HBM (weights + activations + KV traffic).
    pub bytes: f64,
}

/// Decoder-only transformer architecture description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub layers: usize,
    /// Hidden size d.
    pub d: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for MHA.
    pub kv_heads: usize,
    /// FFN inner size (SwiGLU: three d×d_ff matrices).
    pub d_ff: usize,
    pub vocab: usize,
    /// Bytes per element (2 = fp16/bf16).
    pub dtype_bytes: usize,
    /// Tensor-parallel degree this config is sharded over.
    pub tp: usize,
}

impl ModelConfig {
    /// Qwen2.5-3B-like (single-GPU experiments, LDC + ArXiv workloads).
    pub fn qwen3b() -> Self {
        ModelConfig {
            name: "qwen2.5-3b",
            layers: 36,
            d: 2048,
            heads: 16,
            kv_heads: 2,
            d_ff: 11008,
            vocab: 151936,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// Llama3.1-8B-like (single-GPU Mixed workload).
    pub fn llama8b() -> Self {
        ModelConfig {
            name: "llama3.1-8b",
            layers: 32,
            d: 4096,
            heads: 32,
            kv_heads: 8,
            d_ff: 14336,
            vocab: 128256,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// Qwen2.5-14B-like (dual-GPU TP=2 experiments).
    pub fn qwen14b() -> Self {
        ModelConfig {
            name: "qwen2.5-14b",
            layers: 48,
            d: 5120,
            heads: 40,
            kv_heads: 8,
            d_ff: 13824,
            vocab: 152064,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// ~20M-param model actually executed on the PJRT CPU runtime
    /// (matches `python/compile/model.py`).
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny-20m",
            layers: 4,
            d: 256,
            heads: 4,
            kv_heads: 4,
            d_ff: 1024,
            vocab: 512,
            dtype_bytes: 4, // f32 on CPU
            tp: 1,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "qwen3b" | "qwen2.5-3b" => Some(Self::qwen3b()),
            "llama8b" | "llama3.1-8b" => Some(Self::llama8b()),
            "qwen14b" | "qwen2.5-14b" => Some(Self::qwen14b()),
            "tiny" | "tiny-20m" => Some(Self::tiny()),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    /// KV projection width (kv_heads × head_dim).
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    /// Shard over `ways` GPUs (tensor parallelism). Heads and FFN split;
    /// per-GPU op costs shrink accordingly, and [`Self::comm_bytes`] becomes
    /// non-zero.
    pub fn with_tp(&self, ways: usize) -> Self {
        assert!(ways >= 1 && self.heads % ways == 0 && self.kv_heads.max(ways) % ways == 0);
        let mut c = *self;
        c.tp = ways;
        c
    }

    /// Approximate parameter count.
    pub fn params(&self) -> f64 {
        let d = self.d as f64;
        let attn = d * d // Wq
            + 2.0 * d * self.kv_dim() as f64 // Wk, Wv
            + d * d; // Wo
        let ffn = 3.0 * d * self.d_ff as f64; // SwiGLU: gate, up, down
        self.layers as f64 * (attn + ffn) + 2.0 * d * self.vocab as f64
    }

    /// Total weight bytes (whole model, before TP sharding).
    pub fn weights_bytes(&self) -> f64 {
        self.params() * self.dtype_bytes as f64
    }

    /// KV-cache bytes per token (both K and V, all layers, GQA width).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (self.layers * 2 * self.kv_dim() * self.dtype_bytes) as f64
    }

    /// Per-layer allreduce traffic for `n` tokens under TP (two collectives
    /// per layer: post-attention and post-FFN), in bytes *per GPU*.
    pub fn comm_bytes(&self, n_tokens: f64) -> f64 {
        if self.tp <= 1 {
            return 0.0;
        }
        // Ring allreduce moves ~2·(tp-1)/tp of the buffer per GPU, twice per layer.
        let buf = n_tokens * self.d as f64 * self.dtype_bytes as f64;
        let factor = 2.0 * (self.tp as f64 - 1.0) / self.tp as f64;
        2.0 * self.layers as f64 * buf * factor
    }

    fn shard(&self, x: f64) -> f64 {
        x / self.tp as f64
    }

    /// Operator work for a *prefill* iteration processing `n_tokens` new
    /// tokens whose attention spans `kv_tokens` total cached+current tokens
    /// (summed over the requests in the batch: Σᵢ nᵢ·Lᵢ is passed
    /// pre-aggregated as `attn_token_pairs`).
    ///
    /// `include_lm_head`: only the chunk that finishes a prompt computes
    /// logits (one token per finishing request).
    pub fn prefill_ops(
        &self,
        n_tokens: usize,
        attn_token_pairs: f64,
        kv_read_tokens: f64,
        finishing: usize,
    ) -> Vec<OpWork> {
        let mut ops = Vec::with_capacity(6);
        self.prefill_ops_into(n_tokens, attn_token_pairs, kv_read_tokens, finishing, &mut ops);
        ops
    }

    /// Allocation-free [`Self::prefill_ops`]: *appends* the operator list to
    /// `ops` (callers clear their reused buffer first; engines exploit the
    /// append contract to compose decode + prefill + comm work into one
    /// buffer per iteration without allocating — §Perf).
    pub fn prefill_ops_into(
        &self,
        n_tokens: usize,
        attn_token_pairs: f64,
        kv_read_tokens: f64,
        finishing: usize,
        ops: &mut Vec<OpWork>,
    ) {
        let n = n_tokens as f64;
        let d = self.d as f64;
        let dff = self.d_ff as f64;
        let kvd = self.kv_dim() as f64;
        let l = self.layers as f64;
        let b = self.dtype_bytes as f64;

        // Q/K/V projection: n·d·(d + 2·kv_dim) MACs per layer.
        let qkv_flops = 2.0 * n * d * (d + 2.0 * kvd) * l;
        let qkv_w = (d * d + 2.0 * d * kvd) * b * l;
        let qkv_act = 2.0 * n * d * b * l;
        ops.push(OpWork {
            class: OpClass::Qkv,
            flops: self.shard(qkv_flops),
            bytes: self.shard(qkv_w) + qkv_act,
        });

        // Prefill attention: QKᵀ + AV = 4·Σ nᵢLᵢ·d flops per layer. Memory
        // traffic follows the flash-attention schedule: each q-tile
        // (FLASH_QTILE rows) re-streams the full attended KV through
        // SRAM/VMEM, so HBM reads scale with ceil(n / tile) — this KV
        // re-streaming is what makes long-context prefill attention a real
        // bandwidth consumer (the §3.3 contention source).
        let attn_flops = 4.0 * attn_token_pairs * d * l;
        let qtiles = ((n_tokens + FLASH_QTILE - 1) / FLASH_QTILE).max(1) as f64;
        let kv_bytes = kv_read_tokens * self.kv_bytes_per_token() * qtiles * KV_GATHER_OVERHEAD;
        ops.push(OpWork {
            class: OpClass::AttnPrefill,
            flops: self.shard(attn_flops),
            bytes: self.shard(kv_bytes) + 2.0 * n * d * b * l,
        });

        // Output projection.
        let proj_flops = 2.0 * n * d * d * l;
        ops.push(OpWork {
            class: OpClass::AttnLinear,
            flops: self.shard(proj_flops),
            bytes: self.shard(d * d * b * l) + 2.0 * n * d * b * l,
        });

        // SwiGLU FFN: 3 matmuls of d×d_ff.
        let ffn_flops = 3.0 * 2.0 * n * d * dff * l;
        ops.push(OpWork {
            class: OpClass::Ffn,
            flops: self.shard(ffn_flops),
            bytes: self.shard(3.0 * d * dff * b * l) + 2.0 * n * d * b * l,
        });

        if finishing > 0 {
            let f = finishing as f64;
            ops.push(OpWork {
                class: OpClass::LmHead,
                flops: self.shard(2.0 * f * d * self.vocab as f64),
                bytes: self.shard(d * self.vocab as f64 * b) + f * d * b,
            });
        }

        let comm = self.comm_bytes(n);
        if comm > 0.0 {
            ops.push(OpWork {
                class: OpClass::Comm,
                flops: 0.0,
                bytes: comm,
            });
        }
    }

    /// Operator work for a *decode* iteration over a batch of `batch`
    /// requests whose cached contexts sum to `kv_tokens`.
    pub fn decode_ops(&self, batch: usize, kv_tokens: f64) -> Vec<OpWork> {
        let mut ops = Vec::with_capacity(6);
        self.decode_ops_into(batch, kv_tokens, &mut ops);
        ops
    }

    /// Allocation-free [`Self::decode_ops`]: *appends* the operator list to
    /// `ops` (see [`Self::prefill_ops_into`] for the append contract).
    pub fn decode_ops_into(&self, batch: usize, kv_tokens: f64, ops: &mut Vec<OpWork>) {
        let n = batch as f64;
        let d = self.d as f64;
        let dff = self.d_ff as f64;
        let kvd = self.kv_dim() as f64;
        let l = self.layers as f64;
        let b = self.dtype_bytes as f64;

        let qkv_flops = 2.0 * n * d * (d + 2.0 * kvd) * l;
        ops.push(OpWork {
            class: OpClass::Qkv,
            flops: self.shard(qkv_flops),
            bytes: self.shard((d * d + 2.0 * d * kvd) * b * l) + 2.0 * n * d * b * l,
        });

        // Decode attention: GEMV per request, 4·Lᵢ·d flops; streams the whole
        // KV cache of the batch once per layer (already summed in kv_tokens),
        // through the paged-block gather.
        let attn_flops = 4.0 * kv_tokens * d * l;
        ops.push(OpWork {
            class: OpClass::AttnDecode,
            flops: self.shard(attn_flops),
            bytes: self.shard(kv_tokens * self.kv_bytes_per_token() * KV_GATHER_OVERHEAD)
                + 2.0 * n * d * b * l,
        });

        let proj_flops = 2.0 * n * d * d * l;
        ops.push(OpWork {
            class: OpClass::AttnLinear,
            flops: self.shard(proj_flops),
            bytes: self.shard(d * d * b * l) + 2.0 * n * d * b * l,
        });

        let ffn_flops = 3.0 * 2.0 * n * d * dff * l;
        ops.push(OpWork {
            class: OpClass::Ffn,
            flops: self.shard(ffn_flops),
            bytes: self.shard(3.0 * d * dff * b * l) + 2.0 * n * d * b * l,
        });

        ops.push(OpWork {
            class: OpClass::LmHead,
            flops: self.shard(2.0 * n * d * self.vocab as f64),
            bytes: self.shard(d * self.vocab as f64 * b) + n * d * b,
        });

        let comm = self.comm_bytes(n);
        if comm > 0.0 {
            ops.push(OpWork {
                class: OpClass::Comm,
                flops: 0.0,
                bytes: comm,
            });
        }
    }

    /// Total FLOPs of a prefill iteration (for roofline sanity checks).
    pub fn prefill_flops(&self, n_tokens: usize, attn_token_pairs: f64) -> f64 {
        self.prefill_ops(n_tokens, attn_token_pairs, 0.0, 0)
            .iter()
            .map(|o| o.flops)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        // Within 35% of nominal sizes (embedding/norm details ignored).
        let q3 = ModelConfig::qwen3b().params();
        assert!((2.0e9..4.5e9).contains(&q3), "qwen3b params {q3:.2e}");
        let l8 = ModelConfig::llama8b().params();
        assert!((6.0e9..9.5e9).contains(&l8), "llama8b params {l8:.2e}");
        let q14 = ModelConfig::qwen14b().params();
        assert!((11.0e9..17.0e9).contains(&q14), "qwen14b params {q14:.2e}");
        let t = ModelConfig::tiny().params();
        assert!((2.0e6..30.0e6).contains(&t), "tiny params {t:.2e}");
    }

    #[test]
    fn kv_bytes_per_token_gqa() {
        let c = ModelConfig::llama8b();
        // 32 layers × 2 (K,V) × 8 kv_heads × 128 head_dim × 2 bytes = 131072
        assert_eq!(c.kv_bytes_per_token(), 131072.0);
    }

    #[test]
    fn prefill_flops_track_2pn() {
        // Dense prefill FLOPs ≈ 2 · params · n for short contexts.
        let c = ModelConfig::llama8b();
        let n = 512usize;
        let dense: f64 = c
            .prefill_ops(n, 0.0, 0.0, 0)
            .iter()
            .filter(|o| o.class != OpClass::AttnPrefill)
            .map(|o| o.flops)
            .sum();
        let approx = 2.0 * (c.params() - 2.0 * (c.d * c.vocab) as f64) * n as f64;
        let rel = (dense - approx).abs() / approx;
        assert!(rel < 0.05, "dense={dense:.3e} approx={approx:.3e} rel={rel}");
    }

    #[test]
    fn decode_is_memory_bound_dense() {
        // At batch 1 the dense ops' arithmetic intensity must be tiny
        // (weight-read dominated) — the §2.3 observation.
        let c = ModelConfig::qwen3b();
        for op in c.decode_ops(1, 4096.0) {
            if DENSE_CLASSES.contains(&op.class) {
                let intensity = op.flops / op.bytes;
                assert!(
                    intensity < 4.0,
                    "{}: intensity {intensity} should be memory-bound",
                    op.class
                );
            }
        }
    }

    #[test]
    fn prefill_attention_scales_with_pairs() {
        let c = ModelConfig::qwen3b();
        let a = c.prefill_ops(256, 256.0 * 1000.0, 1000.0, 0);
        let b = c.prefill_ops(256, 256.0 * 2000.0, 2000.0, 0);
        let fa = a.iter().find(|o| o.class == OpClass::AttnPrefill).unwrap();
        let fb = b.iter().find(|o| o.class == OpClass::AttnPrefill).unwrap();
        assert!((fb.flops / fa.flops - 2.0).abs() < 1e-9);
        assert!(fb.bytes > fa.bytes);
    }

    #[test]
    fn tp_shards_flops_and_adds_comm() {
        let c = ModelConfig::qwen14b();
        let c2 = c.with_tp(2);
        let ops1 = c.decode_ops(8, 8.0 * 2048.0);
        let ops2 = c2.decode_ops(8, 8.0 * 2048.0);
        let f1: f64 = ops1.iter().map(|o| o.flops).sum();
        let f2: f64 = ops2.iter().map(|o| o.flops).sum();
        assert!((f2 / f1 - 0.5).abs() < 1e-9, "TP2 halves per-GPU flops");
        assert!(ops2.iter().any(|o| o.class == OpClass::Comm));
        assert!(!ops1.iter().any(|o| o.class == OpClass::Comm));
    }

    #[test]
    fn lm_head_only_when_finishing() {
        let c = ModelConfig::qwen3b();
        assert!(!c
            .prefill_ops(128, 128.0 * 128.0, 128.0, 0)
            .iter()
            .any(|o| o.class == OpClass::LmHead));
        assert!(c
            .prefill_ops(128, 128.0 * 128.0, 128.0, 2)
            .iter()
            .any(|o| o.class == OpClass::LmHead));
    }

    #[test]
    fn ops_into_appends_and_matches_allocating_api() {
        let c = ModelConfig::qwen3b();
        let mut buf = vec![OpWork { class: OpClass::Comm, flops: 0.0, bytes: 1.0 }];
        c.decode_ops_into(8, 8.0 * 1024.0, &mut buf);
        c.prefill_ops_into(256, 256.0 * 900.0, 900.0, 1, &mut buf);
        let want: Vec<OpWork> = c
            .decode_ops(8, 8.0 * 1024.0)
            .into_iter()
            .chain(c.prefill_ops(256, 256.0 * 900.0, 900.0, 1))
            .collect();
        assert_eq!(buf.len(), 1 + want.len(), "into variants must append");
        for (got, want) in buf[1..].iter().zip(&want) {
            assert_eq!(got.class, want.class);
            assert_eq!(got.flops, want.flops);
            assert_eq!(got.bytes, want.bytes);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["qwen3b", "llama8b", "qwen14b", "tiny"] {
            assert!(ModelConfig::by_name(n).is_some());
        }
        assert!(ModelConfig::by_name("gpt5").is_none());
    }
}
