//! Sharded virtual-time execution of the fleet loop (§Perf).
//!
//! [`Cluster::run_parallel`] partitions the replicas of a fleet across
//! worker threads and advances each shard independently between
//! *interaction boundaries*, synchronizing only where replicas can
//! actually affect each other. The result is digest-identical to the
//! sequential [`Cluster::run`] for **any** thread count, any window
//! size, and any work-stealing configuration (pinned by
//! `tests/golden_digest.rs` and `tests/prop_cluster.rs`).
//!
//! ## Why sharding is exact, not approximate
//!
//! The fleet couples replicas in exactly three places: routing (an arrival
//! reads every active replica's load), autoscaler ticks (a decision reads
//! fleet-wide state and may spawn/drain replicas), and the fleet counters
//! derived from both. Between consecutive boundaries drawn from those
//! interactions, every replica evolves independently — the module-level
//! *equivalence* invariant (a replica not stepped at a foreign event
//! cannot change observable state) means stepping it only at its own
//! internal event times reproduces the sequential trajectory bit for bit.
//!
//! ## Protocol
//!
//! The caller's thread acts as the coordinator; `threads` persistent
//! workers (spawned under [`std::thread::scope`], talking over
//! [`std::sync::mpsc`] channels) own the replica shards. Each round the
//! coordinator broadcasts one [`RoundCmd`] and collects one [`Report`] per
//! worker:
//!
//! 1. **drain** directives from a scale-down decided at the previous
//!    boundary (empty victims retire immediately, at the decision time);
//! 2. **spawn** directives (initial fleet and autoscaler growth);
//! 3. a **boundary step** at time `B`: injections in arrival order plus
//!    every owned replica whose next event is due at `B`, stepped in id
//!    order — exactly the step set of the sequential loop at `B`;
//! 4. a **prime** step giving freshly spawned replicas their first step at
//!    the fleet's true next event time (which the coordinator computes
//!    from the reported per-shard key minima — see `prime` below);
//! 5. an **advance** phase: each owned in-service replica processes its
//!    own internal events strictly below the round's `horizon`, at their
//!    exact times.
//!
//! Routing and autoscaling stay on the coordinator, which mirrors the
//! sequential loop's view rebuilds from the per-shard load reports (merged
//! in replica-id order, so float reductions like the tick's `mean_kv` sum
//! in the identical order). Autoscaler ticks take two rendezvous — a
//! step-only round at `B`, then the decision — because the decision needs
//! post-step state; plain arrival boundaries fuse the boundary step and
//! the next advance into a single round.
//!
//! ## The synchronization window (`--window`)
//!
//! `window > 0` caps how far (in virtual seconds) a shard may run ahead of
//! the last boundary before re-synchronizing; `0` means "free-run to the
//! next interaction". Because boundaries are derived from interactions
//! only, a window-capped round performs no routing, no tick, and no step —
//! it merely splits the advance phase — so results are invariant to the
//! window size *by construction* (property-tested). The cap exists to
//! bound shard run-ahead (and worst-case report staleness) when embedding
//! the loop in a live system; simulation output does not depend on it.
//!
//! ## Deliberate differences from [`Cluster::run`]
//!
//! * `ClusterMetrics::events` counts boundary rounds plus per-shard
//!   internal steps (the sequential loop counts iterations); it is
//!   excluded from [`ClusterMetrics::digest`].
//! * `replica_seconds` is computed analytically (Σ over replicas of
//!   `end − started_at`), which is thread- and window-invariant but can
//!   differ from the sequential running accumulation by float-summation
//!   noise (≪ 1e-6; also excluded from the digest).
//! * `record_event_times` is not supported (`event_times` stays empty) —
//!   there is no single global event sequence to record.
//! * Periodic trace *sampling* is not supported (no `Sample` events are
//!   emitted): a mid-window sample would need fleet-global state that
//!   shards only materialize at boundaries. All other trace events are
//!   emitted at their exact virtual times into per-shard sinks and merged
//!   into the canonical `(time, replica)` order at the end of the run —
//!   compare traces with [`crate::trace::canonical_order`], not emission
//!   order.
//!
//! ## Work stealing (`--steal-threshold`, `--balance-interval`)
//!
//! Static sharding leaves threads idle under skew: a session-affinity hot
//! spot or autoscaler churn concentrates stepping work on one shard while
//! the others wait at every rendezvous. With a [`StealCfg`], the
//! coordinator keeps deterministic per-shard load accounts — engine steps
//! executed per replica per round, reported alongside the load views and
//! derived *only* from simulation state, never wall clock — and every
//! `balance_interval` virtual seconds runs [`plan_rebalance`]: while the
//! busiest shard exceeds `threshold ×` the laziest, move the largest
//! replica that fits inside half the gap. Migrations apply at rendezvous
//! boundaries over two rounds (the old owner evicts after fully advancing
//! the replica to the horizon; the new owner adopts it before any stepping
//! in the next round), so the replica never misses or repeats an event.
//! Autoscaler-spawned replicas are routed to the lightest shard instead of
//! `id % threads`. Each migration emits
//! [`EventKind::ShardRebalance`](crate::trace::EventKind::ShardRebalance).
//!
//! Rebalancing cannot change results: *which thread* steps a replica is
//! invisible to the simulation (replicas interact only through the
//! coordinator's boundary-time routing and tick observations, which are
//! shard-agnostic), so the digest is identical with stealing on, off, or
//! any threshold/interval — the scheduling metadata (`rebalances`,
//! `shard_steps`) is excluded from [`ClusterMetrics::digest`] and the
//! `ShardRebalance` events are the only trace difference.
//!
//! ## Rendezvous batching
//!
//! With stealing enabled (and tracing off), arrival boundaries whose
//! routing is *blind* — provably independent of post-boundary load, i.e.
//! round-robin cursor arithmetic and session-affinity sticky hits (see
//! [`Router::blind_probe`]) — are batched into a single worker
//! round-trip: one command carries several step times plus their
//! injections, and each worker interleaves advance/inject/step locally at
//! the exact virtual times. Load-aware decisions (JSQ, least-KV, affinity
//! misses) still synchronize per arrival instant, as do autoscaler ticks
//! and balance checks. This cuts coordination overhead precisely where
//! skewed workloads concentrate it: dense same-session arrival trains.
//!
//! The tick-at-an-internal-event edge is the one measure-zero caveat: the
//! sequential loop evaluates `t + 1e-12 >= tick` at internal replica
//! events too, so an internal event landing within 1e-12 *before* a tick
//! fires that tick infinitesimally early, whereas here ticks fire at
//! their boundary time. Arrival and tick times are sums of continuous
//! random variates, so an exact collision has probability zero; every
//! differential test seed is pinned.

use super::autoscaler::{Autoscaler, FleetObs};
use super::prefixcache::{PrefixState, PrefixStats};
use super::replica::{Replica, ReplicaState};
use super::router::{ReplicaView, Router, TenantGate};
use super::{Cluster, ClusterCfg, ClusterMetrics, ReplicaStats, ScaleEvent};
use crate::costmodel::calibrate;
use crate::engine::common::ArrivalFeed;
use crate::engine::Engine;
use crate::metrics::{Histogram, RunMetrics};
use crate::trace::{merge_streams, EventKind, TraceEvent, Tracer};
use crate::util::f64_total_key;
use crate::workload::Request;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Arrival source for the fleet loops: a time-sorted sequence of requests
/// consumed boundary by boundary. Implemented by [`SliceArrivals`] (a
/// materialized trace) and [`StreamArrivals`] (any request iterator, e.g.
/// [`crate::workload::generate_iter`], so a 10⁶-request open-loop workload
/// never exists in memory at once).
pub trait Arrivals {
    /// Arrival time of the next request, if any. `&mut` so streaming
    /// sources can pull their look-ahead slot.
    fn peek_time(&mut self) -> Option<f64>;
    /// Replace `out` with every request arriving at or before `t`, in
    /// arrival order.
    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>);
    /// True once no further requests will arrive.
    fn exhausted(&mut self) -> bool;
    /// Requests offered so far — the timeout baseline. For a slice this is
    /// its full length; for a stream it counts requests actually pulled
    /// (a stream cut off by `max_virtual_time` never materializes its
    /// tail, so unpulled requests are not counted as timeouts).
    fn offered(&self) -> usize;
}

/// [`Arrivals`] over a materialized, time-sorted trace.
pub struct SliceArrivals<'a> {
    feed: ArrivalFeed<'a>,
    total: usize,
}

impl<'a> SliceArrivals<'a> {
    pub fn new(trace: &'a [Request]) -> Self {
        SliceArrivals { feed: ArrivalFeed::new(trace), total: trace.len() }
    }
}

impl Arrivals for SliceArrivals<'_> {
    fn peek_time(&mut self) -> Option<f64> {
        self.feed.peek_time()
    }

    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>) {
        out.clear();
        out.extend_from_slice(self.feed.pop_until(t));
    }

    fn exhausted(&mut self) -> bool {
        self.feed.exhausted()
    }

    fn offered(&self) -> usize {
        self.total
    }
}

/// [`Arrivals`] over any time-sorted request iterator (one-request
/// look-ahead buffer; O(1) memory regardless of workload length).
pub struct StreamArrivals<I: Iterator<Item = Request>> {
    it: I,
    peeked: Option<Request>,
    pulled: usize,
}

impl<I: Iterator<Item = Request>> StreamArrivals<I> {
    pub fn new(it: I) -> Self {
        StreamArrivals { it, peeked: None, pulled: 0 }
    }

    fn fill(&mut self) {
        if self.peeked.is_none() {
            self.peeked = self.it.next();
            if self.peeked.is_some() {
                self.pulled += 1;
            }
        }
    }
}

impl<I: Iterator<Item = Request>> Arrivals for StreamArrivals<I> {
    fn peek_time(&mut self) -> Option<f64> {
        self.fill();
        self.peeked.map(|r| r.arrival)
    }

    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>) {
        out.clear();
        loop {
            self.fill();
            match self.peeked {
                Some(r) if r.arrival <= t => {
                    debug_assert!(out.last().map_or(true, |p| p.arrival <= r.arrival));
                    out.push(r);
                    self.peeked = None;
                }
                _ => break,
            }
        }
    }

    fn exhausted(&mut self) -> bool {
        self.fill();
        self.peeked.is_none()
    }

    fn offered(&self) -> usize {
        self.pulled
    }
}

/// Work-stealing configuration for the sharded loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StealCfg {
    /// Rebalance when the busiest shard's windowed step count exceeds
    /// `threshold ×` the laziest shard's (must be > 1).
    pub threshold: f64,
    /// Virtual seconds between balance checks.
    pub interval: f64,
}

impl Default for StealCfg {
    fn default() -> Self {
        StealCfg { threshold: 1.5, interval: 1.0 }
    }
}

/// Full configuration for [`Cluster::run_parallel_cfg`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParallelCfg {
    /// Worker threads (≥ 1).
    pub threads: usize,
    /// Synchronization window in virtual seconds (0 = free-run to the next
    /// interaction) — bounds shard run-ahead, never changes results.
    pub window: f64,
    /// Work stealing; `None` = static sharding (`id % threads`).
    pub steal: Option<StealCfg>,
}

impl Default for ParallelCfg {
    fn default() -> Self {
        ParallelCfg { threads: 1, window: 0.0, steal: None }
    }
}

impl ParallelCfg {
    pub fn new(threads: usize) -> Self {
        ParallelCfg { threads, ..Self::default() }
    }
}

/// Plan shard-to-shard replica migrations for one balance check.
/// Deterministic and side-effect-free with respect to the simulation: it
/// reads only the windowed load accounts.
///
/// * `shard_load` — windowed engine steps per shard; **mutated in place**
///   to reflect the hypothetical post-move loads (callers reset the window
///   right after a check, so the mutation costs nothing).
/// * `candidates` — `(replica id, windowed steps)` for every currently
///   routable replica.
/// * `owner[id]` — the shard currently owning each replica.
/// * `excluded` — ids that must not move (pending drains, in-transit).
/// * `moves` — cleared, then appended with `(id, from, to)`.
///
/// Greedy loop: take the busiest and laziest shards (ties toward the lower
/// index); stop when `busiest < threshold × max(laziest, 1)`; otherwise
/// move the largest-load candidate on the busiest shard whose load `l`
/// satisfies `0 < 2·l ≤ gap` (so a move never overshoots the balance
/// point; ties toward the smaller id), apply it hypothetically, repeat.
/// Bounded by one move per candidate, and in practice by the gap
/// shrinking monotonically.
pub fn plan_rebalance(
    shard_load: &mut [u64],
    candidates: &[(usize, u64)],
    owner: &[usize],
    threshold: f64,
    excluded: &[usize],
    moves: &mut Vec<(usize, usize, usize)>,
) {
    moves.clear();
    if shard_load.len() < 2 {
        return;
    }
    loop {
        let mut hi = 0usize;
        let mut lo = 0usize;
        for (w, &l) in shard_load.iter().enumerate() {
            if l > shard_load[hi] {
                hi = w;
            }
            if l < shard_load[lo] {
                lo = w;
            }
        }
        if (shard_load[hi] as f64) < threshold * (shard_load[lo].max(1) as f64) {
            return;
        }
        let gap = shard_load[hi] - shard_load[lo];
        let mut pick: Option<(usize, u64)> = None;
        for &(id, l) in candidates {
            if id >= owner.len()
                || owner[id] != hi
                || l == 0
                || 2 * l > gap
                || excluded.contains(&id)
                || moves.iter().any(|&(m, _, _)| m == id)
            {
                continue;
            }
            // Largest load first; ties toward the smaller id.
            if pick.map_or(true, |(pid, pl)| l > pl || (l == pl && id < pid)) {
                pick = Some((id, l));
            }
        }
        let Some((id, l)) = pick else { return };
        shard_load[hi] -= l;
        shard_load[lo] += l;
        moves.push((id, hi, lo));
        if moves.len() >= candidates.len() {
            return;
        }
    }
}

/// One coordinator→worker round (phases run in the listed order). The
/// struct round-trips: workers hand it back inside the [`Report`]
/// (`spent`), so every `Vec` here is a recycled buffer and steady-state
/// rounds allocate nothing on either side (§Perf).
#[derive(Default)]
struct RoundCmd {
    /// Migrated replicas this shard now owns (adopted before anything
    /// else, so every later phase sees them as local).
    adopts: Vec<Replica>,
    /// Replica ids to hand back to the coordinator at the end of the
    /// round, after they have been fully advanced to the horizon.
    evicts: Vec<usize>,
    /// Replica ids to drain (scale-down victims), at `drain_t`. Empties
    /// retire immediately at `drain_t`, as in the sequential retire scan.
    drains: Vec<usize>,
    drain_t: f64,
    /// Replicas to create: `(id, started_at)`.
    spawns: Vec<(usize, f64)>,
    /// Boundary step times, strictly increasing (empty = no boundary step
    /// this round; > 1 entry = a rendezvous batch of blind-routed arrival
    /// instants). Workers advance each replica through its own events
    /// strictly below each time before injecting/stepping at it.
    step_times: Vec<f64>,
    /// `(step index, target id, request, effective prompt)` in arrival
    /// order; the target steps at `step_times[index]`. The effective
    /// prompt is the coordinator's prefix-tier resolution (`u32::MAX` =
    /// no tier — the engine keeps its own prefix model).
    injections: Vec<(u32, usize, Request, u32)>,
    /// Primed replicas whose first step coincides with `step_times[0]`.
    step_primed: Vec<usize>,
    /// First-step time for `prime_ids` (`NaN` = no prime this round),
    /// strictly inside this round's advance range.
    prime_t: f64,
    prime_ids: Vec<usize>,
    /// Advance owned replicas through internal events `< horizon`
    /// (and `≤ max_virtual_time`); `∞` = drain everything schedulable.
    horizon: f64,
    /// Report buffers the worker fills (double-buffered through `spent`):
    /// load views of owned *active* replicas and `(id, engine steps this
    /// round)` of owned in-service replicas, both in id order.
    views_buf: Vec<ReplicaView>,
    loads_buf: Vec<(u32, u32)>,
    /// Tenant label of every request completed this round (WFQ feedback;
    /// filled only when `ClusterCfg::wfq` is set). The coordinator drains
    /// it into the gate's in-flight accounts — a commutative count, so the
    /// shard merge order cannot affect admission decisions.
    dones_buf: Vec<u16>,
}

impl RoundCmd {
    /// Clear every buffer (capacity retained) so the struct can be
    /// refilled for the next round.
    fn reset(&mut self) {
        self.adopts.clear();
        self.evicts.clear();
        self.drains.clear();
        self.spawns.clear();
        self.step_times.clear();
        self.injections.clear();
        self.step_primed.clear();
        self.prime_ids.clear();
        self.views_buf.clear();
        self.loads_buf.clear();
        self.dones_buf.clear();
        self.drain_t = 0.0;
        self.prime_t = f64::NAN;
        self.horizon = 0.0;
    }
}

enum Cmd {
    Round(RoundCmd),
    /// End of run: sync survivors to `last_t`, hand everything back.
    Finish { last_t: f64 },
}

/// One worker→coordinator round report.
struct Report {
    /// Replicas evicted this round (fully advanced to the horizon; their
    /// parting views/loads are still in `spent`), in `evicts` order.
    evicted: Vec<Replica>,
    /// Minimum next-event time over owned in-service replicas (`NaN` =
    /// none) — unfiltered, mirroring the sequential loop's live keys.
    key_min: f64,
    /// Requests completed by this round's steps.
    completed: usize,
    /// Engine `step()` calls performed this round.
    steps: usize,
    /// Latest event time processed in the advance phase (`-∞` = none).
    max_t: f64,
    /// The consumed command, carrying the filled `views_buf`/`loads_buf`
    /// back for recycling.
    spent: RoundCmd,
}

/// Everything a worker hands back at [`Cmd::Finish`].
struct WorkerOut {
    /// The shard's replicas (all retired by now), id order.
    replicas: Vec<Replica>,
    /// Mid-run retirements: `(retire time, id, metrics)`.
    done: Vec<(f64, usize, RunMetrics)>,
    /// End-of-run survivors: `(id, metrics)`, id order.
    survivors: Vec<(usize, RunMetrics)>,
    /// The shard tracer's event stream.
    events: Vec<TraceEvent>,
}

/// Find a shard-owned replica by id (shards stay sorted: spawn ids are
/// handed out in increasing order).
fn find(bin: &[Replica], id: usize) -> usize {
    bin.binary_search_by_key(&id, |r| r.id).expect("replica owned by this shard")
}

/// Record the tenant labels of any completions `rep` produced since the
/// last harvest (WFQ completion feedback). Must run before a retire, which
/// drains the record log and resets the cursor. Only called when
/// `ClusterCfg::wfq` is set — the cursor never advances otherwise.
#[inline]
fn harvest_tenant_dones(rep: &mut Replica, dones: &mut Vec<u16>) {
    let n = rep.eng.records().len();
    if n > rep.records_seen {
        for rec in &rep.eng.records()[rep.records_seen..] {
            dones.push(rec.tenant);
        }
        rep.records_seen = n;
    }
}

/// Worker thread body: owns one shard of replicas and executes rounds
/// until [`Cmd::Finish`].
fn worker_loop(
    rx: Receiver<Cmd>,
    tx: Sender<Report>,
    tracer: Tracer,
    cfg: ClusterCfg,
) -> WorkerOut {
    let max_vt = cfg.engine.max_virtual_time;
    let wfq = cfg.wfq.is_some();
    let mut bin: Vec<Replica> = Vec::new();
    let mut done: Vec<(f64, usize, RunMetrics)> = Vec::new();
    let mut set: Vec<usize> = Vec::new();
    // Tenant labels of this round's completions (swapped into the report's
    // `dones_buf` in phase 6; stays empty when multi-tenancy is off).
    let mut dones: Vec<u16> = Vec::new();

    loop {
        match rx.recv() {
            Ok(Cmd::Round(mut rc)) => {
                let mut completed = 0usize;
                let mut steps = 0usize;
                let mut max_t = f64::NEG_INFINITY;
                let mut evicted: Vec<Replica> = Vec::new();

                // 0. Adopt migrated replicas before anything else, so this
                //    round's drains/injections/steps see them as local.
                //    Their engine tracer re-attaches to this shard's sink
                //    (streams are merged canonically at the end of the run,
                //    so the split is invisible).
                for mut rep in rc.adopts.drain(..) {
                    rep.eng.set_tracer(tracer.for_replica(rep.id as u32));
                    let at = bin.partition_point(|r| r.id < rep.id);
                    bin.insert(at, rep);
                }

                // Reset the per-round load accounts (the shard scheduler's
                // signal; reported in phase 6).
                for rep in bin.iter_mut() {
                    rep.round_steps = 0;
                }

                // 1. Drains: mark victims; empties retire at drain_t
                //    (syncing their clocks first, like the sequential
                //    retire scan — a drained-empty step completes nothing).
                for &id in &rc.drains {
                    let i = find(&bin, id);
                    bin[i].drain();
                    if bin[i].drained() {
                        if bin[i].eng.now() < rc.drain_t {
                            let out = bin[i].eng.step(rc.drain_t);
                            debug_assert_eq!(out.completed, 0);
                        }
                        tracer.emit_for(id as u32, rc.drain_t, EventKind::ReplicaRetire);
                        let m = bin[i].retire(rc.drain_t);
                        done.push((rc.drain_t, id, m));
                    }
                }

                // 2. Spawns (initial fleet and autoscaler growth). Spawn
                //    ids are handed out globally increasing, so they always
                //    sort after everything owned (adopted ids included).
                for &(id, at) in &rc.spawns {
                    debug_assert!(bin.last().map_or(true, |r| r.id < id));
                    let mut rep = Replica::new(id, cfg.kind, &cfg.engine, at);
                    rep.eng.set_tracer(tracer.for_replica(id as u32));
                    tracer.emit_for(id as u32, at, EventKind::ReplicaStart);
                    bin.push(rep);
                }

                // 3. Boundary steps, one per batched time, in time order.
                //    At each time t: first advance every owned replica
                //    through its own events strictly below t at their exact
                //    times (skipped at index 0 — the previous round's
                //    horizon already did it), *then* inject (injecting
                //    before the advance would let an engine admit the
                //    request into an earlier internal batch than the
                //    sequential loop), then step injected ∪ due ∪
                //    primed-at-t₀ in id order (bin order == id order).
                for (k, &t) in rc.step_times.iter().enumerate() {
                    if k > 0 {
                        for rep in bin.iter_mut() {
                            if !rep.in_service() {
                                continue;
                            }
                            while let Some(e) = rep.eng.next_event() {
                                if e >= t || e > max_vt {
                                    break;
                                }
                                let out = rep.eng.step(e);
                                completed += out.completed;
                                steps += 1;
                                rep.round_steps += 1;
                                if wfq && out.completed > 0 {
                                    harvest_tenant_dones(rep, &mut dones);
                                }
                                if e > max_t {
                                    max_t = e;
                                }
                                if rep.drained() {
                                    tracer.emit_for(rep.id as u32, e, EventKind::ReplicaRetire);
                                    done.push((e, rep.id, rep.retire(e)));
                                    break;
                                }
                            }
                        }
                    }
                    set.clear();
                    for &(ki, id, req, eff) in &rc.injections {
                        if ki as usize == k {
                            let i = find(&bin, id);
                            if eff == u32::MAX {
                                bin[i].eng.inject(req);
                            } else {
                                bin[i].eng.inject_effective(req, Some(eff as usize));
                            }
                            bin[i].routed += 1;
                            set.push(i);
                        }
                    }
                    for (i, rep) in bin.iter_mut().enumerate() {
                        if rep.in_service() {
                            if let Some(e) = rep.eng.next_event() {
                                debug_assert!(e + 1e-12 >= t, "event missed by advance");
                                if e <= t {
                                    set.push(i);
                                }
                            }
                        }
                    }
                    if k == 0 {
                        for &id in &rc.step_primed {
                            set.push(find(&bin, id));
                        }
                    }
                    set.sort_unstable();
                    set.dedup();
                    for i in set.drain(..) {
                        let rep = &mut bin[i];
                        if !rep.in_service() {
                            continue;
                        }
                        let out = rep.eng.step(t);
                        completed += out.completed;
                        steps += 1;
                        rep.round_steps += 1;
                        if wfq && out.completed > 0 {
                            harvest_tenant_dones(rep, &mut dones);
                        }
                        if rep.drained() {
                            tracer.emit_for(rep.id as u32, t, EventKind::ReplicaRetire);
                            done.push((t, rep.id, rep.retire(t)));
                        }
                    }
                }

                // 4. Prime: first step of freshly spawned replicas at the
                //    fleet's true next event (inside this round's range).
                if !rc.prime_t.is_nan() {
                    let tp = rc.prime_t;
                    for &id in &rc.prime_ids {
                        let i = find(&bin, id);
                        if bin[i].in_service() {
                            let out = bin[i].eng.step(tp);
                            completed += out.completed;
                            steps += 1;
                            bin[i].round_steps += 1;
                            if wfq && out.completed > 0 {
                                harvest_tenant_dones(&mut bin[i], &mut dones);
                            }
                            if tp > max_t {
                                max_t = tp;
                            }
                        }
                    }
                }

                // 5. Advance: each owned replica processes its own events
                //    below the horizon, at their exact times.
                for rep in bin.iter_mut() {
                    if !rep.in_service() {
                        continue;
                    }
                    while let Some(e) = rep.eng.next_event() {
                        if e >= rc.horizon || e > max_vt {
                            break;
                        }
                        let out = rep.eng.step(e);
                        completed += out.completed;
                        steps += 1;
                        rep.round_steps += 1;
                        if wfq && out.completed > 0 {
                            harvest_tenant_dones(rep, &mut dones);
                        }
                        if e > max_t {
                            max_t = e;
                        }
                        if rep.drained() {
                            tracer.emit_for(rep.id as u32, e, EventKind::ReplicaRetire);
                            done.push((e, rep.id, rep.retire(e)));
                            break;
                        }
                    }
                }

                // 6. Report shard state as of the horizon into the
                //    command's recycled buffers. Evictees are still owned
                //    here, so their parting views/keys/loads are included.
                rc.views_buf.clear();
                rc.views_buf.extend(bin.iter().filter(|r| r.is_active()).map(|r| r.view()));
                rc.loads_buf.clear();
                rc.loads_buf.extend(
                    bin.iter()
                        .filter(|r| r.in_service())
                        .map(|r| (r.id as u32, r.round_steps)),
                );
                // Hand this round's completion tenants back (recycled
                // buffer: `rc.dones_buf` arrives cleared by reset()).
                std::mem::swap(&mut rc.dones_buf, &mut dones);
                let mut key_min = f64::NAN;
                for rep in bin.iter_mut() {
                    if rep.in_service() {
                        if let Some(e) = rep.eng.next_event() {
                            if key_min.is_nan() || e < key_min {
                                key_min = e;
                            }
                        }
                    }
                }

                // 7. Evict: hand migrating replicas back, fully advanced.
                for &id in &rc.evicts {
                    let i = find(&bin, id);
                    evicted.push(bin.remove(i));
                }

                tx.send(Report { evicted, key_min, completed, steps, max_t, spent: rc })
                    .expect("coordinator alive");
            }
            Ok(Cmd::Finish { last_t }) => {
                let mut survivors: Vec<(usize, RunMetrics)> = Vec::new();
                for rep in bin.iter_mut() {
                    if rep.in_service() {
                        if rep.eng.now() < last_t {
                            rep.eng.step(last_t);
                        }
                        rep.state = ReplicaState::Draining; // permit retire()
                        let m = rep.retire(last_t);
                        rep.retired_at = None; // still in service at end
                        survivors.push((rep.id, m));
                    }
                }
                return WorkerOut { replicas: bin, done, survivors, events: tracer.take() };
            }
            Err(_) => {
                // Coordinator dropped (panic unwind): exit quietly.
                return WorkerOut {
                    replicas: bin,
                    done,
                    survivors: Vec::new(),
                    events: tracer.take(),
                };
            }
        }
    }
}

impl Cluster {
    /// Sharded co-simulation over a materialized trace: digest-identical
    /// to [`Cluster::run`] for any `threads ≥ 1` and any `window ≥ 0`
    /// (see the module docs for the argument and the deliberate
    /// differences: `events`, `replica_seconds`, sampling,
    /// `record_event_times`). Static sharding; see
    /// [`Cluster::run_parallel_cfg`] for work stealing.
    pub fn run_parallel(&mut self, trace: &[Request], threads: usize, window: f64) -> ClusterMetrics {
        self.run_parallel_cfg(trace, ParallelCfg { threads, window, steal: None })
    }

    /// Sharded co-simulation with the full [`ParallelCfg`] surface —
    /// thread count, synchronization window, and optional work stealing.
    /// Digest-identical to [`Cluster::run`] for every configuration.
    pub fn run_parallel_cfg(&mut self, trace: &[Request], pcfg: ParallelCfg) -> ClusterMetrics {
        let scaler = self.build_scaler(trace);
        self.run_parallel_core(SliceArrivals::new(trace), scaler, pcfg)
    }

    /// Sharded co-simulation over a streaming workload (the arrivals never
    /// need to exist in memory at once — pair with
    /// [`crate::workload::generate_iter`] /
    /// [`crate::workload::generate_bursty_iter`] for 10⁶-request runs).
    ///
    /// Autoscaling calibrates replica capacity from mean request lengths,
    /// which a stream cannot be scanned for — pass `mean_hint =
    /// Some((mean_prompt, mean_output))` when `cfg.autoscale` is set
    /// (e.g. from [`crate::workload::Dataset`] statistics); without a
    /// hint the capacity model falls back to unit lengths.
    pub fn run_parallel_stream<I: Iterator<Item = Request>>(
        &mut self,
        requests: I,
        mean_hint: Option<(f64, f64)>,
        threads: usize,
        window: f64,
    ) -> ClusterMetrics {
        self.run_parallel_stream_cfg(requests, mean_hint, ParallelCfg { threads, window, steal: None })
    }

    /// Streaming front-end with the full [`ParallelCfg`] surface.
    pub fn run_parallel_stream_cfg<I: Iterator<Item = Request>>(
        &mut self,
        requests: I,
        mean_hint: Option<(f64, f64)>,
        pcfg: ParallelCfg,
    ) -> ClusterMetrics {
        let scaler = self.cfg.autoscale.map(|acfg| {
            let cost = calibrate(&self.cfg.engine.gpu);
            let (mp, mo) = mean_hint.unwrap_or((1.0, 1.0));
            Autoscaler::new(
                acfg,
                super::autoscaler::predict_replica_rate(&cost, &self.cfg.engine, mp, mo),
            )
        });
        self.run_parallel_core(StreamArrivals::new(requests), scaler, pcfg)
    }

    fn run_parallel_core<A: Arrivals>(
        &mut self,
        mut arrivals: A,
        mut scaler: Option<Autoscaler>,
        pcfg: ParallelCfg,
    ) -> ClusterMetrics {
        let ParallelCfg { threads, window, steal } = pcfg;
        assert!(threads >= 1, "run_parallel needs at least one worker");
        assert!(window >= 0.0, "window must be nonnegative");
        if let Some(sc) = &steal {
            assert!(sc.threshold > 1.0, "steal threshold must exceed 1");
            assert!(sc.interval > 0.0, "balance interval must be positive");
        }
        let cfg = self.cfg.clone();
        let n0 = match &cfg.autoscale {
            Some(a) => cfg.replicas.clamp(a.min_replicas, a.max_replicas),
            None => cfg.replicas,
        };
        self.replicas = Vec::new();
        self.router = Router::new(cfg.policy);
        // Prefix-tier state lives on the coordinator: every lookup and
        // admit happens at routing time, so the machinery is identical to
        // the sequential loops by construction (workers only ever see the
        // already-resolved effective prompt riding on the injection).
        self.prefix = cfg.prefix_cfg().map(PrefixState::new);
        self.event_times.clear();
        let max_vt = cfg.engine.max_virtual_time;
        let mut next_tick = scaler.as_ref().map(|s| s.cfg.interval);

        // Coordinator bookkeeping (mirrors the sequential loop's counters).
        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut peak_replicas = n0;
        let mut active_cnt = n0;
        let mut pending_total = 0usize;
        let mut arrivals_since_tick = 0usize;
        let mut next_id = n0;
        let mut last_t = 0.0f64;
        let mut rounds = 0usize;
        let mut steps_total = 0usize;
        // Merged active-replica views as of the current horizon, id order.
        let mut views: Vec<ReplicaView> = Vec::new();
        let mut keys_min = f64::NAN;
        // Replicas awaiting their first step, and when it lands: the
        // fleet's next event as of their spawn (min of next arrival, next
        // tick, and every shard's key minimum) — fixed at spawn time, since
        // nothing can schedule an earlier event afterwards.
        let mut primed: Vec<usize> = (0..n0).collect();
        let mut prime_t = f64::NAN; // resolved at the first boundary probe
        // Directives decided at a tick, applied in the next round.
        let mut pending_spawns: Vec<(usize, f64)> = Vec::new();
        let mut pending_drains: Vec<usize> = Vec::new();
        let mut drain_t = 0.0f64;
        let mut arr_buf: Vec<Request> = Vec::new();
        let mut kv_buf: Vec<f64> = Vec::new();
        let mut outs: Vec<WorkerOut> = Vec::new();

        // Multi-tenant WFQ gate, mirroring the sequential loops. While the
        // gate holds a backlog the loop runs in *lockstep*: boundaries
        // include the earliest shard event (`keys_min`) and rounds stop at
        // the boundary (horizon = B), because any completion may free a
        // quota slot and trigger a dispatch at that exact virtual time.
        // With no backlog, completions need no immediate dispatch and the
        // loop free-runs exactly as the untagged fast path. `wfq_ready_at`
        // re-enters the dispatch loop at the same instant a completion
        // freed slots — pure virtual-time state, identical to the
        // sequential loops' pseudo-event.
        let mut gate = cfg.wfq.clone().map(TenantGate::new);
        let mut wfq_ready_at: Option<f64> = None;
        let mut throttled_buf: Vec<(usize, u16)> = Vec::new();
        let mut round_dones = false;

        // Shard-scheduler state. `owner[id]` replaces the static
        // `id % threads` partition and is the single routing authority for
        // every per-replica directive. Loads are engine steps: windowed
        // (reset each balance check) for decisions, total for reporting.
        let mut owner: Vec<usize> = (0..n0).map(|i| i % threads).collect();
        let mut rep_load: Vec<u64> = vec![0; n0];
        let mut shard_window: Vec<u64> = vec![0; threads];
        let mut shard_total: Vec<u64> = vec![0; threads];
        // Replicas ever assigned per shard — the spawn-placement tiebreak,
        // so simultaneous spawns spread instead of piling on one argmin.
        let mut shard_assigned: Vec<u32> = vec![0; threads];
        for &w in &owner {
            shard_assigned[w] += 1;
        }
        let mut next_balance = steal.map_or(f64::INFINITY, |s| s.interval);
        let mut rebalances = 0usize;
        // Migration machinery: moves decided at a boundary are evicted in
        // the next round (ids in `pending_evicts`, destinations in
        // `migrating`), travel back in that round's reports (the old owner
        // still reports their parting views/keys, so routing never loses
        // sight of them), sit in `in_transit` for exactly one boundary,
        // and are adopted by their new shard at the start of the next round.
        let mut pending_evicts: Vec<usize> = Vec::new();
        let mut migrating: Vec<(usize, usize)> = Vec::new();
        let mut in_transit: Vec<Replica> = Vec::new();
        // Balance-check scratch (reused across checks).
        let mut plan_loads: Vec<u64> = Vec::new();
        let mut plan_reps: Vec<(usize, u64)> = Vec::new();
        let mut excl: Vec<usize> = Vec::new();
        let mut moves_buf: Vec<(usize, usize, usize)> = Vec::new();
        // Rendezvous-batching scratch. Batching needs blind routing and
        // untraced runs (per-arrival Route events pin rendezvous order);
        // WFQ admission is load- and completion-coupled, so gated runs
        // always rendezvous per arrival instant.
        let batching = steal.is_some() && !self.tracer.enabled() && cfg.wfq.is_none();
        let mut batch_times: Vec<f64> = Vec::new();
        let mut batch_inj: Vec<(u32, usize, Request, u32)> = Vec::new();
        let mut hold_buf: Vec<Request> = Vec::new();
        let mut targets_buf: Vec<usize> = Vec::new();
        // A same-instant arrival group that failed the blind probe waits
        // here for its own boundary round (checked before the stream).
        let mut held: Vec<Request> = Vec::new();
        // Cap on batched step times per round: bounds command size and
        // worker latency without measurably hurting amortization.
        const BATCH_CAP: usize = 64;

        // Initial fleet spawns through the same directive path as
        // autoscaler growth, so workers own replica construction uniformly.
        // Synthesize their (empty) views up front: a trace whose first
        // arrival lands exactly at t = 0 routes before any worker report
        // exists (fresh engines report pending 0 / kv 0.0 anyway).
        pending_spawns.extend((0..n0).map(|i| (i, 0.0)));
        views.extend((0..n0).map(|i| ReplicaView {
            index: i as u32,
            pending: 0,
            kv_usage: 0.0,
        }));

        std::thread::scope(|s| {
            let mut txs: Vec<Sender<Cmd>> = Vec::with_capacity(threads);
            let mut rxs: Vec<Receiver<Report>> = Vec::with_capacity(threads);
            let mut handles = Vec::with_capacity(threads);
            for _ in 0..threads {
                let (ctx, crx) = channel::<Cmd>();
                let (rtx, rrx) = channel::<Report>();
                let shard_tracer = self.tracer.fork_sink();
                let wcfg = cfg.clone();
                handles.push(s.spawn(move || worker_loop(crx, rtx, shard_tracer, wcfg)));
                txs.push(ctx);
                rxs.push(rrx);
            }

            // Per-worker recycled command buffers: each round's command is
            // taken from here, and the worker's spent command (with its
            // report buffers) lands back after the report is merged — the
            // double-buffering that keeps steady-state rounds
            // allocation-free on both sides.
            let mut spare: Vec<RoundCmd> =
                (0..threads).map(|_| RoundCmd::default()).collect();
            const NO_T: &[f64] = &[];
            const NO_I: &[(u32, usize, Request, u32)] = &[];
            const NO_P: &[usize] = &[];

            // Broadcast one round (partitioning directives by `owner`) and
            // merge the reports back into the coordinator's state.
            macro_rules! round {
                ($times:expr, $inj:expr, $sp:expr, $horizon:expr) => {{
                    let times: &[f64] = $times;
                    let inj: &[(u32, usize, Request, u32)] = $inj;
                    let sp: &[usize] = $sp;
                    let horizon: f64 = $horizon;
                    for c in spare.iter_mut() {
                        c.drain_t = drain_t;
                        c.horizon = horizon;
                        c.prime_t = f64::NAN;
                        c.step_times.extend_from_slice(times);
                    }
                    for r in in_transit.drain(..) {
                        spare[owner[r.id]].adopts.push(r);
                    }
                    for &id in &pending_evicts {
                        spare[owner[id]].evicts.push(id);
                    }
                    for &id in &pending_drains {
                        spare[owner[id]].drains.push(id);
                    }
                    for &(id, at) in &pending_spawns {
                        spare[owner[id]].spawns.push((id, at));
                    }
                    for &(k, id, req, eff) in inj {
                        spare[owner[id]].injections.push((k, id, req, eff));
                    }
                    for &id in sp {
                        spare[owner[id]].step_primed.push(id);
                    }
                    // Flush a pending prime that lands strictly inside
                    // this round's advance range (never beyond the
                    // simulation horizon — the sequential loop breaks
                    // before stepping anything past max_virtual_time).
                    if !primed.is_empty() && prime_t < horizon && prime_t <= max_vt {
                        for &id in &primed {
                            spare[owner[id]].prime_ids.push(id);
                        }
                        for c in spare.iter_mut() {
                            c.prime_t = prime_t;
                        }
                        primed.clear();
                    }
                    for (w, tx) in txs.iter().enumerate() {
                        tx.send(Cmd::Round(std::mem::take(&mut spare[w])))
                            .expect("worker alive");
                    }
                    pending_evicts.clear();
                    pending_drains.clear();
                    pending_spawns.clear();
                    rounds += 1;
                    views.clear();
                    keys_min = f64::NAN;
                    round_dones = false;
                    for (w, rx) in rxs.iter().enumerate() {
                        let mut rep = rx.recv().expect("worker alive");
                        views.append(&mut rep.spent.views_buf);
                        if let Some(g) = gate.as_mut() {
                            // Release gate slots for this round's
                            // completions (commutative counts — shard
                            // order cannot affect admission decisions).
                            for &tn in &rep.spent.dones_buf {
                                g.on_complete(tn);
                            }
                            round_dones |= !rep.spent.dones_buf.is_empty();
                        }
                        let mut wsteps = 0u64;
                        for &(id, st) in &rep.spent.loads_buf {
                            wsteps += st as u64;
                            if steal.is_some() {
                                rep_load[id as usize] += st as u64;
                            }
                        }
                        shard_window[w] += wsteps;
                        shard_total[w] += wsteps;
                        if !rep.key_min.is_nan()
                            && (keys_min.is_nan() || rep.key_min < keys_min)
                        {
                            keys_min = rep.key_min;
                        }
                        pending_total -= rep.completed;
                        steps_total += rep.steps;
                        if rep.max_t > last_t {
                            last_t = rep.max_t;
                        }
                        // Evicted replicas: reassign ownership and park
                        // them for adoption next round.
                        for r in rep.evicted.drain(..) {
                            let pos = migrating
                                .iter()
                                .position(|&(id, _)| id == r.id)
                                .expect("eviction was planned");
                            let (_, dest) = migrating.swap_remove(pos);
                            owner[r.id] = dest;
                            shard_assigned[dest] += 1;
                            in_transit.push(r);
                        }
                        rep.spent.reset();
                        spare[w] = rep.spent;
                    }
                    // In-transit replicas need no splice: the old owner
                    // reported their parting views/keys/loads this round
                    // (phase 6 precedes the phase-7 evict), and the new
                    // owner adopts them before anything else next round —
                    // the router never loses sight of them.
                    views.sort_unstable_by_key(|v| v.index);
                    // Completions freed gate slots with arrivals still
                    // held: re-dispatch at the round's step time, like the
                    // sequential loops' same-instant extra iteration.
                    // Backlogged rounds run in lockstep (horizon = the one
                    // step time), so these completions are exactly there.
                    if round_dones && gate.as_ref().is_some_and(|g| g.backlogged()) {
                        if let Some(&bt) = times.last() {
                            wfq_ready_at = Some(bt);
                        }
                    }
                }};
            }

            // Workers have processed every event strictly below cur_h.
            let mut cur_h = 0.0f64;
            loop {
                // A gated run must not stop while requests sit in the gate
                // with a re-dispatch armed; a gate holding requests with
                // nothing armed and nothing in flight is wedged
                // (zero-quota/zero-capacity config) and bails out exactly
                // like the sequential loops — held requests time out.
                if held.is_empty()
                    && arrivals.exhausted()
                    && pending_total == 0
                    && gate
                        .as_ref()
                        .map_or(true, |g| g.queued() == 0 || wfq_ready_at.is_none())
                {
                    // Apply directives left by a just-decided scale action
                    // (empty victims must still retire at the decision
                    // time, as in the sequential retire scan).
                    if !pending_drains.is_empty() || !pending_spawns.is_empty() {
                        round!(NO_T, NO_I, NO_P, cur_h);
                    }
                    break;
                }

                // Next interaction boundary: earliest arrival (a held
                // group, by construction, precedes the stream) or tick.
                // A backlogged gate adds the earliest shard event — any
                // completion may free a slot and force a dispatch there —
                // and an armed re-dispatch instant.
                let mut b = f64::INFINITY;
                if let Some(r) = held.first() {
                    b = b.min(r.arrival);
                } else if let Some(a) = arrivals.peek_time() {
                    b = b.min(a);
                }
                if let Some(tk) = next_tick {
                    b = b.min(tk);
                }
                if gate.as_ref().is_some_and(|g| g.backlogged()) && !keys_min.is_nan() {
                    b = b.min(keys_min);
                }
                if let Some(w) = wfq_ready_at {
                    b = b.min(w);
                }

                if !b.is_finite() || b > max_vt {
                    // No further interactions inside the horizon: drain
                    // everything schedulable (workers stop at
                    // max_virtual_time), then stop.
                    if cur_h.is_infinite() {
                        break;
                    }
                    round!(NO_T, NO_I, NO_P, f64::INFINITY);
                    cur_h = f64::INFINITY;
                    continue;
                }

                // Initial replicas resolve their first-step time at the
                // first probe (no shard keys exist before any step).
                if prime_t.is_nan() && !primed.is_empty() {
                    prime_t = b;
                }

                if cur_h < b {
                    // Window-capped advance toward the boundary: no
                    // routing, no tick, no step — output-invariant.
                    let h = if window > 0.0 { (cur_h + window).min(b) } else { b };
                    round!(NO_T, NO_I, NO_P, h);
                    cur_h = h;
                    if keys_min.is_nan()
                        && held.is_empty()
                        && arrivals.exhausted()
                        && pending_total > 0
                    {
                        break; // stall: nothing schedulable, nothing arriving
                    }
                    continue;
                }

                // Boundary round at B == cur_h: route arrivals against the
                // merged post-advance views, rebuilding the load picture
                // per arrival exactly like the sequential loop (injections
                // bump only the target's pending; KV moves only on steps).
                let is_tick = next_tick.is_some_and(|tk| b + 1e-12 >= tk);
                // An armed re-dispatch is consumed by this boundary round:
                // the dispatch loop below drains whatever the freed slots
                // now admit. (The round may re-arm it at this same instant
                // if its completions free further slots.)
                if wfq_ready_at.is_some_and(|w| w <= b) {
                    wfq_ready_at = None;
                }
                if held.first().is_some_and(|r| r.arrival <= b) {
                    arr_buf.clear();
                    arr_buf.append(&mut held);
                } else {
                    arrivals.pop_until(b, &mut arr_buf);
                }
                batch_times.clear();
                batch_inj.clear();
                batch_times.push(b);
                match gate.as_mut() {
                    None => {
                        for r in &arr_buf {
                            let target = self.router.route_with(&views, r, self.prefix.as_ref());
                            self.trace_route(r, target, &views, b);
                            let eff = Self::prefix_admit(
                                &mut self.prefix,
                                &self.tracer,
                                &views,
                                r,
                                target,
                                b,
                            );
                            if let Ok(pos) =
                                views.binary_search_by_key(&(target as u32), |v| v.index)
                            {
                                views[pos].pending += 1;
                            }
                            batch_inj.push((0, target, *r, eff.map_or(u32::MAX, |e| e as u32)));
                            pending_total += 1;
                            arrivals_since_tick += 1;
                        }
                    }
                    Some(g) => {
                        // Tenant gate: enqueue every arrival, then dispatch
                        // in virtual-finish order as quota/capacity allow —
                        // identical to the sequential loops' protocol.
                        throttled_buf.clear();
                        for r in &arr_buf {
                            self.trace_arrival(r);
                            g.push(*r);
                            arrivals_since_tick += 1;
                            throttled_buf.push((r.id, r.tenant));
                        }
                        while let Some(r) = g.pop_next() {
                            let target = self.router.route_with(&views, &r, self.prefix.as_ref());
                            self.trace_admit(&r, target, &views, b);
                            let eff = Self::prefix_admit(
                                &mut self.prefix,
                                &self.tracer,
                                &views,
                                &r,
                                target,
                                b,
                            );
                            if let Ok(pos) =
                                views.binary_search_by_key(&(target as u32), |v| v.index)
                            {
                                views[pos].pending += 1;
                            }
                            batch_inj.push((0, target, r, eff.map_or(u32::MAX, |e| e as u32)));
                            pending_total += 1;
                            throttled_buf.retain(|&(id, _)| id != r.id);
                        }
                        for &(id, tenant) in throttled_buf.iter() {
                            self.trace_throttle(id, tenant, g.queued_for(tenant), b);
                        }
                    }
                }
                let step_primed = if !primed.is_empty() && prime_t == b {
                    std::mem::take(&mut primed)
                } else {
                    Vec::new()
                };

                // Rendezvous batching: pull further arrival instants into
                // this round while every request in each same-instant
                // group routes *blindly* (see `Router::blind_probe`) — no
                // load feedback, so the decisions are identical to
                // per-instant rendezvous. Ticks, the window cap, and the
                // simulation horizon all end a batch; a group with any
                // non-blind member is held intact for its own boundary
                // (all-or-nothing, preserving same-instant route order).
                if batching && !is_tick {
                    let mut blind_n = 0usize;
                    while batch_times.len() < BATCH_CAP {
                        let Some(a) = arrivals.peek_time() else { break };
                        if next_tick.is_some_and(|tk| a + 1e-12 >= tk)
                            || a > max_vt
                            || (window > 0.0 && a >= b + window)
                        {
                            break;
                        }
                        arrivals.pop_until(a, &mut hold_buf);
                        targets_buf.clear();
                        for (j, r) in hold_buf.iter().enumerate() {
                            match self.router.blind_probe_with(
                                &views,
                                blind_n + j,
                                r,
                                self.prefix.as_ref(),
                            ) {
                                Some(t) => targets_buf.push(t),
                                None => break,
                            }
                        }
                        if targets_buf.len() < hold_buf.len() {
                            held.append(&mut hold_buf);
                            break;
                        }
                        let k = batch_times.len() as u32;
                        batch_times.push(a);
                        for (r, &tg) in hold_buf.iter().zip(&targets_buf) {
                            // Blind members passed `pure_touch`, so this admit
                            // is a guaranteed no-op on store contents — it only
                            // refreshes LRU ticks and stats, in the same member
                            // order the sequential loops would use.
                            let eff = Self::prefix_admit(
                                &mut self.prefix,
                                &self.tracer,
                                &views,
                                r,
                                tg,
                                a,
                            );
                            batch_inj.push((k, tg, *r, eff.map_or(u32::MAX, |e| e as u32)));
                            pending_total += 1;
                            arrivals_since_tick += 1;
                        }
                        blind_n += hold_buf.len();
                        hold_buf.clear();
                    }
                    self.router.commit_blind(blind_n);
                }
                last_t = last_t.max(*batch_times.last().expect("batch has its boundary"));

                if is_tick {
                    // Rendezvous 1: boundary step only (horizon B ⇒ no
                    // advance), so the decision sees post-step state.
                    round!(&batch_times, &batch_inj, &step_primed, b);
                    let sc = scaler.as_mut().expect("tick implies scaler");
                    let tk = next_tick.expect("tick implies schedule");
                    kv_buf.clear();
                    kv_buf.extend(views.iter().map(|v| v.kv_usage));
                    let obs = FleetObs {
                        now: b,
                        arrival_rate: arrivals_since_tick as f64 / sc.cfg.interval,
                        active_replicas: views.len(),
                        total_pending: pending_total,
                        mean_kv: crate::util::mean(&kv_buf),
                        max_kv: kv_buf.iter().fold(0.0f64, |a, &v| a.max(v)),
                    };
                    if let Some(target) = sc.decide(&obs) {
                        let from = views.len();
                        self.tracer.emit_for(
                            crate::trace::FLEET,
                            b,
                            EventKind::Scale { from, to: target },
                        );
                        scale_events.push(ScaleEvent { time: b, from, to: target });
                        if target > from {
                            for _ in from..target {
                                // Shard placement: lightest shard first
                                // (windowed steps, then fewest ever
                                // assigned, then index) when stealing;
                                // the static partition otherwise.
                                let w = if steal.is_some() {
                                    (0..threads)
                                        .min_by_key(|&w| {
                                            (shard_window[w], shard_assigned[w], w)
                                        })
                                        .expect("threads >= 1")
                                } else {
                                    next_id % threads
                                };
                                debug_assert_eq!(owner.len(), next_id);
                                owner.push(w);
                                rep_load.push(0);
                                shard_assigned[w] += 1;
                                pending_spawns.push((next_id, b));
                                primed.push(next_id);
                                // Fresh replicas are routable immediately:
                                // synthesize their (empty) views until the
                                // next report includes them.
                                views.push(ReplicaView {
                                    index: next_id as u32,
                                    pending: 0,
                                    kv_usage: 0.0,
                                });
                                next_id += 1;
                            }
                            // First step at the fleet's next event, fixed
                            // now: nothing can schedule an earlier one.
                            prime_t = f64::INFINITY;
                            if let Some(a) = arrivals.peek_time() {
                                prime_t = prime_t.min(a);
                            }
                            prime_t = prime_t.min(tk + sc.cfg.interval);
                            if !keys_min.is_nan() {
                                prime_t = prime_t.min(keys_min);
                            }
                            // The sequential loop primes spawned replicas
                            // at the next processed event, which can be the
                            // gate's same-instant re-dispatch iteration.
                            if let Some(w) = wfq_ready_at {
                                prime_t = prime_t.min(w);
                            }
                        } else {
                            // Drain the least-loaded actives (same
                            // (pending, id) order as the sequential
                            // rescale); they leave the routable set now
                            // and retire once empty.
                            let mut by_load: Vec<(u32, u32)> =
                                views.iter().map(|v| (v.pending, v.index)).collect();
                            by_load.sort_unstable();
                            for &(_, idx) in by_load.iter().take(from - target) {
                                pending_drains.push(idx as usize);
                                self.tracer.emit_for(idx, b, EventKind::ReplicaDrain);
                                if let Ok(pos) =
                                    views.binary_search_by_key(&idx, |v| v.index)
                                {
                                    views.remove(pos);
                                }
                            }
                            drain_t = b;
                        }
                        active_cnt = target;
                    }
                    next_tick = Some(tk + sc.cfg.interval);
                    arrivals_since_tick = 0;
                } else {
                    // Plain arrival boundary: fuse the boundary step(s)
                    // with the advance toward the next interaction.
                    let mut nb = f64::INFINITY;
                    if let Some(r) = held.first() {
                        nb = nb.min(r.arrival);
                    } else if let Some(a) = arrivals.peek_time() {
                        nb = nb.min(a);
                    }
                    if let Some(tk) = next_tick {
                        nb = nb.min(tk);
                    }
                    // Backlogged gate ⇒ lockstep: the horizon stays at the
                    // boundary so no completion beyond it is processed
                    // before the coordinator can turn it into a dispatch.
                    // Slower (one no-op advance round per internal event)
                    // but required for digest parity with the sequential
                    // loops; free-running resumes once the gate drains.
                    let h = if gate.as_ref().is_some_and(|g| g.backlogged()) {
                        b
                    } else if window > 0.0 {
                        (b + window).min(nb)
                    } else {
                        nb
                    };
                    round!(&batch_times, &batch_inj, &step_primed, h);
                    cur_h = h;
                }

                // Balance check: deterministic, virtual-time-scheduled,
                // fed only by the windowed step accounts the reports just
                // updated. Decisions become evict directives for the next
                // round; the windows reset so each check sees one
                // interval's worth of load.
                if let Some(sc) = &steal {
                    if b + 1e-12 >= next_balance {
                        plan_reps.clear();
                        plan_reps.extend(
                            views.iter().map(|v| (v.index as usize, rep_load[v.index as usize])),
                        );
                        excl.clear();
                        excl.extend_from_slice(&pending_drains);
                        excl.extend(in_transit.iter().map(|r| r.id));
                        excl.extend(migrating.iter().map(|&(id, _)| id));
                        plan_loads.clear();
                        plan_loads.extend_from_slice(&shard_window);
                        plan_rebalance(
                            &mut plan_loads,
                            &plan_reps,
                            &owner,
                            sc.threshold,
                            &excl,
                            &mut moves_buf,
                        );
                        for &(id, from, to) in &moves_buf {
                            self.tracer.emit_for(
                                id as u32,
                                b,
                                EventKind::ShardRebalance { from_shard: from, to_shard: to },
                            );
                            pending_evicts.push(id);
                            migrating.push((id, to));
                        }
                        rebalances += moves_buf.len();
                        for x in shard_window.iter_mut() {
                            *x = 0;
                        }
                        for x in rep_load.iter_mut() {
                            *x = 0;
                        }
                        next_balance = b + sc.interval;
                    }
                }

                peak_replicas = peak_replicas.max(active_cnt);
                if keys_min.is_nan()
                    && held.is_empty()
                    && arrivals.exhausted()
                    && pending_total > 0
                {
                    // Stall: nothing schedulable, nothing arriving. Apply
                    // any directives from this boundary's tick first.
                    if !pending_drains.is_empty() || !pending_spawns.is_empty() {
                        round!(NO_T, NO_I, NO_P, cur_h);
                    }
                    break;
                }
            }

            // A migration caught mid-flight by loop exit: abandon planned
            // evictions (purely observational) and adopt anything already
            // in transit so no replica is lost at Finish.
            pending_evicts.clear();
            migrating.clear();
            if !in_transit.is_empty() {
                round!(NO_T, NO_I, NO_P, cur_h);
            }

            for tx in &txs {
                tx.send(Cmd::Finish { last_t }).expect("worker alive");
            }
            for h in handles {
                outs.push(h.join().expect("worker panicked"));
            }
        });

        // Merge per-shard results in the sequential loop's order:
        // mid-run retirements chronologically (ties in id order — the
        // sequential retire scan walks ids), then survivors in id order.
        let mut fleet = RunMetrics::default();
        let mut ttft_hist = Histogram::new();
        let mut tbt_hist = Histogram::new();
        let mut done: Vec<(f64, usize, RunMetrics)> = Vec::new();
        let mut survivors: Vec<(usize, RunMetrics)> = Vec::new();
        let mut streams: Vec<Vec<TraceEvent>> = Vec::new();
        for out in outs {
            done.extend(out.done);
            survivors.extend(out.survivors);
            self.replicas.extend(out.replicas);
            streams.push(out.events);
        }
        done.sort_by_key(|&(t, id, _)| (f64_total_key(t), id));
        survivors.sort_by_key(|&(id, _)| id);
        for (_, _, m) in done {
            ttft_hist.merge(&m.ttft_histogram());
            tbt_hist.merge(&m.tbt_histogram());
            fleet.merge(m);
        }
        for (_, m) in survivors {
            ttft_hist.merge(&m.ttft_histogram());
            tbt_hist.merge(&m.tbt_histogram());
            fleet.merge(m);
        }
        fleet.timeouts = arrivals.offered() - fleet.records.len();
        self.replicas.sort_by_key(|r| r.id);

        // Fold the per-shard trace streams back into the cluster tracer in
        // canonical (time, replica) order.
        if self.tracer.enabled() {
            streams.insert(0, self.tracer.take());
            self.tracer.absorb(merge_streams(streams));
        }

        // Replica-seconds analytically (window/thread-invariant; within
        // float noise of the sequential accumulation — digest-excluded).
        let replica_seconds: f64 = self
            .replicas
            .iter()
            .map(|r| r.retired_at.unwrap_or(last_t) - r.started_at)
            .sum();

        let replicas = self
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                id: r.id,
                routed: r.routed as usize,
                completed: r.eng.completed(),
                started_at: r.started_at,
                retired_at: r.retired_at,
            })
            .collect();

        ClusterMetrics {
            fleet,
            replicas,
            scale_events,
            suppressed_scales: scaler.as_ref().map_or(0, |s| s.suppressed),
            replica_seconds,
            peak_replicas,
            events: rounds + steps_total,
            ttft_hist,
            tbt_hist,
            rebalances,
            shard_steps: shard_total,
            prefix: self
                .prefix
                .as_ref()
                .map_or_else(PrefixStats::default, |p| p.stats),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineCfg, EngineKind};
    use crate::model::ModelConfig;
    use crate::workload::{generate, generate_iter, Dataset};

    fn ecfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn parallel_matches_sequential_digest() {
        let trace = generate(Dataset::Mixed, 40, 6.0, 11);
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg(),
            3,
            super::super::RoutingPolicy::JoinShortestQueue,
        );
        let seq = Cluster::new(cc.clone()).run(&trace);
        for threads in [1usize, 2, 4] {
            let par = Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0);
            assert_eq!(seq.digest(), par.digest(), "threads={threads}");
        }
    }

    #[test]
    fn window_size_does_not_change_results() {
        let trace = generate(Dataset::ShareGpt, 40, 8.0, 23);
        let cc = ClusterCfg::new(
            EngineKind::Vllm,
            ecfg(),
            4,
            super::super::RoutingPolicy::LeastKvPressure,
        );
        let base = Cluster::new(cc.clone()).run_parallel(&trace, 2, 0.0);
        for window in [0.05f64, 0.5, 10.0] {
            let w = Cluster::new(cc.clone()).run_parallel(&trace, 2, window);
            assert_eq!(base.digest(), w.digest(), "window={window}");
        }
    }

    #[test]
    fn stream_arrivals_match_slice_arrivals() {
        // The streaming front-end must be behaviorally identical to the
        // materialized trace (autoscale off: capacity calibration needs
        // trace statistics a stream cannot provide).
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg(),
            2,
            super::super::RoutingPolicy::RoundRobin,
        );
        let trace = generate(Dataset::ShareGpt, 50, 10.0, 9);
        let by_slice = Cluster::new(cc.clone()).run_parallel(&trace, 2, 0.0);
        let by_stream = Cluster::new(cc).run_parallel_stream(
            generate_iter(Dataset::ShareGpt, 50, 10.0, 9),
            None,
            2,
            0.0,
        );
        assert_eq!(by_slice.digest(), by_stream.digest());
        assert_eq!(by_slice.fleet.records.len(), by_stream.fleet.records.len());
    }

    #[test]
    fn plan_rebalance_moves_toward_balance() {
        // Shard 0 carries 100 steps across two replicas; shard 1 has 10.
        let mut loads = vec![100u64, 10];
        let cands = vec![(0usize, 60u64), (2, 40), (1, 10)];
        let owner = vec![0usize, 1, 0];
        let mut moves = Vec::new();
        plan_rebalance(&mut loads, &cands, &owner, 1.5, &[], &mut moves);
        // gap = 90: replica 2 (40 ≤ 45) fits, replica 0 (60) overshoots.
        assert_eq!(moves, vec![(2, 0, 1)]);
        assert_eq!(loads, vec![60, 50]);

        // Balanced input: no moves.
        let mut loads = vec![50u64, 60];
        plan_rebalance(&mut loads, &cands, &owner, 1.5, &[], &mut moves);
        assert!(moves.is_empty());

        // Excluded candidates never move.
        let mut loads = vec![100u64, 10];
        plan_rebalance(&mut loads, &cands, &owner, 1.5, &[2], &mut moves);
        assert!(moves.is_empty(), "only eligible mover was excluded");

        // Single shard: trivially a no-op.
        let mut one = vec![100u64];
        plan_rebalance(&mut one, &cands, &owner, 1.5, &[], &mut moves);
        assert!(moves.is_empty());
    }

    #[test]
    fn stealing_matches_sequential_digest() {
        // Session-affinity hot spot plus autoscale churn — the workload
        // stealing exists for. The digest must not move at all.
        let mut cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg(),
            4,
            super::super::RoutingPolicy::SessionAffinity,
        );
        cc.autoscale = Some(crate::cluster::AutoscalerCfg {
            min_replicas: 2,
            max_replicas: 6,
            interval: 2.0,
            cooldown: 4.0,
            ..Default::default()
        });
        let trace = generate(Dataset::ShareGpt, 120, 15.0, 17);
        let seq = Cluster::new(cc.clone()).run(&trace);
        for threads in [1usize, 2, 4] {
            for steal in [
                None,
                Some(StealCfg { threshold: 1.2, interval: 0.5 }),
                Some(StealCfg { threshold: 2.0, interval: 2.0 }),
            ] {
                let mut c = Cluster::new(cc.clone());
                let par = c.run_parallel_cfg(&trace, ParallelCfg { threads, window: 0.0, steal });
                assert_eq!(
                    seq.digest(),
                    par.digest(),
                    "threads={threads} steal={steal:?}"
                );
                assert_eq!(par.shard_steps.len(), threads);
                if steal.is_none() {
                    assert_eq!(par.rebalances, 0, "static sharding never migrates");
                }
            }
        }
    }

    #[test]
    fn stealing_with_window_matches_digest() {
        let cc = ClusterCfg::new(
            EngineKind::Vllm,
            ecfg(),
            4,
            super::super::RoutingPolicy::RoundRobin,
        );
        let trace = generate(Dataset::ShareGpt, 60, 12.0, 29);
        let seq = Cluster::new(cc.clone()).run(&trace);
        for window in [0.0f64, 0.25, 5.0] {
            let par = Cluster::new(cc.clone()).run_parallel_cfg(
                &trace,
                ParallelCfg {
                    threads: 3,
                    window,
                    steal: Some(StealCfg { threshold: 1.1, interval: 0.25 }),
                },
            );
            assert_eq!(seq.digest(), par.digest(), "window={window}");
        }
    }

    #[test]
    fn stream_arrivals_pop_in_order() {
        let trace = generate(Dataset::Mixed, 20, 5.0, 3);
        let mut s = StreamArrivals::new(trace.iter().copied());
        let mut a = SliceArrivals::new(&trace);
        let mut sb = Vec::new();
        let mut ab = Vec::new();
        for t in [0.5f64, 1.5, 3.0, 100.0] {
            assert_eq!(s.peek_time(), a.peek_time());
            s.pop_until(t, &mut sb);
            a.pop_until(t, &mut ab);
            assert_eq!(sb.len(), ab.len(), "t={t}");
            assert!(sb.iter().zip(&ab).all(|(x, y)| x.id == y.id));
        }
        assert!(s.exhausted() && a.exhausted());
        assert_eq!(s.offered(), 20);
        assert_eq!(a.offered(), 20);
    }
}
