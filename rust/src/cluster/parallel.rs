//! Sharded virtual-time execution of the fleet loop (§Perf).
//!
//! [`Cluster::run_parallel`] partitions the replicas of a fleet across
//! worker threads (`id % threads`) and advances each shard independently
//! between *interaction boundaries*, synchronizing only where replicas can
//! actually affect each other. The result is digest-identical to the
//! sequential [`Cluster::run`] for **any** thread count and any window
//! size (pinned by `tests/golden_digest.rs` and `tests/prop_cluster.rs`).
//!
//! ## Why sharding is exact, not approximate
//!
//! The fleet couples replicas in exactly three places: routing (an arrival
//! reads every active replica's load), autoscaler ticks (a decision reads
//! fleet-wide state and may spawn/drain replicas), and the fleet counters
//! derived from both. Between consecutive boundaries drawn from those
//! interactions, every replica evolves independently — the module-level
//! *equivalence* invariant (a replica not stepped at a foreign event
//! cannot change observable state) means stepping it only at its own
//! internal event times reproduces the sequential trajectory bit for bit.
//!
//! ## Protocol
//!
//! The caller's thread acts as the coordinator; `threads` persistent
//! workers (spawned under [`std::thread::scope`], talking over
//! [`std::sync::mpsc`] channels) own the replica shards. Each round the
//! coordinator broadcasts one [`RoundCmd`] and collects one [`Report`] per
//! worker:
//!
//! 1. **drain** directives from a scale-down decided at the previous
//!    boundary (empty victims retire immediately, at the decision time);
//! 2. **spawn** directives (initial fleet and autoscaler growth);
//! 3. a **boundary step** at time `B`: injections in arrival order plus
//!    every owned replica whose next event is due at `B`, stepped in id
//!    order — exactly the step set of the sequential loop at `B`;
//! 4. a **prime** step giving freshly spawned replicas their first step at
//!    the fleet's true next event time (which the coordinator computes
//!    from the reported per-shard key minima — see `prime` below);
//! 5. an **advance** phase: each owned in-service replica processes its
//!    own internal events strictly below the round's `horizon`, at their
//!    exact times.
//!
//! Routing and autoscaling stay on the coordinator, which mirrors the
//! sequential loop's view rebuilds from the per-shard load reports (merged
//! in replica-id order, so float reductions like the tick's `mean_kv` sum
//! in the identical order). Autoscaler ticks take two rendezvous — a
//! step-only round at `B`, then the decision — because the decision needs
//! post-step state; plain arrival boundaries fuse the boundary step and
//! the next advance into a single round.
//!
//! ## The synchronization window (`--window`)
//!
//! `window > 0` caps how far (in virtual seconds) a shard may run ahead of
//! the last boundary before re-synchronizing; `0` means "free-run to the
//! next interaction". Because boundaries are derived from interactions
//! only, a window-capped round performs no routing, no tick, and no step —
//! it merely splits the advance phase — so results are invariant to the
//! window size *by construction* (property-tested). The cap exists to
//! bound shard run-ahead (and worst-case report staleness) when embedding
//! the loop in a live system; simulation output does not depend on it.
//!
//! ## Deliberate differences from [`Cluster::run`]
//!
//! * `ClusterMetrics::events` counts boundary rounds plus per-shard
//!   internal steps (the sequential loop counts iterations); it is
//!   excluded from [`ClusterMetrics::digest`].
//! * `replica_seconds` is computed analytically (Σ over replicas of
//!   `end − started_at`), which is thread- and window-invariant but can
//!   differ from the sequential running accumulation by float-summation
//!   noise (≪ 1e-6; also excluded from the digest).
//! * `record_event_times` is not supported (`event_times` stays empty) —
//!   there is no single global event sequence to record.
//! * Periodic trace *sampling* is not supported (no `Sample` events are
//!   emitted): a mid-window sample would need fleet-global state that
//!   shards only materialize at boundaries. All other trace events are
//!   emitted at their exact virtual times into per-shard sinks and merged
//!   into the canonical `(time, replica)` order at the end of the run —
//!   compare traces with [`crate::trace::canonical_order`], not emission
//!   order.
//!
//! The tick-at-an-internal-event edge is the one measure-zero caveat: the
//! sequential loop evaluates `t + 1e-12 >= tick` at internal replica
//! events too, so an internal event landing within 1e-12 *before* a tick
//! fires that tick infinitesimally early, whereas here ticks fire at
//! their boundary time. Arrival and tick times are sums of continuous
//! random variates, so an exact collision has probability zero; every
//! differential test seed is pinned.

use super::autoscaler::{Autoscaler, FleetObs};
use super::replica::{Replica, ReplicaState};
use super::router::{ReplicaView, Router};
use super::{Cluster, ClusterCfg, ClusterMetrics, ReplicaStats, ScaleEvent};
use crate::costmodel::calibrate;
use crate::engine::common::ArrivalFeed;
use crate::engine::Engine;
use crate::metrics::{Histogram, RunMetrics};
use crate::trace::{merge_streams, EventKind, TraceEvent, Tracer};
use crate::util::f64_total_key;
use crate::workload::Request;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Arrival source for the fleet loops: a time-sorted sequence of requests
/// consumed boundary by boundary. Implemented by [`SliceArrivals`] (a
/// materialized trace) and [`StreamArrivals`] (any request iterator, e.g.
/// [`crate::workload::generate_iter`], so a 10⁶-request open-loop workload
/// never exists in memory at once).
pub trait Arrivals {
    /// Arrival time of the next request, if any. `&mut` so streaming
    /// sources can pull their look-ahead slot.
    fn peek_time(&mut self) -> Option<f64>;
    /// Replace `out` with every request arriving at or before `t`, in
    /// arrival order.
    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>);
    /// True once no further requests will arrive.
    fn exhausted(&mut self) -> bool;
    /// Requests offered so far — the timeout baseline. For a slice this is
    /// its full length; for a stream it counts requests actually pulled
    /// (a stream cut off by `max_virtual_time` never materializes its
    /// tail, so unpulled requests are not counted as timeouts).
    fn offered(&self) -> usize;
}

/// [`Arrivals`] over a materialized, time-sorted trace.
pub struct SliceArrivals<'a> {
    feed: ArrivalFeed<'a>,
    total: usize,
}

impl<'a> SliceArrivals<'a> {
    pub fn new(trace: &'a [Request]) -> Self {
        SliceArrivals { feed: ArrivalFeed::new(trace), total: trace.len() }
    }
}

impl Arrivals for SliceArrivals<'_> {
    fn peek_time(&mut self) -> Option<f64> {
        self.feed.peek_time()
    }

    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>) {
        out.clear();
        out.extend_from_slice(self.feed.pop_until(t));
    }

    fn exhausted(&mut self) -> bool {
        self.feed.exhausted()
    }

    fn offered(&self) -> usize {
        self.total
    }
}

/// [`Arrivals`] over any time-sorted request iterator (one-request
/// look-ahead buffer; O(1) memory regardless of workload length).
pub struct StreamArrivals<I: Iterator<Item = Request>> {
    it: I,
    peeked: Option<Request>,
    pulled: usize,
}

impl<I: Iterator<Item = Request>> StreamArrivals<I> {
    pub fn new(it: I) -> Self {
        StreamArrivals { it, peeked: None, pulled: 0 }
    }

    fn fill(&mut self) {
        if self.peeked.is_none() {
            self.peeked = self.it.next();
            if self.peeked.is_some() {
                self.pulled += 1;
            }
        }
    }
}

impl<I: Iterator<Item = Request>> Arrivals for StreamArrivals<I> {
    fn peek_time(&mut self) -> Option<f64> {
        self.fill();
        self.peeked.map(|r| r.arrival)
    }

    fn pop_until(&mut self, t: f64, out: &mut Vec<Request>) {
        out.clear();
        loop {
            self.fill();
            match self.peeked {
                Some(r) if r.arrival <= t => {
                    debug_assert!(out.last().map_or(true, |p| p.arrival <= r.arrival));
                    out.push(r);
                    self.peeked = None;
                }
                _ => break,
            }
        }
    }

    fn exhausted(&mut self) -> bool {
        self.fill();
        self.peeked.is_none()
    }

    fn offered(&self) -> usize {
        self.pulled
    }
}

/// One coordinator→worker round (phases run in the listed order).
struct RoundCmd {
    /// Replica ids to drain (scale-down victims), at `drain_t`. Empties
    /// retire immediately at `drain_t`, as in the sequential retire scan.
    drains: Vec<usize>,
    drain_t: f64,
    /// Replicas to create: `(id, started_at)`.
    spawns: Vec<(usize, f64)>,
    /// Boundary step time (`NaN` = no boundary step this round).
    step_t: f64,
    /// `(target id, request)` in arrival order; targets step at `step_t`.
    injections: Vec<(usize, Request)>,
    /// Primed replicas whose first step coincides with `step_t`.
    step_primed: Vec<usize>,
    /// Primed replicas taking their first step strictly inside this
    /// round's advance range: `(first step time, ids)`.
    prime: Option<(f64, Vec<usize>)>,
    /// Advance owned replicas through internal events `< horizon`
    /// (and `≤ max_virtual_time`); `∞` = drain everything schedulable.
    horizon: f64,
}

enum Cmd {
    Round(RoundCmd),
    /// End of run: sync survivors to `last_t`, hand everything back.
    Finish { last_t: f64 },
}

/// One worker→coordinator round report.
struct Report {
    /// Load views of owned *active* replicas, in id order.
    views: Vec<ReplicaView>,
    /// Minimum next-event time over owned in-service replicas (`NaN` =
    /// none) — unfiltered, mirroring the sequential loop's live keys.
    key_min: f64,
    /// Requests completed by this round's steps.
    completed: usize,
    /// Engine `step()` calls performed this round.
    steps: usize,
    /// Latest event time processed in the advance phase (`-∞` = none).
    max_t: f64,
}

/// Everything a worker hands back at [`Cmd::Finish`].
struct WorkerOut {
    /// The shard's replicas (all retired by now), id order.
    replicas: Vec<Replica>,
    /// Mid-run retirements: `(retire time, id, metrics)`.
    done: Vec<(f64, usize, RunMetrics)>,
    /// End-of-run survivors: `(id, metrics)`, id order.
    survivors: Vec<(usize, RunMetrics)>,
    /// The shard tracer's event stream.
    events: Vec<TraceEvent>,
}

/// Find a shard-owned replica by id (shards stay sorted: spawn ids are
/// handed out in increasing order).
fn find(bin: &[Replica], id: usize) -> usize {
    bin.binary_search_by_key(&id, |r| r.id).expect("replica owned by this shard")
}

/// Worker thread body: owns one shard of replicas and executes rounds
/// until [`Cmd::Finish`].
fn worker_loop(
    rx: Receiver<Cmd>,
    tx: Sender<Report>,
    tracer: Tracer,
    cfg: ClusterCfg,
) -> WorkerOut {
    let max_vt = cfg.engine.max_virtual_time;
    let mut bin: Vec<Replica> = Vec::new();
    let mut done: Vec<(f64, usize, RunMetrics)> = Vec::new();
    let mut set: Vec<usize> = Vec::new();

    loop {
        match rx.recv() {
            Ok(Cmd::Round(rc)) => {
                let mut completed = 0usize;
                let mut steps = 0usize;
                let mut max_t = f64::NEG_INFINITY;

                // 1. Drains: mark victims; empties retire at drain_t
                //    (syncing their clocks first, like the sequential
                //    retire scan — a drained-empty step completes nothing).
                for &id in &rc.drains {
                    let i = find(&bin, id);
                    bin[i].drain();
                    if bin[i].drained() {
                        if bin[i].eng.now() < rc.drain_t {
                            let out = bin[i].eng.step(rc.drain_t);
                            debug_assert_eq!(out.completed, 0);
                        }
                        tracer.emit_for(id as u32, rc.drain_t, EventKind::ReplicaRetire);
                        let m = bin[i].retire(rc.drain_t);
                        done.push((rc.drain_t, id, m));
                    }
                }

                // 2. Spawns (initial fleet and autoscaler growth).
                for &(id, at) in &rc.spawns {
                    debug_assert!(bin.last().map_or(true, |r| r.id < id));
                    let mut rep = Replica::new(id, cfg.kind, &cfg.engine, at);
                    rep.eng.set_tracer(tracer.for_replica(id as u32));
                    tracer.emit_for(id as u32, at, EventKind::ReplicaStart);
                    bin.push(rep);
                }

                // 3. Boundary step at step_t: injected ∪ due ∪ primed-at-B,
                //    stepped in id order (bin order == id order).
                if !rc.step_t.is_nan() {
                    let t = rc.step_t;
                    set.clear();
                    for &(id, req) in &rc.injections {
                        let i = find(&bin, id);
                        bin[i].eng.inject(req);
                        bin[i].routed += 1;
                        set.push(i);
                    }
                    for (i, rep) in bin.iter_mut().enumerate() {
                        if rep.in_service() {
                            if let Some(e) = rep.eng.next_event() {
                                debug_assert!(e + 1e-12 >= t, "event missed by advance");
                                if e <= t {
                                    set.push(i);
                                }
                            }
                        }
                    }
                    for &id in &rc.step_primed {
                        set.push(find(&bin, id));
                    }
                    set.sort_unstable();
                    set.dedup();
                    for i in set.drain(..) {
                        let rep = &mut bin[i];
                        if !rep.in_service() {
                            continue;
                        }
                        let out = rep.eng.step(t);
                        completed += out.completed;
                        steps += 1;
                        if rep.drained() {
                            tracer.emit_for(rep.id as u32, t, EventKind::ReplicaRetire);
                            done.push((t, rep.id, rep.retire(t)));
                        }
                    }
                }

                // 4. Prime: first step of freshly spawned replicas at the
                //    fleet's true next event (inside this round's range).
                if let Some((tp, ids)) = &rc.prime {
                    for &id in ids {
                        let i = find(&bin, id);
                        if bin[i].in_service() {
                            let out = bin[i].eng.step(*tp);
                            completed += out.completed;
                            steps += 1;
                            if *tp > max_t {
                                max_t = *tp;
                            }
                        }
                    }
                }

                // 5. Advance: each owned replica processes its own events
                //    below the horizon, at their exact times.
                for rep in bin.iter_mut() {
                    if !rep.in_service() {
                        continue;
                    }
                    while let Some(e) = rep.eng.next_event() {
                        if e >= rc.horizon || e > max_vt {
                            break;
                        }
                        let out = rep.eng.step(e);
                        completed += out.completed;
                        steps += 1;
                        if e > max_t {
                            max_t = e;
                        }
                        if rep.drained() {
                            tracer.emit_for(rep.id as u32, e, EventKind::ReplicaRetire);
                            done.push((e, rep.id, rep.retire(e)));
                            break;
                        }
                    }
                }

                // 6. Report shard state as of the horizon.
                let views: Vec<ReplicaView> =
                    bin.iter().filter(|r| r.is_active()).map(|r| r.view()).collect();
                let mut key_min = f64::NAN;
                for rep in bin.iter_mut() {
                    if rep.in_service() {
                        if let Some(e) = rep.eng.next_event() {
                            if key_min.is_nan() || e < key_min {
                                key_min = e;
                            }
                        }
                    }
                }
                tx.send(Report { views, key_min, completed, steps, max_t })
                    .expect("coordinator alive");
            }
            Ok(Cmd::Finish { last_t }) => {
                let mut survivors: Vec<(usize, RunMetrics)> = Vec::new();
                for rep in bin.iter_mut() {
                    if rep.in_service() {
                        if rep.eng.now() < last_t {
                            rep.eng.step(last_t);
                        }
                        rep.state = ReplicaState::Draining; // permit retire()
                        let m = rep.retire(last_t);
                        rep.retired_at = None; // still in service at end
                        survivors.push((rep.id, m));
                    }
                }
                return WorkerOut { replicas: bin, done, survivors, events: tracer.take() };
            }
            Err(_) => {
                // Coordinator dropped (panic unwind): exit quietly.
                return WorkerOut {
                    replicas: bin,
                    done,
                    survivors: Vec::new(),
                    events: tracer.take(),
                };
            }
        }
    }
}

impl Cluster {
    /// Sharded co-simulation over a materialized trace: digest-identical
    /// to [`Cluster::run`] for any `threads ≥ 1` and any `window ≥ 0`
    /// (see the module docs for the argument and the deliberate
    /// differences: `events`, `replica_seconds`, sampling,
    /// `record_event_times`).
    pub fn run_parallel(&mut self, trace: &[Request], threads: usize, window: f64) -> ClusterMetrics {
        let scaler = self.build_scaler(trace);
        self.run_parallel_core(SliceArrivals::new(trace), scaler, threads, window)
    }

    /// Sharded co-simulation over a streaming workload (the arrivals never
    /// need to exist in memory at once — pair with
    /// [`crate::workload::generate_iter`] /
    /// [`crate::workload::generate_bursty_iter`] for 10⁶-request runs).
    ///
    /// Autoscaling calibrates replica capacity from mean request lengths,
    /// which a stream cannot be scanned for — pass `mean_hint =
    /// Some((mean_prompt, mean_output))` when `cfg.autoscale` is set
    /// (e.g. from [`crate::workload::Dataset`] statistics); without a
    /// hint the capacity model falls back to unit lengths.
    pub fn run_parallel_stream<I: Iterator<Item = Request>>(
        &mut self,
        requests: I,
        mean_hint: Option<(f64, f64)>,
        threads: usize,
        window: f64,
    ) -> ClusterMetrics {
        let scaler = self.cfg.autoscale.map(|acfg| {
            let cost = calibrate(&self.cfg.engine.gpu);
            let (mp, mo) = mean_hint.unwrap_or((1.0, 1.0));
            Autoscaler::new(
                acfg,
                super::autoscaler::predict_replica_rate(&cost, &self.cfg.engine, mp, mo),
            )
        });
        self.run_parallel_core(StreamArrivals::new(requests), scaler, threads, window)
    }

    fn run_parallel_core<A: Arrivals>(
        &mut self,
        mut arrivals: A,
        mut scaler: Option<Autoscaler>,
        threads: usize,
        window: f64,
    ) -> ClusterMetrics {
        assert!(threads >= 1, "run_parallel needs at least one worker");
        assert!(window >= 0.0, "window must be nonnegative");
        let cfg = self.cfg.clone();
        let n0 = match &cfg.autoscale {
            Some(a) => cfg.replicas.clamp(a.min_replicas, a.max_replicas),
            None => cfg.replicas,
        };
        self.replicas = Vec::new();
        self.router = Router::new(cfg.policy);
        self.event_times.clear();
        let max_vt = cfg.engine.max_virtual_time;
        let mut next_tick = scaler.as_ref().map(|s| s.cfg.interval);

        // Coordinator bookkeeping (mirrors the sequential loop's counters).
        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut peak_replicas = n0;
        let mut active_cnt = n0;
        let mut pending_total = 0usize;
        let mut arrivals_since_tick = 0usize;
        let mut next_id = n0;
        let mut last_t = 0.0f64;
        let mut rounds = 0usize;
        let mut steps_total = 0usize;
        // Merged active-replica views as of the current horizon, id order.
        let mut views: Vec<ReplicaView> = Vec::new();
        let mut keys_min = f64::NAN;
        // Replicas awaiting their first step, and when it lands: the
        // fleet's next event as of their spawn (min of next arrival, next
        // tick, and every shard's key minimum) — fixed at spawn time, since
        // nothing can schedule an earlier event afterwards.
        let mut primed: Vec<usize> = (0..n0).collect();
        let mut prime_t = f64::NAN; // resolved at the first boundary probe
        // Directives decided at a tick, applied in the next round.
        let mut pending_spawns: Vec<(usize, f64)> = Vec::new();
        let mut pending_drains: Vec<usize> = Vec::new();
        let mut drain_t = 0.0f64;
        let mut arr_buf: Vec<Request> = Vec::new();
        let mut kv_buf: Vec<f64> = Vec::new();
        let mut outs: Vec<WorkerOut> = Vec::new();

        // Initial fleet spawns through the same directive path as
        // autoscaler growth, so workers own replica construction uniformly.
        // Synthesize their (empty) views up front: a trace whose first
        // arrival lands exactly at t = 0 routes before any worker report
        // exists (fresh engines report pending 0 / kv 0.0 anyway).
        pending_spawns.extend((0..n0).map(|i| (i, 0.0)));
        views.extend((0..n0).map(|i| ReplicaView {
            index: i as u32,
            pending: 0,
            kv_usage: 0.0,
        }));

        std::thread::scope(|s| {
            let mut txs: Vec<Sender<Cmd>> = Vec::with_capacity(threads);
            let mut rxs: Vec<Receiver<Report>> = Vec::with_capacity(threads);
            let mut handles = Vec::with_capacity(threads);
            for _ in 0..threads {
                let (ctx, crx) = channel::<Cmd>();
                let (rtx, rrx) = channel::<Report>();
                let shard_tracer = self.tracer.fork_sink();
                let wcfg = cfg.clone();
                handles.push(s.spawn(move || worker_loop(crx, rtx, shard_tracer, wcfg)));
                txs.push(ctx);
                rxs.push(rrx);
            }

            // Broadcast one round (partitioning directives by shard) and
            // merge the reports back into the coordinator's state.
            macro_rules! round {
                ($step_t:expr, $injections:expr, $step_primed:expr, $horizon:expr) => {{
                    let step_primed: Vec<usize> = $step_primed;
                    let injections: Vec<(usize, Request)> = $injections;
                    let horizon: f64 = $horizon;
                    // Flush a pending prime that lands strictly inside
                    // this round's advance range (never beyond the
                    // simulation horizon — the sequential loop breaks
                    // before stepping anything past max_virtual_time).
                    let prime_now = if !primed.is_empty() && prime_t < horizon && prime_t <= max_vt
                    {
                        Some((prime_t, std::mem::take(&mut primed)))
                    } else {
                        None
                    };
                    for (w, tx) in txs.iter().enumerate() {
                        let rc = RoundCmd {
                            drains: pending_drains
                                .iter()
                                .copied()
                                .filter(|id| id % threads == w)
                                .collect(),
                            drain_t,
                            spawns: pending_spawns
                                .iter()
                                .copied()
                                .filter(|(id, _)| id % threads == w)
                                .collect(),
                            step_t: $step_t,
                            injections: injections
                                .iter()
                                .copied()
                                .filter(|(id, _)| id % threads == w)
                                .collect(),
                            step_primed: step_primed
                                .iter()
                                .copied()
                                .filter(|id| id % threads == w)
                                .collect(),
                            prime: prime_now.as_ref().map(|(tp, ids)| {
                                (*tp, ids.iter().copied().filter(|id| id % threads == w).collect())
                            }),
                            horizon,
                        };
                        tx.send(Cmd::Round(rc)).expect("worker alive");
                    }
                    pending_drains.clear();
                    pending_spawns.clear();
                    rounds += 1;
                    views.clear();
                    keys_min = f64::NAN;
                    for rx in &rxs {
                        let rep = rx.recv().expect("worker alive");
                        views.extend(rep.views);
                        if !rep.key_min.is_nan()
                            && (keys_min.is_nan() || rep.key_min < keys_min)
                        {
                            keys_min = rep.key_min;
                        }
                        pending_total -= rep.completed;
                        steps_total += rep.steps;
                        if rep.max_t > last_t {
                            last_t = rep.max_t;
                        }
                    }
                    views.sort_unstable_by_key(|v| v.index);
                }};
            }

            // Workers have processed every event strictly below cur_h.
            let mut cur_h = 0.0f64;
            loop {
                if arrivals.exhausted() && pending_total == 0 {
                    // Apply directives left by a just-decided scale action
                    // (empty victims must still retire at the decision
                    // time, as in the sequential retire scan).
                    if !pending_drains.is_empty() || !pending_spawns.is_empty() {
                        round!(f64::NAN, Vec::new(), Vec::new(), cur_h);
                    }
                    break;
                }

                // Next interaction boundary: earliest arrival or tick.
                let mut b = f64::INFINITY;
                if let Some(a) = arrivals.peek_time() {
                    b = b.min(a);
                }
                if let Some(tk) = next_tick {
                    b = b.min(tk);
                }

                if !b.is_finite() || b > max_vt {
                    // No further interactions inside the horizon: drain
                    // everything schedulable (workers stop at
                    // max_virtual_time), then stop.
                    if cur_h.is_infinite() {
                        break;
                    }
                    round!(f64::NAN, Vec::new(), Vec::new(), f64::INFINITY);
                    cur_h = f64::INFINITY;
                    continue;
                }

                // Initial replicas resolve their first-step time at the
                // first probe (no shard keys exist before any step).
                if prime_t.is_nan() && !primed.is_empty() {
                    prime_t = b;
                }

                if cur_h < b {
                    // Window-capped advance toward the boundary: no
                    // routing, no tick, no step — output-invariant.
                    let h = if window > 0.0 { (cur_h + window).min(b) } else { b };
                    round!(f64::NAN, Vec::new(), Vec::new(), h);
                    cur_h = h;
                    if keys_min.is_nan() && arrivals.exhausted() && pending_total > 0 {
                        break; // stall: nothing schedulable, nothing arriving
                    }
                    continue;
                }

                // Boundary round at B == cur_h: route arrivals against the
                // merged post-advance views, rebuilding the load picture
                // per arrival exactly like the sequential loop (injections
                // bump only the target's pending; KV moves only on steps).
                let is_tick = next_tick.is_some_and(|tk| b + 1e-12 >= tk);
                arrivals.pop_until(b, &mut arr_buf);
                let mut injections: Vec<(usize, Request)> = Vec::with_capacity(arr_buf.len());
                for r in &arr_buf {
                    let target = self.router.route(&views, r);
                    self.trace_route(r, target, &views, b);
                    if let Ok(pos) = views.binary_search_by_key(&(target as u32), |v| v.index)
                    {
                        views[pos].pending += 1;
                    }
                    injections.push((target, *r));
                    pending_total += 1;
                    arrivals_since_tick += 1;
                }
                let step_primed = if !primed.is_empty() && prime_t == b {
                    std::mem::take(&mut primed)
                } else {
                    Vec::new()
                };
                last_t = last_t.max(b);

                if is_tick {
                    // Rendezvous 1: boundary step only (horizon B ⇒ no
                    // advance), so the decision sees post-step state.
                    round!(b, injections, step_primed, b);
                    let sc = scaler.as_mut().expect("tick implies scaler");
                    let tk = next_tick.expect("tick implies schedule");
                    kv_buf.clear();
                    kv_buf.extend(views.iter().map(|v| v.kv_usage));
                    let obs = FleetObs {
                        now: b,
                        arrival_rate: arrivals_since_tick as f64 / sc.cfg.interval,
                        active_replicas: views.len(),
                        total_pending: pending_total,
                        mean_kv: crate::util::mean(&kv_buf),
                        max_kv: kv_buf.iter().fold(0.0f64, |a, &v| a.max(v)),
                    };
                    if let Some(target) = sc.decide(&obs) {
                        let from = views.len();
                        self.tracer.emit_for(
                            crate::trace::FLEET,
                            b,
                            EventKind::Scale { from, to: target },
                        );
                        scale_events.push(ScaleEvent { time: b, from, to: target });
                        if target > from {
                            for _ in from..target {
                                pending_spawns.push((next_id, b));
                                primed.push(next_id);
                                // Fresh replicas are routable immediately:
                                // synthesize their (empty) views until the
                                // next report includes them.
                                views.push(ReplicaView {
                                    index: next_id as u32,
                                    pending: 0,
                                    kv_usage: 0.0,
                                });
                                next_id += 1;
                            }
                            // First step at the fleet's next event, fixed
                            // now: nothing can schedule an earlier one.
                            prime_t = f64::INFINITY;
                            if let Some(a) = arrivals.peek_time() {
                                prime_t = prime_t.min(a);
                            }
                            prime_t = prime_t.min(tk + sc.cfg.interval);
                            if !keys_min.is_nan() {
                                prime_t = prime_t.min(keys_min);
                            }
                        } else {
                            // Drain the least-loaded actives (same
                            // (pending, id) order as the sequential
                            // rescale); they leave the routable set now
                            // and retire once empty.
                            let mut by_load: Vec<(u32, u32)> =
                                views.iter().map(|v| (v.pending, v.index)).collect();
                            by_load.sort_unstable();
                            for &(_, idx) in by_load.iter().take(from - target) {
                                pending_drains.push(idx as usize);
                                self.tracer.emit_for(idx, b, EventKind::ReplicaDrain);
                                if let Ok(pos) =
                                    views.binary_search_by_key(&idx, |v| v.index)
                                {
                                    views.remove(pos);
                                }
                            }
                            drain_t = b;
                        }
                        active_cnt = target;
                    }
                    next_tick = Some(tk + sc.cfg.interval);
                    arrivals_since_tick = 0;
                } else {
                    // Plain arrival boundary: fuse the boundary step with
                    // the advance toward the next interaction.
                    let mut nb = f64::INFINITY;
                    if let Some(a) = arrivals.peek_time() {
                        nb = nb.min(a);
                    }
                    if let Some(tk) = next_tick {
                        nb = nb.min(tk);
                    }
                    let h = if window > 0.0 { (b + window).min(nb) } else { nb };
                    round!(b, injections, step_primed, h);
                    cur_h = h;
                }

                peak_replicas = peak_replicas.max(active_cnt);
                if keys_min.is_nan() && arrivals.exhausted() && pending_total > 0 {
                    // Stall: nothing schedulable, nothing arriving. Apply
                    // any directives from this boundary's tick first.
                    if !pending_drains.is_empty() || !pending_spawns.is_empty() {
                        round!(f64::NAN, Vec::new(), Vec::new(), cur_h);
                    }
                    break;
                }
            }

            for tx in &txs {
                tx.send(Cmd::Finish { last_t }).expect("worker alive");
            }
            for h in handles {
                outs.push(h.join().expect("worker panicked"));
            }
        });

        // Merge per-shard results in the sequential loop's order:
        // mid-run retirements chronologically (ties in id order — the
        // sequential retire scan walks ids), then survivors in id order.
        let mut fleet = RunMetrics::default();
        let mut ttft_hist = Histogram::new();
        let mut tbt_hist = Histogram::new();
        let mut done: Vec<(f64, usize, RunMetrics)> = Vec::new();
        let mut survivors: Vec<(usize, RunMetrics)> = Vec::new();
        let mut streams: Vec<Vec<TraceEvent>> = Vec::new();
        for out in outs {
            done.extend(out.done);
            survivors.extend(out.survivors);
            self.replicas.extend(out.replicas);
            streams.push(out.events);
        }
        done.sort_by_key(|&(t, id, _)| (f64_total_key(t), id));
        survivors.sort_by_key(|&(id, _)| id);
        for (_, _, m) in done {
            ttft_hist.merge(&m.ttft_histogram());
            tbt_hist.merge(&m.tbt_histogram());
            fleet.merge(m);
        }
        for (_, m) in survivors {
            ttft_hist.merge(&m.ttft_histogram());
            tbt_hist.merge(&m.tbt_histogram());
            fleet.merge(m);
        }
        fleet.timeouts = arrivals.offered() - fleet.records.len();
        self.replicas.sort_by_key(|r| r.id);

        // Fold the per-shard trace streams back into the cluster tracer in
        // canonical (time, replica) order.
        if self.tracer.enabled() {
            streams.insert(0, self.tracer.take());
            self.tracer.absorb(merge_streams(streams));
        }

        // Replica-seconds analytically (window/thread-invariant; within
        // float noise of the sequential accumulation — digest-excluded).
        let replica_seconds: f64 = self
            .replicas
            .iter()
            .map(|r| r.retired_at.unwrap_or(last_t) - r.started_at)
            .sum();

        let replicas = self
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                id: r.id,
                routed: r.routed as usize,
                completed: r.eng.completed(),
                started_at: r.started_at,
                retired_at: r.retired_at,
            })
            .collect();

        ClusterMetrics {
            fleet,
            replicas,
            scale_events,
            suppressed_scales: scaler.as_ref().map_or(0, |s| s.suppressed),
            replica_seconds,
            peak_replicas,
            events: rounds + steps_total,
            ttft_hist,
            tbt_hist,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineCfg, EngineKind};
    use crate::model::ModelConfig;
    use crate::workload::{generate, generate_iter, Dataset};

    fn ecfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn parallel_matches_sequential_digest() {
        let trace = generate(Dataset::Mixed, 40, 6.0, 11);
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg(),
            3,
            super::super::RoutingPolicy::JoinShortestQueue,
        );
        let seq = Cluster::new(cc.clone()).run(&trace);
        for threads in [1usize, 2, 4] {
            let par = Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0);
            assert_eq!(seq.digest(), par.digest(), "threads={threads}");
        }
    }

    #[test]
    fn window_size_does_not_change_results() {
        let trace = generate(Dataset::ShareGpt, 40, 8.0, 23);
        let cc = ClusterCfg::new(
            EngineKind::Vllm,
            ecfg(),
            4,
            super::super::RoutingPolicy::LeastKvPressure,
        );
        let base = Cluster::new(cc.clone()).run_parallel(&trace, 2, 0.0);
        for window in [0.05f64, 0.5, 10.0] {
            let w = Cluster::new(cc.clone()).run_parallel(&trace, 2, window);
            assert_eq!(base.digest(), w.digest(), "window={window}");
        }
    }

    #[test]
    fn stream_arrivals_match_slice_arrivals() {
        // The streaming front-end must be behaviorally identical to the
        // materialized trace (autoscale off: capacity calibration needs
        // trace statistics a stream cannot provide).
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            ecfg(),
            2,
            super::super::RoutingPolicy::RoundRobin,
        );
        let trace = generate(Dataset::ShareGpt, 50, 10.0, 9);
        let by_slice = Cluster::new(cc.clone()).run_parallel(&trace, 2, 0.0);
        let by_stream = Cluster::new(cc).run_parallel_stream(
            generate_iter(Dataset::ShareGpt, 50, 10.0, 9),
            None,
            2,
            0.0,
        );
        assert_eq!(by_slice.digest(), by_stream.digest());
        assert_eq!(by_slice.fleet.records.len(), by_stream.fleet.records.len());
    }

    #[test]
    fn stream_arrivals_pop_in_order() {
        let trace = generate(Dataset::Mixed, 20, 5.0, 3);
        let mut s = StreamArrivals::new(trace.iter().copied());
        let mut a = SliceArrivals::new(&trace);
        let mut sb = Vec::new();
        let mut ab = Vec::new();
        for t in [0.5f64, 1.5, 3.0, 100.0] {
            assert_eq!(s.peek_time(), a.peek_time());
            s.pop_until(t, &mut sb);
            a.pop_until(t, &mut ab);
            assert_eq!(sb.len(), ab.len(), "t={t}");
            assert!(sb.iter().zip(&ab).all(|(x, y)| x.id == y.id));
        }
        assert!(s.exhausted() && a.exhausted());
        assert_eq!(s.offered(), 20);
        assert_eq!(a.offered(), 20);
    }
}
