//! Fleet-wide prefix-cache tier: deterministic, router-visible prefix reuse.
//!
//! The single-engine `sched::RadixCache` models prefix reuse as a private
//! probabilistic draw — invisible to the router, so the fleet cannot trade
//! prefix locality against load balance. This module makes reuse a
//! *mechanism* instead of a distribution:
//!
//! * every replica owns a [`PrefixStore`] — the set of prefix chains whose
//!   KV is resident on that GPU, capacity-bounded in tokens with
//!   deterministic LRU eviction, and coupled to the replica's KV pressure
//!   (above `kv_watermark` the store's budget halves, shedding cold
//!   prefixes before the engine would have to preempt decodes);
//! * a shared fleet tier (LMCache-style) remembers the longest prefix any
//!   replica has computed per chain; a replica missing locally can *fetch*
//!   it over a [`TierCfg`] transfer class (NVLink / RDMA / TCP) instead of
//!   recomputing — the fetch cost is charged as equivalent prefill tokens,
//!   so a tier hit lands strictly between a local hit (free) and a miss
//!   (full recompute) whenever the link is faster than recompute;
//! * the whole state lives coordinator-side in [`PrefixState`]: lookups are
//!   pure, mutation happens only at routing commit ([`PrefixState::admit`]),
//!   and every decision is a deterministic function of the routed sequence —
//!   which is exactly what keeps the three fleet loops digest-identical.
//!
//! The router's `PrefixAware` policy scores replicas by resident-prefix
//! tokens minus a load penalty (see `cluster::router`); the winning
//! replica's engine is injected with the *effective* prompt computed here
//! (best of local hit / tier fetch / miss).

use crate::workload::Request;
use std::collections::HashMap;

/// A tier transfer class: bandwidth in bytes/s plus a flat latency floor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierCfg {
    /// Link bandwidth (bytes/s).
    pub bw: f64,
    /// Per-fetch latency floor (seconds).
    pub lat: f64,
}

impl TierCfg {
    /// Intra-node NVLink-class fabric (~400 GB/s, ~2 µs).
    pub fn nvlink() -> Self {
        TierCfg { bw: 400e9, lat: 2e-6 }
    }

    /// Cross-node RDMA-class fabric (~25 GB/s, ~10 µs).
    pub fn rdma() -> Self {
        TierCfg { bw: 25e9, lat: 10e-6 }
    }

    /// Commodity TCP-class fabric (~2.5 GB/s, ~200 µs).
    pub fn tcp() -> Self {
        TierCfg { bw: 2.5e9, lat: 200e-6 }
    }

    pub fn by_name(name: &str) -> Option<TierCfg> {
        match name.to_ascii_lowercase().as_str() {
            "nvlink" => Some(Self::nvlink()),
            "rdma" => Some(Self::rdma()),
            "tcp" => Some(Self::tcp()),
            _ => None,
        }
    }
}

/// Fleet prefix-cache configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixCacheCfg {
    /// Resident prefix tokens each replica's store may hold.
    pub capacity: usize,
    /// Shared fleet tier; `None` = local stores only (miss on remote).
    pub tier: Option<TierCfg>,
    /// KV bytes per cached token (sizes tier transfers).
    pub kv_bytes_per_token: f64,
    /// Prefill throughput (tokens/s) used to convert transfer seconds into
    /// equivalent prefill tokens — the common currency of the cost model.
    pub prefill_tps: f64,
    /// KV-usage watermark above which a replica's store budget halves.
    pub kv_watermark: f64,
    /// Routing-score load penalty (resident tokens one queued request is
    /// worth; see the `PrefixAware` score in `cluster::router`).
    pub load_penalty: f64,
}

impl Default for PrefixCacheCfg {
    fn default() -> Self {
        PrefixCacheCfg {
            capacity: 1 << 18,
            tier: Some(TierCfg::rdma()),
            kv_bytes_per_token: 65_536.0,
            prefill_tps: 20_000.0,
            kv_watermark: 0.90,
            load_penalty: 64.0,
        }
    }
}

impl PrefixCacheCfg {
    /// Cost of fetching `shared` prefix tokens over `tier`, expressed as
    /// equivalent prefill tokens (≥ 1: a fetch is never free).
    pub fn xfer_tokens(&self, tier: &TierCfg, shared: usize) -> usize {
        let secs = tier.lat + shared as f64 * self.kv_bytes_per_token / tier.bw;
        ((secs * self.prefill_tps).ceil() as usize).max(1)
    }

    /// Store budget under the KV watermark coupling: KV pressure at or above
    /// the watermark halves the prefix budget (decode KV outranks cache).
    pub fn effective_capacity(&self, kv_usage: f64) -> usize {
        if kv_usage >= self.kv_watermark {
            self.capacity / 2
        } else {
            self.capacity
        }
    }
}

/// How a routed request's prefix resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixHit {
    /// Shared prefix resident on the routed replica — reuse is free.
    Local,
    /// Fetched from the fleet tier — reuse pays transfer, not recompute.
    Tier,
    /// Chain known but not reachable cheaper than recompute.
    Miss,
    /// No shared prefix to look up (chain head or untagged request).
    Cold,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    chain: u32,
    resident: u32,
    /// Logical LRU clock value of the last touch.
    touched: u64,
}

/// Per-replica resident-prefix set: token-capacity-bounded, deterministic
/// LRU. Stores are small (one entry per live chain routed here), so linear
/// scans beat pointer-chased LRU lists and are trivially deterministic.
#[derive(Debug, Clone, Default)]
pub struct PrefixStore {
    entries: Vec<Entry>,
    total: u64,
    tick: u64,
}

impl PrefixStore {
    /// Resident prefix tokens for `chain` (0 if absent). Pure — never
    /// touches LRU state.
    pub fn resident(&self, chain: u32) -> usize {
        self.entries
            .iter()
            .find(|e| e.chain == chain)
            .map_or(0, |e| e.resident as usize)
    }

    /// Total resident tokens across chains.
    pub fn total_tokens(&self) -> usize {
        self.total as usize
    }

    /// Number of resident chains.
    pub fn chains(&self) -> usize {
        self.entries.len()
    }

    /// Admit (or touch) `chain` with a prompt of `len` tokens: residency
    /// grows monotonically to `max(resident, len)`, the entry becomes
    /// most-recently-used, and least-recently-used *other* chains are
    /// evicted until the store fits `capacity`. Returns the eviction count.
    ///
    /// A `len ≤ resident` admit under capacity is a **pure LRU touch** — no
    /// growth, no eviction — which is what makes same-instant prefix-pinned
    /// arrivals commute (the rendezvous-batching blind-probe contract, see
    /// `cluster::parallel`).
    pub fn admit(&mut self, chain: u32, len: usize, capacity: usize) -> usize {
        self.tick += 1;
        let len = len.min(u32::MAX as usize) as u32;
        match self.entries.iter_mut().find(|e| e.chain == chain) {
            Some(e) => {
                if len > e.resident {
                    self.total += (len - e.resident) as u64;
                    e.resident = len;
                }
                e.touched = self.tick;
            }
            None => {
                self.entries.push(Entry { chain, resident: len, touched: self.tick });
                self.total += len as u64;
            }
        }
        let mut evictions = 0usize;
        while self.total > capacity as u64 && self.entries.len() > 1 {
            // LRU victim: smallest (touched, chain). The just-touched entry
            // holds the max tick, so it is never the victim here.
            let mut victim = 0usize;
            for i in 1..self.entries.len() {
                let (a, b) = (&self.entries[i], &self.entries[victim]);
                if (a.touched, a.chain) < (b.touched, b.chain) {
                    victim = i;
                }
            }
            self.total -= self.entries[victim].resident as u64;
            self.entries.remove(victim);
            evictions += 1;
        }
        if self.total > capacity as u64 {
            // A lone chain larger than the whole budget: trim it in place
            // (the tail of an over-long prefix is dropped, the head stays).
            let e = &mut self.entries[0];
            self.total = capacity as u64;
            e.resident = capacity as u32;
            if capacity == 0 {
                self.entries.clear();
                evictions += 1;
            }
        }
        evictions
    }
}

/// Fleet-wide counters surfaced through `ClusterMetrics` (and folded into
/// the digest — they are a deterministic function of the routed sequence,
/// so all three fleet loops must agree on every field).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Routed requests that had a shared prefix to look up.
    pub lookups: u64,
    pub local_hits: u64,
    pub tier_hits: u64,
    pub misses: u64,
    /// Chains evicted from per-replica stores.
    pub evictions: u64,
    /// Prefill tokens not recomputed (local savings + tier savings net of
    /// transfer cost).
    pub tokens_saved: u64,
}

impl PrefixStats {
    /// Fleet hit rate (local + tier over lookups; 0 with no lookups).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        (self.local_hits + self.tier_hits) as f64 / self.lookups as f64
    }
}

/// Coordinator-side prefix state: one [`PrefixStore`] per replica id plus
/// the shared fleet tier and the fleet counters. Replica ids are never
/// reused, so retired replicas' stores simply go inert.
#[derive(Debug, Clone)]
pub struct PrefixState {
    pub cfg: PrefixCacheCfg,
    stores: Vec<PrefixStore>,
    /// chain → longest prefix any replica has published.
    tier: HashMap<u32, u32>,
    pub stats: PrefixStats,
}

impl PrefixState {
    pub fn new(cfg: PrefixCacheCfg) -> Self {
        PrefixState { cfg, stores: Vec::new(), tier: HashMap::new(), stats: PrefixStats::default() }
    }

    /// Resident prefix tokens for `chain` on replica `rep` (pure).
    pub fn resident(&self, rep: usize, chain: u32) -> usize {
        self.stores.get(rep).map_or(0, |s| s.resident(chain))
    }

    /// Longest prefix the fleet tier can serve for `chain` (0 when the tier
    /// is disabled).
    pub fn tier_len(&self, chain: u32) -> usize {
        if self.cfg.tier.is_none() {
            return 0;
        }
        self.tier.get(&chain).map_or(0, |&l| l as usize)
    }

    /// The replica's store (for tests / diagnostics).
    pub fn store(&self, rep: usize) -> Option<&PrefixStore> {
        self.stores.get(rep)
    }

    /// Effective prefill length if `req` were routed to `rep`, and how the
    /// prefix would resolve. Pure — routing probes may call this freely.
    ///
    /// `eff = min(plen − local, plen − tier + xfer(tier), plen).max(1)`
    /// with ties preferring the local path.
    pub fn effective_prompt(&self, rep: usize, req: &Request) -> (usize, PrefixHit) {
        let plen = req.plen();
        let s = req.shared();
        if req.prefix == 0 || s == 0 {
            return (plen, PrefixHit::Cold);
        }
        let local = self.resident(rep, req.prefix).min(s);
        let eff_local = plen - local;
        if let Some(t) = self.cfg.tier {
            let st = self.tier_len(req.prefix).min(s);
            if st > local {
                let eff_tier = plen - st + self.cfg.xfer_tokens(&t, st);
                if eff_tier < eff_local {
                    return (eff_tier.max(1), PrefixHit::Tier);
                }
            }
        }
        if local > 0 {
            (eff_local.max(1), PrefixHit::Local)
        } else {
            (plen, PrefixHit::Miss)
        }
    }

    /// True when routing `req` to `rep` would be a *pure LRU touch*: the
    /// chain is fully resident (covers the whole prompt, so no growth), the
    /// replica's KV pressure is below the watermark, and the store sits
    /// within the *halved* budget — so the admit cannot evict under either
    /// capacity, whatever KV usage it is later committed with. That last
    /// clause is what makes the touch exact for rendezvous batching: the
    /// parallel coordinator probes with boundary-time KV views while the
    /// sequential loop commits with instant-time ones, and a touch that is
    /// a no-op under both budgets is identical under both views.
    pub fn pure_touch(&self, rep: usize, req: &Request, kv_usage: f64) -> bool {
        req.prefix != 0
            && kv_usage < self.cfg.kv_watermark
            && self.resident(rep, req.prefix) >= req.plen()
            && self
                .stores
                .get(rep)
                .is_some_and(|s| s.total_tokens() <= self.cfg.capacity / 2)
    }

    /// Commit `req`'s routing to `rep`: classify against current state,
    /// account the fleet counters, admit the full prompt into the replica's
    /// store (watermark-coupled capacity from the routing-time `kv_usage`
    /// view), and publish the chain to the tier. Returns the effective
    /// prefill length to inject and the hit class.
    pub fn admit(&mut self, rep: usize, req: &Request, kv_usage: f64) -> (usize, PrefixHit) {
        let (eff, hit) = self.effective_prompt(rep, req);
        let plen = req.plen();
        if hit != PrefixHit::Cold {
            self.stats.lookups += 1;
            match hit {
                PrefixHit::Local => self.stats.local_hits += 1,
                PrefixHit::Tier => self.stats.tier_hits += 1,
                PrefixHit::Miss => self.stats.misses += 1,
                PrefixHit::Cold => unreachable!(),
            }
            self.stats.tokens_saved += (plen - eff) as u64;
        }
        if req.prefix != 0 {
            if rep >= self.stores.len() {
                self.stores.resize_with(rep + 1, PrefixStore::default);
            }
            let cap = self.cfg.effective_capacity(kv_usage);
            let ev = self.stores[rep].admit(req.prefix, plen, cap);
            self.stats.evictions += ev as u64;
            if self.cfg.tier.is_some() {
                let e = self.tier.entry(req.prefix).or_insert(0);
                *e = (*e).max(plen.min(u32::MAX as usize) as u32);
            }
        }
        (eff, hit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, plen: u32, prefix: u32, shared: u16) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: plen,
            output_len: 4,
            tenant: 0,
            prefix,
            shared_len: shared,
        }
    }

    #[test]
    fn tier_presets_and_names() {
        for name in ["nvlink", "rdma", "tcp"] {
            let t = TierCfg::by_name(name).unwrap();
            assert!(t.bw > 0.0 && t.lat > 0.0);
        }
        assert!(TierCfg::by_name("carrier-pigeon").is_none());
        // Faster fabric → cheaper fetch for the same prefix.
        let cfg = PrefixCacheCfg::default();
        let nv = cfg.xfer_tokens(&TierCfg::nvlink(), 4096);
        let rd = cfg.xfer_tokens(&TierCfg::rdma(), 4096);
        let tc = cfg.xfer_tokens(&TierCfg::tcp(), 4096);
        assert!(nv < rd && rd < tc, "xfer {nv} {rd} {tc}");
        assert!(cfg.xfer_tokens(&TierCfg::nvlink(), 0) >= 1, "a fetch is never free");
    }

    #[test]
    fn store_grows_touches_and_evicts_lru() {
        let mut s = PrefixStore::default();
        assert_eq!(s.admit(1, 100, 1000), 0);
        assert_eq!(s.admit(2, 200, 1000), 0);
        assert_eq!(s.resident(1), 100);
        // Same-chain admit with a longer prompt grows residency.
        assert_eq!(s.admit(1, 150, 1000), 0);
        assert_eq!(s.resident(1), 150);
        assert_eq!(s.total_tokens(), 350);
        // Shorter re-admit is a pure touch: no growth.
        s.admit(1, 50, 1000);
        assert_eq!(s.resident(1), 150);
        // Chain 2 is now LRU; overflow evicts it, not the touched chain 1.
        assert_eq!(s.admit(3, 700, 1000), 1);
        assert_eq!(s.resident(2), 0);
        assert_eq!(s.resident(1), 150);
        assert!(s.total_tokens() <= 1000);
    }

    #[test]
    fn store_never_exceeds_capacity() {
        let mut s = PrefixStore::default();
        for i in 0..200u32 {
            s.admit(i + 1, 64 + (i as usize % 7) * 32, 512);
            assert!(s.total_tokens() <= 512, "over capacity after admit {i}");
        }
        // A lone oversized chain is trimmed to the budget.
        let mut s = PrefixStore::default();
        s.admit(9, 4096, 512);
        assert_eq!(s.total_tokens(), 512);
        assert_eq!(s.resident(9), 512);
        // Zero budget keeps nothing.
        let mut s = PrefixStore::default();
        s.admit(9, 100, 0);
        assert_eq!(s.total_tokens(), 0);
        assert_eq!(s.chains(), 0);
    }

    #[test]
    fn lru_order_is_deterministic() {
        let run = || {
            let mut s = PrefixStore::default();
            let mut evs = Vec::new();
            for step in 0..50usize {
                let chain = (step % 7 + 1) as u32;
                evs.push(s.admit(chain, 120, 600));
            }
            (evs, s.total_tokens())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn effective_prompt_orders_local_tier_miss() {
        let mut st = PrefixState::new(PrefixCacheCfg::default());
        let r = req(0, 1000, 7, 800);
        // Nothing anywhere: miss (and cold for untagged requests).
        assert_eq!(st.effective_prompt(0, &r), (1000, PrefixHit::Miss));
        assert_eq!(st.effective_prompt(0, &req(1, 1000, 0, 0)).1, PrefixHit::Cold);
        // Seed replica 0 with the chain (cold head turn, then resident).
        st.admit(0, &req(2, 1000, 7, 0), 0.0);
        let (eff_local, h) = st.effective_prompt(0, &r);
        assert_eq!(h, PrefixHit::Local);
        assert_eq!(eff_local, 200);
        // Replica 1 has nothing local but can fetch from the tier.
        let (eff_tier, h) = st.effective_prompt(1, &r);
        assert_eq!(h, PrefixHit::Tier);
        assert!(
            eff_local < eff_tier && eff_tier < 1000,
            "tier cost must sit strictly between local hit and miss: {eff_local} < {eff_tier} < 1000"
        );
        // Tier disabled: remote replica pays full recompute.
        let no_tier = PrefixCacheCfg { tier: None, ..PrefixCacheCfg::default() };
        let mut st2 = PrefixState::new(no_tier);
        st2.admit(0, &req(2, 1000, 7, 0), 0.0);
        assert_eq!(st2.effective_prompt(1, &r), (1000, PrefixHit::Miss));
    }

    #[test]
    fn admit_accounts_stats_and_watermark() {
        let mut st = PrefixState::new(PrefixCacheCfg {
            capacity: 1024,
            ..PrefixCacheCfg::default()
        });
        st.admit(0, &req(0, 600, 1, 0), 0.0); // cold head: no lookup
        assert_eq!(st.stats.lookups, 0);
        let (eff, hit) = st.admit(0, &req(1, 700, 1, 400), 0.0);
        assert_eq!(hit, PrefixHit::Local);
        assert_eq!(eff, 300);
        assert_eq!(st.stats.local_hits, 1);
        assert_eq!(st.stats.tokens_saved, 400);
        // Above the watermark the budget halves: a second large chain must
        // evict the first.
        let ev_before = st.stats.evictions;
        st.admit(0, &req(2, 500, 2, 0), 0.95);
        assert!(st.stats.evictions > ev_before, "watermark shrink must evict");
        assert!(st.store(0).unwrap().total_tokens() <= 512);
        // pure_touch needs full residency, sub-watermark KV, *and* enough
        // headroom that the admit is a no-op under the halved budget too.
        let mut st = PrefixState::new(PrefixCacheCfg {
            capacity: 1024,
            ..PrefixCacheCfg::default()
        });
        st.admit(0, &req(3, 400, 3, 0), 0.0); // total 400 ≤ 1024/2
        assert!(st.pure_touch(0, &req(4, 300, 3, 200), 0.5));
        assert!(!st.pure_touch(0, &req(4, 500, 3, 200), 0.5), "growth is not a touch");
        assert!(!st.pure_touch(0, &req(4, 300, 3, 200), 0.95), "watermark blocks blind");
        st.admit(0, &req(5, 200, 4, 0), 0.0); // total 600 > 1024/2
        assert!(
            !st.pure_touch(0, &req(6, 300, 3, 200), 0.5),
            "no halved-budget headroom → a commit could evict → not blind"
        );
    }
}
