//! Request routing across engine replicas.
//!
//! The router is the cluster's only admission point: every arrival is
//! dispatched to exactly one *active* replica. Policies range from
//! state-oblivious (round-robin) to load-aware (join-shortest-queue,
//! least-KV-pressure — the fleet-level analogue of Nexus's KV-watermark
//! mode switching) to locality-aware (session affinity, which keeps a
//! simulated user's traffic on one replica so prefix caches stay warm).

use crate::workload::{Request, TenantSpec};
use std::collections::{HashMap, VecDeque};

/// Simulated concurrent sessions for [`RoutingPolicy::SessionAffinity`]:
/// request ids are interleaved round-robin across this many users.
const AFFINITY_SESSIONS: usize = 64;

/// Dispatch policy for arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through active replicas regardless of load.
    RoundRobin,
    /// Fewest admitted-but-unfinished requests wins.
    JoinShortestQueue,
    /// Lowest live KV usage wins (ties broken by queue depth).
    LeastKvPressure,
    /// Sticky per-session placement with JSQ fallback on drain/overflow.
    SessionAffinity,
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::JoinShortestQueue => "jsq",
            RoutingPolicy::LeastKvPressure => "least-kv",
            RoutingPolicy::SessionAffinity => "affinity",
        }
    }

    /// Longer description for `--help` output.
    pub fn describe(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "cycle through active replicas",
            RoutingPolicy::JoinShortestQueue => "fewest in-flight requests wins",
            RoutingPolicy::LeastKvPressure => "lowest KV-cache usage wins",
            RoutingPolicy::SessionAffinity => "sticky per-session placement",
        }
    }

    pub fn by_name(name: &str) -> Option<RoutingPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(RoutingPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" | "shortest-queue" => {
                Some(RoutingPolicy::JoinShortestQueue)
            }
            "least-kv" | "kv" | "least-kv-pressure" => Some(RoutingPolicy::LeastKvPressure),
            "affinity" | "session" | "session-affinity" => Some(RoutingPolicy::SessionAffinity),
            _ => None,
        }
    }

    pub fn all() -> &'static [RoutingPolicy] {
        &[
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::LeastKvPressure,
            RoutingPolicy::SessionAffinity,
        ]
    }
}

/// Load snapshot of one routable (active) replica.
///
/// Compact (§Perf): `u32` index/pending keep the view at 16 bytes, so the
/// per-arrival view rebuild over a 1024-replica fleet stays cache-friendly
/// (fleet sizes and queue depths are ≪ 2³²).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Absolute replica index in the fleet.
    pub index: u32,
    /// Admitted-but-unfinished requests.
    pub pending: u32,
    /// Live KV usage `KV_u` ∈ [0, 1].
    pub kv_usage: f64,
}

/// Stateful dispatcher: one per cluster run.
#[derive(Debug)]
pub struct Router {
    pub policy: RoutingPolicy,
    rr_next: usize,
    /// session key → replica index (affinity policy only).
    sessions: HashMap<u64, usize>,
    /// Total requests dispatched.
    pub dispatched: usize,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Router { policy, rr_next: 0, sessions: HashMap::new(), dispatched: 0 }
    }

    fn jsq(views: &[ReplicaView]) -> usize {
        views
            .iter()
            .min_by_key(|v| (v.pending, v.index))
            .expect("router needs at least one active replica")
            .index as usize
    }

    /// Pick the target replica for one arrival. `views` must describe the
    /// currently *active* replicas (non-empty; draining replicas excluded).
    ///
    /// The router never retains `views` past the call, so the cluster loop
    /// refills one reusable buffer per arrival instead of allocating a
    /// fresh snapshot (§Perf) — same-instant dispatches still see each
    /// other because the buffer is rebuilt between arrivals.
    pub fn route(&mut self, views: &[ReplicaView], req: &Request) -> usize {
        assert!(!views.is_empty(), "route with no active replicas");
        self.dispatched += 1;
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let v = &views[self.rr_next % views.len()];
                self.rr_next = self.rr_next.wrapping_add(1);
                v.index as usize
            }
            RoutingPolicy::JoinShortestQueue => Self::jsq(views),
            RoutingPolicy::LeastKvPressure => {
                views
                    .iter()
                    .min_by(|a, b| {
                        (a.kv_usage, a.pending, a.index)
                            .partial_cmp(&(b.kv_usage, b.pending, b.index))
                            .unwrap()
                    })
                    .unwrap()
                    .index as usize
            }
            RoutingPolicy::SessionAffinity => {
                let key = (req.id % AFFINITY_SESSIONS) as u64;
                if let Some(&idx) = self.sessions.get(&key) {
                    if views.iter().any(|v| v.index as usize == idx) {
                        return idx;
                    }
                }
                // New session, or its replica drained: place by JSQ and pin.
                let idx = Self::jsq(views);
                self.sessions.insert(key, idx);
                idx
            }
        }
    }

    /// Probe the target for the `nth` arrival of a same-instant group
    /// *without* mutating router state — the rendezvous-batching fast path
    /// in [`crate::cluster::parallel`] uses this to check whether a whole
    /// group of arrivals can be dispatched in one worker round-trip.
    ///
    /// Returns `Some(replica index)` only when the decision is *blind*:
    /// provably identical to what [`Router::route`] would pick given the
    /// same pre-group `views`, independent of the queue-depth effects of
    /// the group's earlier members. Round-robin qualifies always (the
    /// cursor advances by one per arrival, so member `nth` lands at offset
    /// `rr_next + nth`); session affinity qualifies only on a sticky hit
    /// (the pin ignores load). JSQ / least-KV and affinity misses read
    /// live load, so they return `None` and the group falls back to
    /// per-arrival rendezvous routing.
    ///
    /// On success for *every* member, commit the group with
    /// [`Router::commit_blind`]; on any `None`, commit nothing.
    pub fn blind_probe(&self, views: &[ReplicaView], nth: usize, req: &Request) -> Option<usize> {
        assert!(!views.is_empty(), "probe with no active replicas");
        match self.policy {
            RoutingPolicy::RoundRobin => {
                Some(views[self.rr_next.wrapping_add(nth) % views.len()].index as usize)
            }
            RoutingPolicy::SessionAffinity => {
                let key = (req.id % AFFINITY_SESSIONS) as u64;
                let idx = *self.sessions.get(&key)?;
                views.iter().any(|v| v.index as usize == idx).then_some(idx)
            }
            RoutingPolicy::JoinShortestQueue | RoutingPolicy::LeastKvPressure => None,
        }
    }

    /// Commit `n` arrivals dispatched via successful [`Router::blind_probe`]
    /// calls: advances the round-robin cursor and the dispatch counter
    /// exactly as `n` individual [`Router::route`] calls would have.
    pub fn commit_blind(&mut self, n: usize) {
        self.dispatched += n;
        if self.policy == RoutingPolicy::RoundRobin {
            self.rr_next = self.rr_next.wrapping_add(n);
        }
    }
}

/// Multi-tenant admission config: a weighted-fair-queueing front stage in
/// front of the router (see [`TenantGate`]). `None` in
/// [`crate::cluster::ClusterCfg`] keeps the untagged single-queue fast path
/// byte-for-byte identical — the gate is pay-for-what-you-use.
#[derive(Debug, Clone)]
pub struct WfqCfg {
    /// Per-tenant weights / SLOs / quotas; requests carry an index into
    /// this table ([`Request::tenant`]). Labels past the end are clamped
    /// to the last entry (deterministic, never drops traffic).
    pub tenants: Vec<TenantSpec>,
    /// Fleet-wide cap on admitted-but-unfinished requests across all
    /// tenants. `usize::MAX` disables the global cap (quotas still apply).
    pub capacity: usize,
}

impl WfqCfg {
    pub fn new(tenants: Vec<TenantSpec>) -> Self {
        WfqCfg { tenants, capacity: usize::MAX }
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    /// `n` tenants with default (uniform) specs.
    pub fn uniform(n: usize) -> Self {
        WfqCfg::new(vec![TenantSpec::default(); n.max(1)])
    }
}

/// One tenant's FIFO inside the gate.
#[derive(Debug)]
struct TenantQueue {
    /// Held arrivals, each stamped with its WFQ virtual finish tag.
    q: VecDeque<(Request, f64)>,
    /// Admitted-but-unfinished requests charged to this tenant.
    inflight: usize,
    /// Virtual finish tag of the tenant's most recently stamped request;
    /// chains back-to-back arrivals so a tenant's backlog is served at
    /// exactly its weight share.
    last_vfinish: f64,
}

/// Weighted-fair-queueing admission gate: the cluster's multi-tenant front
/// stage, sitting *before* the [`Router`] (which still picks the replica).
///
/// Classic virtual-time WFQ with unit request cost: an arrival from tenant
/// `k` is stamped `vfinish = max(vtime, k.last_vfinish) + 1/weight_k`, and
/// the gate always dispatches the eligible head with the smallest
/// `(vfinish, tenant index)` — the index tie-break keeps every decision
/// deterministic. A head is *eligible* when its tenant is under its
/// admission quota and the fleet is under the global capacity cap.
///
/// Determinism contract (shared with both fleet loops): the gate is a pure
/// function of the arrival sequence and completion callbacks — virtual
/// time only, never wall clock — so sequential, reference, and parallel
/// loops drive identical gates to identical decisions.
#[derive(Debug)]
pub struct TenantGate {
    cfg: WfqCfg,
    queues: Vec<TenantQueue>,
    /// Admitted-but-unfinished across all tenants (vs `cfg.capacity`).
    inflight_total: usize,
    /// WFQ virtual time: advances to the dispatched tag on each pop.
    vtime: f64,
    /// Total requests held back at least once (observability only).
    pub throttled: usize,
}

impl TenantGate {
    pub fn new(cfg: WfqCfg) -> Self {
        let n = cfg.tenants.len().max(1);
        let queues = (0..n)
            .map(|_| TenantQueue { q: VecDeque::new(), inflight: 0, last_vfinish: 0.0 })
            .collect();
        TenantGate { cfg, queues, inflight_total: 0, vtime: 0.0, throttled: 0 }
    }

    /// Fold a request label into the gate's tenant table (clamp past-end).
    #[inline]
    fn slot(&self, tenant: u16) -> usize {
        (tenant as usize).min(self.queues.len() - 1)
    }

    #[inline]
    fn weight(&self, slot: usize) -> f64 {
        self.cfg.tenants.get(slot).map_or(1.0, |s| s.weight).max(1e-9)
    }

    #[inline]
    fn quota(&self, slot: usize) -> usize {
        self.cfg.tenants.get(slot).map_or(usize::MAX, |s| s.admission_quota)
    }

    /// Enqueue one arrival, stamping its virtual finish tag.
    pub fn push(&mut self, req: Request) {
        let slot = self.slot(req.tenant);
        let vstart = self.vtime.max(self.queues[slot].last_vfinish);
        let vfinish = vstart + 1.0 / self.weight(slot);
        self.queues[slot].last_vfinish = vfinish;
        self.queues[slot].q.push_back((req, vfinish));
    }

    /// Dispatch the next eligible request, if any: smallest
    /// `(head vfinish, tenant index)` among tenants under quota, subject to
    /// the global capacity cap. Charges the in-flight slot immediately.
    pub fn pop_next(&mut self) -> Option<Request> {
        if self.inflight_total >= self.cfg.capacity {
            return None;
        }
        let mut best: Option<(f64, usize)> = None;
        for (idx, tq) in self.queues.iter().enumerate() {
            if tq.inflight >= self.quota(idx) {
                continue;
            }
            if let Some(&(_, vfinish)) = tq.q.front() {
                let better = match best {
                    None => true,
                    Some((bv, bi)) => vfinish < bv || (vfinish == bv && idx < bi),
                };
                if better {
                    best = Some((vfinish, idx));
                }
            }
        }
        let (vfinish, idx) = best?;
        let (req, _) = self.queues[idx].q.pop_front().expect("head just observed");
        self.queues[idx].inflight += 1;
        self.inflight_total += 1;
        self.vtime = self.vtime.max(vfinish);
        Some(req)
    }

    /// A request from `tenant` finished: release its in-flight slot.
    pub fn on_complete(&mut self, tenant: u16) {
        let slot = self.slot(tenant);
        debug_assert!(self.queues[slot].inflight > 0, "complete without admit");
        self.queues[slot].inflight = self.queues[slot].inflight.saturating_sub(1);
        self.inflight_total = self.inflight_total.saturating_sub(1);
    }

    /// Any arrival still held back?
    #[inline]
    pub fn backlogged(&self) -> bool {
        self.queues.iter().any(|tq| !tq.q.is_empty())
    }

    /// Total held-back arrivals across tenants.
    #[inline]
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|tq| tq.q.len()).sum()
    }

    /// Held-back arrivals for one tenant label (post-clamp).
    #[inline]
    pub fn queued_for(&self, tenant: u16) -> usize {
        self.queues[self.slot(tenant)].q.len()
    }

    /// Admitted-but-unfinished requests charged to one tenant label.
    #[inline]
    pub fn inflight_for(&self, tenant: u16) -> usize {
        self.queues[self.slot(tenant)].inflight
    }

    /// Admitted-but-unfinished across all tenants.
    #[inline]
    pub fn inflight_total(&self) -> usize {
        self.inflight_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request { id, arrival: 0.0, prompt_len: 100, output_len: 10, tenant: 0 }
    }

    fn views(loads: &[(u32, u32, f64)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .map(|&(index, pending, kv_usage)| ReplicaView { index, pending, kv_usage })
            .collect()
    }

    #[test]
    fn policy_name_roundtrip() {
        for &p in RoutingPolicy::all() {
            assert_eq!(RoutingPolicy::by_name(p.name()), Some(p));
            assert!(!p.describe().is_empty());
        }
        assert!(RoutingPolicy::by_name("random").is_none());
    }

    #[test]
    fn round_robin_cycles_active_set() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let v = views(&[(0, 0, 0.0), (2, 0, 0.0), (5, 0, 0.0)]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&v, &req(i))).collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
        assert_eq!(r.dispatched, 6);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        let v = views(&[(0, 7, 0.1), (1, 2, 0.9), (2, 2, 0.3)]);
        // Tie on pending=2 broken by index.
        assert_eq!(r.route(&v, &req(0)), 1);
    }

    #[test]
    fn least_kv_prefers_cold_cache() {
        let mut r = Router::new(RoutingPolicy::LeastKvPressure);
        let v = views(&[(0, 1, 0.8), (1, 9, 0.2), (2, 1, 0.5)]);
        assert_eq!(r.route(&v, &req(0)), 1, "kv usage dominates queue depth");
    }

    #[test]
    fn blind_probe_matches_route() {
        // Round-robin: probing members 0..n of a same-instant group with
        // offsets then committing once reproduces n sequential route() calls.
        let v = views(&[(0, 0, 0.0), (2, 0, 0.0), (5, 0, 0.0)]);
        let mut blind = Router::new(RoutingPolicy::RoundRobin);
        let mut seq = Router::new(RoutingPolicy::RoundRobin);
        for round in 0..3 {
            let group: Vec<usize> = (0..4)
                .map(|n| blind.blind_probe(&v, n, &req(round * 4 + n)).unwrap())
                .collect();
            blind.commit_blind(group.len());
            let expect: Vec<usize> =
                (0..4).map(|n| seq.route(&v, &req(round * 4 + n))).collect();
            assert_eq!(group, expect, "round {round}");
        }
        assert_eq!(blind.dispatched, seq.dispatched);

        // Affinity: unpinned session is not blind; pinned session is, and
        // the probe matches the sticky route without mutating state.
        let mut r = Router::new(RoutingPolicy::SessionAffinity);
        assert_eq!(r.blind_probe(&v, 0, &req(3)), None, "unpinned session reads load");
        let pinned = r.route(&v, &req(3));
        assert_eq!(r.blind_probe(&v, 7, &req(3 + 64)), Some(pinned), "nth-independent");
        let gone = views(&[(2, 0, 0.0), (5, 0, 0.0)]);
        assert_eq!(r.blind_probe(&gone, 0, &req(3 + 64)), None, "pinned replica drained");

        // Load-aware policies never qualify.
        let r = Router::new(RoutingPolicy::JoinShortestQueue);
        assert_eq!(r.blind_probe(&v, 0, &req(0)), None);
        let r = Router::new(RoutingPolicy::LeastKvPressure);
        assert_eq!(r.blind_probe(&v, 0, &req(0)), None);
    }

    #[test]
    fn affinity_is_sticky_until_drain() {
        let mut r = Router::new(RoutingPolicy::SessionAffinity);
        let v = views(&[(0, 0, 0.0), (1, 5, 0.0)]);
        let first = r.route(&v, &req(3));
        assert_eq!(first, 0, "initial placement is JSQ");
        // Same session (id ≡ 3 mod 64) sticks even when load flips.
        let v_flipped = views(&[(0, 50, 0.0), (1, 0, 0.0)]);
        assert_eq!(r.route(&v_flipped, &req(3 + 64)), 0);
        // Replica 0 drained: session remaps to an active replica.
        let v_drained = views(&[(1, 0, 0.0)]);
        assert_eq!(r.route(&v_drained, &req(3 + 128)), 1);
        // ...and stays remapped afterwards.
        let v_back = views(&[(0, 0, 0.0), (1, 9, 0.0)]);
        assert_eq!(r.route(&v_back, &req(3 + 192)), 1);
    }

    fn treq(id: usize, tenant: u16) -> Request {
        Request { id, arrival: 0.0, prompt_len: 100, output_len: 10, tenant }
    }

    fn spec(weight: f64, quota: usize) -> TenantSpec {
        TenantSpec { weight, admission_quota: quota, ..TenantSpec::default() }
    }

    #[test]
    fn wfq_serves_backlogs_in_weight_proportion() {
        // Tenant 0 weight 2, tenant 1 weight 1: over a saturated backlog the
        // dispatch order must interleave 2:1.
        let mut g = TenantGate::new(WfqCfg::new(vec![spec(2.0, usize::MAX), spec(1.0, usize::MAX)]));
        for i in 0..6 {
            g.push(treq(i, 0));
        }
        for i in 6..9 {
            g.push(treq(i, 1));
        }
        let order: Vec<u16> = std::iter::from_fn(|| g.pop_next()).map(|r| r.tenant).collect();
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
        assert!(!g.backlogged());
        assert_eq!(g.inflight_total(), 9, "pops charge in-flight slots");
    }

    #[test]
    fn wfq_tie_breaks_by_tenant_index() {
        // Equal weights, same stamp sequence: lower tenant index wins ties.
        let mut g = TenantGate::new(WfqCfg::uniform(2));
        g.push(treq(0, 1));
        g.push(treq(1, 0));
        assert_eq!(g.pop_next().unwrap().tenant, 0);
        assert_eq!(g.pop_next().unwrap().tenant, 1);
    }

    #[test]
    fn quota_holds_tenant_back_until_completion() {
        let mut g = TenantGate::new(WfqCfg::new(vec![spec(1.0, 1), spec(1.0, usize::MAX)]));
        g.push(treq(0, 0));
        g.push(treq(1, 0));
        g.push(treq(2, 1));
        assert_eq!(g.pop_next().unwrap().id, 0);
        // Tenant 0 at quota: its second request is skipped, tenant 1 runs.
        assert_eq!(g.pop_next().unwrap().id, 2);
        assert!(g.pop_next().is_none(), "only tenant 0 queued, and it is at quota");
        assert_eq!(g.queued_for(0), 1);
        assert_eq!(g.inflight_for(0), 1);
        g.on_complete(0);
        assert_eq!(g.pop_next().unwrap().id, 1, "completion frees the quota slot");
    }

    #[test]
    fn capacity_caps_total_inflight() {
        let mut g = TenantGate::new(WfqCfg::uniform(2).with_capacity(2));
        for i in 0..4 {
            g.push(treq(i, (i % 2) as u16));
        }
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_none(), "global capacity reached");
        assert_eq!(g.queued(), 2);
        g.on_complete(0);
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_none());
    }

    #[test]
    fn out_of_range_labels_clamp_to_last_tenant() {
        let mut g = TenantGate::new(WfqCfg::uniform(2));
        g.push(treq(0, 9));
        assert_eq!(g.queued_for(1), 1, "label 9 folds into the last tenant");
        let r = g.pop_next().unwrap();
        assert_eq!(r.tenant, 9, "the request itself keeps its label");
        g.on_complete(9);
        assert_eq!(g.inflight_for(1), 0);
    }
}
