//! Request routing across engine replicas.
//!
//! The router is the cluster's only admission point: every arrival is
//! dispatched to exactly one *active* replica. Policies range from
//! state-oblivious (round-robin) to load-aware (join-shortest-queue,
//! least-KV-pressure — the fleet-level analogue of Nexus's KV-watermark
//! mode switching) to locality-aware (session affinity, which keeps a
//! simulated user's traffic on one replica so prefix caches stay warm).

use crate::workload::Request;
use std::collections::HashMap;

/// Simulated concurrent sessions for [`RoutingPolicy::SessionAffinity`]:
/// request ids are interleaved round-robin across this many users.
const AFFINITY_SESSIONS: usize = 64;

/// Dispatch policy for arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through active replicas regardless of load.
    RoundRobin,
    /// Fewest admitted-but-unfinished requests wins.
    JoinShortestQueue,
    /// Lowest live KV usage wins (ties broken by queue depth).
    LeastKvPressure,
    /// Sticky per-session placement with JSQ fallback on drain/overflow.
    SessionAffinity,
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::JoinShortestQueue => "jsq",
            RoutingPolicy::LeastKvPressure => "least-kv",
            RoutingPolicy::SessionAffinity => "affinity",
        }
    }

    /// Longer description for `--help` output.
    pub fn describe(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "cycle through active replicas",
            RoutingPolicy::JoinShortestQueue => "fewest in-flight requests wins",
            RoutingPolicy::LeastKvPressure => "lowest KV-cache usage wins",
            RoutingPolicy::SessionAffinity => "sticky per-session placement",
        }
    }

    pub fn by_name(name: &str) -> Option<RoutingPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(RoutingPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" | "shortest-queue" => {
                Some(RoutingPolicy::JoinShortestQueue)
            }
            "least-kv" | "kv" | "least-kv-pressure" => Some(RoutingPolicy::LeastKvPressure),
            "affinity" | "session" | "session-affinity" => Some(RoutingPolicy::SessionAffinity),
            _ => None,
        }
    }

    pub fn all() -> &'static [RoutingPolicy] {
        &[
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::LeastKvPressure,
            RoutingPolicy::SessionAffinity,
        ]
    }
}

/// Load snapshot of one routable (active) replica.
///
/// Compact (§Perf): `u32` index/pending keep the view at 16 bytes, so the
/// per-arrival view rebuild over a 1024-replica fleet stays cache-friendly
/// (fleet sizes and queue depths are ≪ 2³²).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Absolute replica index in the fleet.
    pub index: u32,
    /// Admitted-but-unfinished requests.
    pub pending: u32,
    /// Live KV usage `KV_u` ∈ [0, 1].
    pub kv_usage: f64,
}

/// Stateful dispatcher: one per cluster run.
#[derive(Debug)]
pub struct Router {
    pub policy: RoutingPolicy,
    rr_next: usize,
    /// session key → replica index (affinity policy only).
    sessions: HashMap<u64, usize>,
    /// Total requests dispatched.
    pub dispatched: usize,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Router { policy, rr_next: 0, sessions: HashMap::new(), dispatched: 0 }
    }

    fn jsq(views: &[ReplicaView]) -> usize {
        views
            .iter()
            .min_by_key(|v| (v.pending, v.index))
            .expect("router needs at least one active replica")
            .index as usize
    }

    /// Pick the target replica for one arrival. `views` must describe the
    /// currently *active* replicas (non-empty; draining replicas excluded).
    ///
    /// The router never retains `views` past the call, so the cluster loop
    /// refills one reusable buffer per arrival instead of allocating a
    /// fresh snapshot (§Perf) — same-instant dispatches still see each
    /// other because the buffer is rebuilt between arrivals.
    pub fn route(&mut self, views: &[ReplicaView], req: &Request) -> usize {
        assert!(!views.is_empty(), "route with no active replicas");
        self.dispatched += 1;
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let v = &views[self.rr_next % views.len()];
                self.rr_next = self.rr_next.wrapping_add(1);
                v.index as usize
            }
            RoutingPolicy::JoinShortestQueue => Self::jsq(views),
            RoutingPolicy::LeastKvPressure => {
                views
                    .iter()
                    .min_by(|a, b| {
                        (a.kv_usage, a.pending, a.index)
                            .partial_cmp(&(b.kv_usage, b.pending, b.index))
                            .unwrap()
                    })
                    .unwrap()
                    .index as usize
            }
            RoutingPolicy::SessionAffinity => {
                let key = (req.id % AFFINITY_SESSIONS) as u64;
                if let Some(&idx) = self.sessions.get(&key) {
                    if views.iter().any(|v| v.index as usize == idx) {
                        return idx;
                    }
                }
                // New session, or its replica drained: place by JSQ and pin.
                let idx = Self::jsq(views);
                self.sessions.insert(key, idx);
                idx
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request { id, arrival: 0.0, prompt_len: 100, output_len: 10 }
    }

    fn views(loads: &[(u32, u32, f64)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .map(|&(index, pending, kv_usage)| ReplicaView { index, pending, kv_usage })
            .collect()
    }

    #[test]
    fn policy_name_roundtrip() {
        for &p in RoutingPolicy::all() {
            assert_eq!(RoutingPolicy::by_name(p.name()), Some(p));
            assert!(!p.describe().is_empty());
        }
        assert!(RoutingPolicy::by_name("random").is_none());
    }

    #[test]
    fn round_robin_cycles_active_set() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let v = views(&[(0, 0, 0.0), (2, 0, 0.0), (5, 0, 0.0)]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&v, &req(i))).collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
        assert_eq!(r.dispatched, 6);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        let v = views(&[(0, 7, 0.1), (1, 2, 0.9), (2, 2, 0.3)]);
        // Tie on pending=2 broken by index.
        assert_eq!(r.route(&v, &req(0)), 1);
    }

    #[test]
    fn least_kv_prefers_cold_cache() {
        let mut r = Router::new(RoutingPolicy::LeastKvPressure);
        let v = views(&[(0, 1, 0.8), (1, 9, 0.2), (2, 1, 0.5)]);
        assert_eq!(r.route(&v, &req(0)), 1, "kv usage dominates queue depth");
    }

    #[test]
    fn affinity_is_sticky_until_drain() {
        let mut r = Router::new(RoutingPolicy::SessionAffinity);
        let v = views(&[(0, 0, 0.0), (1, 5, 0.0)]);
        let first = r.route(&v, &req(3));
        assert_eq!(first, 0, "initial placement is JSQ");
        // Same session (id ≡ 3 mod 64) sticks even when load flips.
        let v_flipped = views(&[(0, 50, 0.0), (1, 0, 0.0)]);
        assert_eq!(r.route(&v_flipped, &req(3 + 64)), 0);
        // Replica 0 drained: session remaps to an active replica.
        let v_drained = views(&[(1, 0, 0.0)]);
        assert_eq!(r.route(&v_drained, &req(3 + 128)), 1);
        // ...and stays remapped afterwards.
        let v_back = views(&[(0, 0, 0.0), (1, 9, 0.0)]);
        assert_eq!(r.route(&v_back, &req(3 + 192)), 1);
    }
}
