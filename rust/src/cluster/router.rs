//! Request routing across engine replicas.
//!
//! The router is the cluster's only admission point: every arrival is
//! dispatched to exactly one *active* replica. Policies range from
//! state-oblivious (round-robin) to load-aware (join-shortest-queue,
//! least-KV-pressure — the fleet-level analogue of Nexus's KV-watermark
//! mode switching) to locality-aware (session affinity, which keeps a
//! simulated user's traffic on one replica so prefix caches stay warm).

use super::prefixcache::PrefixState;
use crate::workload::{Request, TenantSpec};
use std::collections::{HashMap, VecDeque};

/// Simulated concurrent sessions for [`RoutingPolicy::SessionAffinity`]:
/// request ids are interleaved round-robin across this many users.
const AFFINITY_SESSIONS: usize = 64;

/// Default cap on live session pins ([`Router::with_session_cap`] overrides;
/// the oldest pin is recycled deterministically when the cap is hit, so the
/// map can never grow without bound over a long streaming run).
const SESSION_CAP: usize = 4096;

/// Dispatch policy for arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through active replicas regardless of load.
    RoundRobin,
    /// Fewest admitted-but-unfinished requests wins.
    JoinShortestQueue,
    /// Lowest live KV usage wins (ties broken by queue depth).
    LeastKvPressure,
    /// Sticky per-session placement with JSQ fallback on drain/overflow.
    SessionAffinity,
    /// Resident-prefix tokens minus a load penalty wins (JSQ fallback when
    /// the fleet holds nothing for the request's chain). Requires the
    /// cluster's prefix-cache tier (`ClusterCfg::prefix`); without it the
    /// policy degenerates to JSQ.
    PrefixAware,
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::JoinShortestQueue => "jsq",
            RoutingPolicy::LeastKvPressure => "least-kv",
            RoutingPolicy::SessionAffinity => "affinity",
            RoutingPolicy::PrefixAware => "prefix",
        }
    }

    /// Longer description for `--help` output.
    pub fn describe(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "cycle through active replicas",
            RoutingPolicy::JoinShortestQueue => "fewest in-flight requests wins",
            RoutingPolicy::LeastKvPressure => "lowest KV-cache usage wins",
            RoutingPolicy::SessionAffinity => "sticky per-session placement",
            RoutingPolicy::PrefixAware => "most resident prefix tokens wins",
        }
    }

    pub fn by_name(name: &str) -> Option<RoutingPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(RoutingPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" | "shortest-queue" => {
                Some(RoutingPolicy::JoinShortestQueue)
            }
            "least-kv" | "kv" | "least-kv-pressure" => Some(RoutingPolicy::LeastKvPressure),
            "affinity" | "session" | "session-affinity" => Some(RoutingPolicy::SessionAffinity),
            "prefix" | "prefix-aware" => Some(RoutingPolicy::PrefixAware),
            _ => None,
        }
    }

    pub fn all() -> &'static [RoutingPolicy] {
        &[
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::LeastKvPressure,
            RoutingPolicy::SessionAffinity,
            RoutingPolicy::PrefixAware,
        ]
    }
}

/// Load snapshot of one routable (active) replica.
///
/// Compact (§Perf): `u32` index/pending keep the view at 16 bytes, so the
/// per-arrival view rebuild over a 1024-replica fleet stays cache-friendly
/// (fleet sizes and queue depths are ≪ 2³²).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Absolute replica index in the fleet.
    pub index: u32,
    /// Admitted-but-unfinished requests.
    pub pending: u32,
    /// Live KV usage `KV_u` ∈ [0, 1].
    pub kv_usage: f64,
}

/// Stateful dispatcher: one per cluster run.
#[derive(Debug)]
pub struct Router {
    pub policy: RoutingPolicy,
    rr_next: usize,
    /// session key → replica index (affinity policy only).
    sessions: HashMap<u64, usize>,
    /// Pin insertion order for deterministic recycling at `session_cap`
    /// (may hold stale keys for pins purged out of band; skipped lazily).
    session_order: VecDeque<u64>,
    /// Max live session pins before the oldest is recycled.
    session_cap: usize,
    /// Total requests dispatched.
    pub dispatched: usize,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Self::with_session_cap(policy, SESSION_CAP)
    }

    /// A router whose session-pin table is capped at `cap` entries (FIFO
    /// recycling). The default cap is [`SESSION_CAP`]; tests shrink it to
    /// exercise the recycling path.
    pub fn with_session_cap(policy: RoutingPolicy, cap: usize) -> Self {
        Router {
            policy,
            rr_next: 0,
            sessions: HashMap::new(),
            session_order: VecDeque::new(),
            session_cap: cap.max(1),
            dispatched: 0,
        }
    }

    /// Live session pins (bounded by the cap; observability/tests).
    pub fn sessions_pinned(&self) -> usize {
        self.sessions.len()
    }

    /// Drop every session pinned to a replica that left service. The pins
    /// were already dead — a sticky lookup on a drained replica falls
    /// through to JSQ-and-repin — so purging changes no routing decision;
    /// it just keeps the map from accumulating tombstones under autoscaler
    /// churn.
    pub fn purge_replica(&mut self, idx: usize) {
        self.sessions.retain(|_, &mut v| v != idx);
    }

    /// Pin `key` to `idx`, recycling the oldest pin past the cap. A remap
    /// of a known session keeps its original age.
    fn pin_session(&mut self, key: u64, idx: usize) {
        if self.sessions.insert(key, idx).is_some() {
            return;
        }
        self.session_order.push_back(key);
        while self.sessions.len() > self.session_cap {
            match self.session_order.pop_front() {
                Some(old) if old == key => {
                    // The newest pin is never the recycling victim.
                    self.session_order.push_back(key);
                }
                Some(old) => {
                    self.sessions.remove(&old);
                }
                None => break,
            }
        }
    }

    fn jsq(views: &[ReplicaView]) -> usize {
        views
            .iter()
            .min_by_key(|v| (v.pending, v.index))
            .expect("router needs at least one active replica")
            .index as usize
    }

    /// Lowest-index replica in `views` holding the request's full shared
    /// prefix (`resident ≥ shared > 0`). The *lowest-index* choice (rather
    /// than least-loaded) is load-independent, which is what lets the
    /// blind-probe fast path commit full hits without rendezvous.
    fn full_prefix_hit(views: &[ReplicaView], req: &Request, ps: &PrefixState) -> Option<usize> {
        let s = req.shared();
        if s == 0 {
            return None;
        }
        views
            .iter()
            .map(|v| v.index as usize)
            .filter(|&i| ps.resident(i, req.prefix) >= s)
            .min()
    }

    /// Pick the target replica for one arrival. `views` must describe the
    /// currently *active* replicas (non-empty; draining replicas excluded).
    ///
    /// The router never retains `views` past the call, so the cluster loop
    /// refills one reusable buffer per arrival instead of allocating a
    /// fresh snapshot (§Perf) — same-instant dispatches still see each
    /// other because the buffer is rebuilt between arrivals.
    ///
    /// `prefix` is the cluster's prefix-cache tier; only
    /// [`RoutingPolicy::PrefixAware`] reads it. [`Router::route`] is the
    /// `None` shorthand for callers without a tier.
    pub fn route_with(
        &mut self,
        views: &[ReplicaView],
        req: &Request,
        prefix: Option<&PrefixState>,
    ) -> usize {
        assert!(!views.is_empty(), "route with no active replicas");
        self.dispatched += 1;
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let v = &views[self.rr_next % views.len()];
                self.rr_next = self.rr_next.wrapping_add(1);
                v.index as usize
            }
            RoutingPolicy::JoinShortestQueue => Self::jsq(views),
            RoutingPolicy::LeastKvPressure => {
                views
                    .iter()
                    .min_by(|a, b| {
                        (a.kv_usage, a.pending, a.index)
                            .partial_cmp(&(b.kv_usage, b.pending, b.index))
                            .unwrap()
                    })
                    .unwrap()
                    .index as usize
            }
            RoutingPolicy::SessionAffinity => {
                let key = (req.id % AFFINITY_SESSIONS) as u64;
                if let Some(&idx) = self.sessions.get(&key) {
                    if views.iter().any(|v| v.index as usize == idx) {
                        return idx;
                    }
                }
                // New session, or its replica drained: place by JSQ and pin.
                let idx = Self::jsq(views);
                self.pin_session(key, idx);
                idx
            }
            RoutingPolicy::PrefixAware => {
                let Some(ps) = prefix else { return Self::jsq(views) };
                // Full hit: the shared prefix is entirely resident
                // somewhere — reuse is free, so locality beats load.
                if let Some(idx) = Self::full_prefix_hit(views, req, ps) {
                    return idx;
                }
                // Partial residency: score resident-prefix tokens minus a
                // load penalty per queued request; positive score required
                // so a long queue can't hide behind a sliver of prefix.
                let s = req.shared();
                let mut best: Option<(f64, usize)> = None;
                if s > 0 {
                    for v in views {
                        let i = v.index as usize;
                        let res = ps.resident(i, req.prefix).min(s);
                        if res == 0 {
                            continue;
                        }
                        let score = res as f64 - ps.cfg.load_penalty * v.pending as f64;
                        let better = match best {
                            None => true,
                            Some((bs, bi)) => score > bs || (score == bs && i < bi),
                        };
                        if score > 0.0 && better {
                            best = Some((score, i));
                        }
                    }
                }
                match best {
                    Some((_, i)) => i,
                    // Nothing resident (or nothing worth the queue): JSQ.
                    None => Self::jsq(views),
                }
            }
        }
    }

    /// [`Router::route_with`] without a prefix tier.
    pub fn route(&mut self, views: &[ReplicaView], req: &Request) -> usize {
        self.route_with(views, req, None)
    }

    /// Probe the target for the `nth` arrival of a same-instant group
    /// *without* mutating router state — the rendezvous-batching fast path
    /// in [`crate::cluster::parallel`] uses this to check whether a whole
    /// group of arrivals can be dispatched in one worker round-trip.
    ///
    /// Returns `Some(replica index)` only when the decision is *blind*:
    /// provably identical to what [`Router::route_with`] would pick given
    /// the same pre-group `views`, independent of the queue-depth effects
    /// of the group's earlier members. Round-robin qualifies always (the
    /// cursor advances by one per arrival, so member `nth` lands at offset
    /// `rr_next + nth`); session affinity qualifies only on a sticky hit
    /// (the pin ignores load); prefix-aware qualifies only when the target
    /// is a full-hit *pure touch* (fully resident below the KV watermark,
    /// so committing it mutates nothing the group's other members can
    /// observe). JSQ / least-KV, affinity misses, and partial prefix hits
    /// read live load, so they return `None` and the group falls back to
    /// per-arrival rendezvous routing.
    ///
    /// On success for *every* member, commit the group with
    /// [`Router::commit_blind`]; on any `None`, commit nothing.
    pub fn blind_probe_with(
        &self,
        views: &[ReplicaView],
        nth: usize,
        req: &Request,
        prefix: Option<&PrefixState>,
    ) -> Option<usize> {
        assert!(!views.is_empty(), "probe with no active replicas");
        match self.policy {
            RoutingPolicy::RoundRobin => {
                Some(views[self.rr_next.wrapping_add(nth) % views.len()].index as usize)
            }
            RoutingPolicy::SessionAffinity => {
                let key = (req.id % AFFINITY_SESSIONS) as u64;
                let idx = *self.sessions.get(&key)?;
                views.iter().any(|v| v.index as usize == idx).then_some(idx)
            }
            RoutingPolicy::PrefixAware => {
                let ps = prefix?;
                let idx = Self::full_prefix_hit(views, req, ps)?;
                let kv = views.iter().find(|v| v.index as usize == idx)?.kv_usage;
                // Blind only when committing is a pure LRU touch: full
                // residency below the watermark — no growth, no eviction,
                // no score any same-instant sibling could observe change.
                ps.pure_touch(idx, req, kv).then_some(idx)
            }
            RoutingPolicy::JoinShortestQueue | RoutingPolicy::LeastKvPressure => None,
        }
    }

    /// [`Router::blind_probe_with`] without a prefix tier.
    pub fn blind_probe(&self, views: &[ReplicaView], nth: usize, req: &Request) -> Option<usize> {
        self.blind_probe_with(views, nth, req, None)
    }

    /// Commit `n` arrivals dispatched via successful [`Router::blind_probe`]
    /// calls: advances the round-robin cursor and the dispatch counter
    /// exactly as `n` individual [`Router::route`] calls would have.
    pub fn commit_blind(&mut self, n: usize) {
        self.dispatched += n;
        if self.policy == RoutingPolicy::RoundRobin {
            self.rr_next = self.rr_next.wrapping_add(n);
        }
    }
}

/// Multi-tenant admission config: a weighted-fair-queueing front stage in
/// front of the router (see [`TenantGate`]). `None` in
/// [`crate::cluster::ClusterCfg`] keeps the untagged single-queue fast path
/// byte-for-byte identical — the gate is pay-for-what-you-use.
#[derive(Debug, Clone)]
pub struct WfqCfg {
    /// Per-tenant weights / SLOs / quotas; requests carry an index into
    /// this table ([`Request::tenant`]). Labels past the end are clamped
    /// to the last entry (deterministic, never drops traffic).
    pub tenants: Vec<TenantSpec>,
    /// Fleet-wide cap on admitted-but-unfinished requests across all
    /// tenants. `usize::MAX` disables the global cap (quotas still apply).
    pub capacity: usize,
}

impl WfqCfg {
    pub fn new(tenants: Vec<TenantSpec>) -> Self {
        WfqCfg { tenants, capacity: usize::MAX }
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    /// `n` tenants with default (uniform) specs.
    pub fn uniform(n: usize) -> Self {
        WfqCfg::new(vec![TenantSpec::default(); n.max(1)])
    }
}

/// One tenant's FIFO inside the gate.
#[derive(Debug)]
struct TenantQueue {
    /// Held arrivals, each stamped with its WFQ virtual finish tag.
    q: VecDeque<(Request, f64)>,
    /// Admitted-but-unfinished requests charged to this tenant.
    inflight: usize,
    /// Virtual finish tag of the tenant's most recently stamped request;
    /// chains back-to-back arrivals so a tenant's backlog is served at
    /// exactly its weight share.
    last_vfinish: f64,
}

/// Weighted-fair-queueing admission gate: the cluster's multi-tenant front
/// stage, sitting *before* the [`Router`] (which still picks the replica).
///
/// Classic virtual-time WFQ with unit request cost: an arrival from tenant
/// `k` is stamped `vfinish = max(vtime, k.last_vfinish) + 1/weight_k`, and
/// the gate always dispatches the eligible head with the smallest
/// `(vfinish, tenant index)` — the index tie-break keeps every decision
/// deterministic. A head is *eligible* when its tenant is under its
/// admission quota and the fleet is under the global capacity cap.
///
/// Determinism contract (shared with both fleet loops): the gate is a pure
/// function of the arrival sequence and completion callbacks — virtual
/// time only, never wall clock — so sequential, reference, and parallel
/// loops drive identical gates to identical decisions.
#[derive(Debug)]
pub struct TenantGate {
    cfg: WfqCfg,
    queues: Vec<TenantQueue>,
    /// Admitted-but-unfinished across all tenants (vs `cfg.capacity`).
    inflight_total: usize,
    /// WFQ virtual time: advances to the dispatched tag on each pop.
    vtime: f64,
    /// Total requests held back at least once (observability only).
    pub throttled: usize,
}

impl TenantGate {
    pub fn new(cfg: WfqCfg) -> Self {
        let n = cfg.tenants.len().max(1);
        let queues = (0..n)
            .map(|_| TenantQueue { q: VecDeque::new(), inflight: 0, last_vfinish: 0.0 })
            .collect();
        TenantGate { cfg, queues, inflight_total: 0, vtime: 0.0, throttled: 0 }
    }

    /// Fold a request label into the gate's tenant table (clamp past-end).
    #[inline]
    fn slot(&self, tenant: u16) -> usize {
        (tenant as usize).min(self.queues.len() - 1)
    }

    #[inline]
    fn weight(&self, slot: usize) -> f64 {
        self.cfg.tenants.get(slot).map_or(1.0, |s| s.weight).max(1e-9)
    }

    #[inline]
    fn quota(&self, slot: usize) -> usize {
        self.cfg.tenants.get(slot).map_or(usize::MAX, |s| s.admission_quota)
    }

    /// Enqueue one arrival, stamping its virtual finish tag.
    pub fn push(&mut self, req: Request) {
        let slot = self.slot(req.tenant);
        let vstart = self.vtime.max(self.queues[slot].last_vfinish);
        let vfinish = vstart + 1.0 / self.weight(slot);
        self.queues[slot].last_vfinish = vfinish;
        self.queues[slot].q.push_back((req, vfinish));
    }

    /// Dispatch the next eligible request, if any: smallest
    /// `(head vfinish, tenant index)` among tenants under quota, subject to
    /// the global capacity cap. Charges the in-flight slot immediately.
    pub fn pop_next(&mut self) -> Option<Request> {
        if self.inflight_total >= self.cfg.capacity {
            return None;
        }
        let mut best: Option<(f64, usize)> = None;
        for (idx, tq) in self.queues.iter().enumerate() {
            if tq.inflight >= self.quota(idx) {
                continue;
            }
            if let Some(&(_, vfinish)) = tq.q.front() {
                let better = match best {
                    None => true,
                    Some((bv, bi)) => vfinish < bv || (vfinish == bv && idx < bi),
                };
                if better {
                    best = Some((vfinish, idx));
                }
            }
        }
        let (vfinish, idx) = best?;
        let (req, _) = self.queues[idx].q.pop_front().expect("head just observed");
        self.queues[idx].inflight += 1;
        self.inflight_total += 1;
        self.vtime = self.vtime.max(vfinish);
        Some(req)
    }

    /// A request from `tenant` finished: release its in-flight slot.
    pub fn on_complete(&mut self, tenant: u16) {
        let slot = self.slot(tenant);
        debug_assert!(self.queues[slot].inflight > 0, "complete without admit");
        self.queues[slot].inflight = self.queues[slot].inflight.saturating_sub(1);
        self.inflight_total = self.inflight_total.saturating_sub(1);
    }

    /// Any arrival still held back?
    #[inline]
    pub fn backlogged(&self) -> bool {
        self.queues.iter().any(|tq| !tq.q.is_empty())
    }

    /// Total held-back arrivals across tenants.
    #[inline]
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|tq| tq.q.len()).sum()
    }

    /// Held-back arrivals for one tenant label (post-clamp).
    #[inline]
    pub fn queued_for(&self, tenant: u16) -> usize {
        self.queues[self.slot(tenant)].q.len()
    }

    /// Admitted-but-unfinished requests charged to one tenant label.
    #[inline]
    pub fn inflight_for(&self, tenant: u16) -> usize {
        self.queues[self.slot(tenant)].inflight
    }

    /// Admitted-but-unfinished across all tenants.
    #[inline]
    pub fn inflight_total(&self) -> usize {
        self.inflight_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: 100,
            output_len: 10,
            tenant: 0,
            prefix: 0,
            shared_len: 0,
        }
    }

    fn preq(id: usize, plen: u32, prefix: u32, shared: u16) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: plen,
            output_len: 10,
            tenant: 0,
            prefix,
            shared_len: shared,
        }
    }

    fn views(loads: &[(u32, u32, f64)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .map(|&(index, pending, kv_usage)| ReplicaView { index, pending, kv_usage })
            .collect()
    }

    #[test]
    fn policy_name_roundtrip() {
        for &p in RoutingPolicy::all() {
            assert_eq!(RoutingPolicy::by_name(p.name()), Some(p));
            assert!(!p.describe().is_empty());
        }
        assert!(RoutingPolicy::by_name("random").is_none());
    }

    #[test]
    fn round_robin_cycles_active_set() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let v = views(&[(0, 0, 0.0), (2, 0, 0.0), (5, 0, 0.0)]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&v, &req(i))).collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
        assert_eq!(r.dispatched, 6);
    }

    #[test]
    fn jsq_picks_least_loaded() {
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue);
        let v = views(&[(0, 7, 0.1), (1, 2, 0.9), (2, 2, 0.3)]);
        // Tie on pending=2 broken by index.
        assert_eq!(r.route(&v, &req(0)), 1);
    }

    #[test]
    fn least_kv_prefers_cold_cache() {
        let mut r = Router::new(RoutingPolicy::LeastKvPressure);
        let v = views(&[(0, 1, 0.8), (1, 9, 0.2), (2, 1, 0.5)]);
        assert_eq!(r.route(&v, &req(0)), 1, "kv usage dominates queue depth");
    }

    #[test]
    fn blind_probe_matches_route() {
        // Round-robin: probing members 0..n of a same-instant group with
        // offsets then committing once reproduces n sequential route() calls.
        let v = views(&[(0, 0, 0.0), (2, 0, 0.0), (5, 0, 0.0)]);
        let mut blind = Router::new(RoutingPolicy::RoundRobin);
        let mut seq = Router::new(RoutingPolicy::RoundRobin);
        for round in 0..3 {
            let group: Vec<usize> = (0..4)
                .map(|n| blind.blind_probe(&v, n, &req(round * 4 + n)).unwrap())
                .collect();
            blind.commit_blind(group.len());
            let expect: Vec<usize> =
                (0..4).map(|n| seq.route(&v, &req(round * 4 + n))).collect();
            assert_eq!(group, expect, "round {round}");
        }
        assert_eq!(blind.dispatched, seq.dispatched);

        // Affinity: unpinned session is not blind; pinned session is, and
        // the probe matches the sticky route without mutating state.
        let mut r = Router::new(RoutingPolicy::SessionAffinity);
        assert_eq!(r.blind_probe(&v, 0, &req(3)), None, "unpinned session reads load");
        let pinned = r.route(&v, &req(3));
        assert_eq!(r.blind_probe(&v, 7, &req(3 + 64)), Some(pinned), "nth-independent");
        let gone = views(&[(2, 0, 0.0), (5, 0, 0.0)]);
        assert_eq!(r.blind_probe(&gone, 0, &req(3 + 64)), None, "pinned replica drained");

        // Load-aware policies never qualify.
        let r = Router::new(RoutingPolicy::JoinShortestQueue);
        assert_eq!(r.blind_probe(&v, 0, &req(0)), None);
        let r = Router::new(RoutingPolicy::LeastKvPressure);
        assert_eq!(r.blind_probe(&v, 0, &req(0)), None);
    }

    #[test]
    fn prefix_aware_scores_residency_against_load() {
        use crate::cluster::prefixcache::{PrefixCacheCfg, PrefixState};
        let mut r = Router::new(RoutingPolicy::PrefixAware);
        let v = views(&[(0, 0, 0.0), (1, 3, 0.0), (2, 1, 0.0)]);
        // No tier wired at all: pure JSQ.
        assert_eq!(r.route(&v, &preq(0, 500, 7, 400)), 0);
        let cfg = PrefixCacheCfg { load_penalty: 64.0, ..PrefixCacheCfg::default() };
        let mut ps = PrefixState::new(cfg);
        // Cold request under a tier: still JSQ.
        assert_eq!(r.route_with(&v, &preq(1, 500, 0, 0), Some(&ps)), 0);
        // Replica 1 holds the whole chain: full hit beats its longer queue.
        ps.admit(1, &preq(2, 500, 7, 0), 0.0);
        assert_eq!(r.route_with(&v, &preq(3, 500, 7, 400), Some(&ps)), 1);
        // Partial residency (300 of 400 shared) on a loaded replica loses
        // once the load penalty outweighs the resident tokens.
        let mut ps2 = PrefixState::new(cfg);
        ps2.admit(1, &preq(4, 300, 9, 0), 0.0);
        let heavy = views(&[(0, 0, 0.0), (1, 10, 0.0)]);
        assert_eq!(
            r.route_with(&heavy, &preq(5, 500, 9, 400), Some(&ps2)),
            0,
            "300 resident − 64·10 pending < 0 → JSQ fallback"
        );
        let light = views(&[(0, 0, 0.0), (1, 2, 0.0)]);
        assert_eq!(
            r.route_with(&light, &preq(6, 500, 9, 400), Some(&ps2)),
            1,
            "300 resident − 64·2 pending > 0 → partial hit wins"
        );
    }

    #[test]
    fn prefix_blind_probe_requires_pure_touch() {
        use crate::cluster::prefixcache::{PrefixCacheCfg, PrefixState};
        let r = Router::new(RoutingPolicy::PrefixAware);
        let v = views(&[(0, 0, 0.1), (1, 0, 0.1)]);
        // No tier / cold request: never blind.
        assert_eq!(r.blind_probe(&v, 0, &preq(0, 500, 7, 400)), None);
        let mut ps = PrefixState::new(PrefixCacheCfg::default());
        assert_eq!(r.blind_probe_with(&v, 0, &preq(0, 500, 0, 0), Some(&ps)), None);
        // Fully resident below the watermark: blind, and it matches route.
        ps.admit(1, &preq(1, 500, 7, 0), 0.0);
        let probe = r.blind_probe_with(&v, 3, &preq(2, 500, 7, 400), Some(&ps));
        assert_eq!(probe, Some(1), "nth-independent full-hit pure touch");
        let mut r2 = Router::new(RoutingPolicy::PrefixAware);
        assert_eq!(r2.route_with(&v, &preq(2, 500, 7, 400), Some(&ps)), 1);
        // A longer prompt would grow the store entry: not a pure touch.
        assert_eq!(r.blind_probe_with(&v, 0, &preq(3, 600, 7, 400), Some(&ps)), None);
        // KV above the watermark can shrink the budget: not blind either.
        let hot = views(&[(0, 0, 0.1), (1, 0, 0.95)]);
        assert_eq!(r.blind_probe_with(&hot, 0, &preq(4, 500, 7, 400), Some(&ps)), None);
    }

    #[test]
    fn session_pins_are_capped_and_recycled() {
        let mut r = Router::with_session_cap(RoutingPolicy::SessionAffinity, 8);
        let v = views(&[(0, 0, 0.0), (1, 0, 0.0)]);
        // 40 distinct sessions (ids 0..40 < AFFINITY_SESSIONS) against an
        // 8-pin cap: the map must never exceed the cap.
        for id in 0..40 {
            r.route(&v, &req(id));
            assert!(r.sessions_pinned() <= 8, "pin table exceeded cap at id {id}");
        }
        // Recycling is FIFO: the most recent 8 sessions are still pinned
        // (their repeat routes stay sticky), the oldest were recycled.
        let pinned_before = r.sessions_pinned();
        r.route(&v, &req(39 + 64)); // session 39 again: sticky, no new pin
        assert_eq!(r.sessions_pinned(), pinned_before);
        // Purging a drained replica drops exactly its pins and changes no
        // subsequent decision vs the JSQ-and-repin fallback.
        let pins = r.sessions_pinned();
        r.purge_replica(0);
        assert!(r.sessions_pinned() <= pins);
        let v1 = views(&[(1, 0, 0.0)]);
        assert_eq!(r.route(&v1, &req(0)), 1);
    }

    #[test]
    fn affinity_is_sticky_until_drain() {
        let mut r = Router::new(RoutingPolicy::SessionAffinity);
        let v = views(&[(0, 0, 0.0), (1, 5, 0.0)]);
        let first = r.route(&v, &req(3));
        assert_eq!(first, 0, "initial placement is JSQ");
        // Same session (id ≡ 3 mod 64) sticks even when load flips.
        let v_flipped = views(&[(0, 50, 0.0), (1, 0, 0.0)]);
        assert_eq!(r.route(&v_flipped, &req(3 + 64)), 0);
        // Replica 0 drained: session remaps to an active replica.
        let v_drained = views(&[(1, 0, 0.0)]);
        assert_eq!(r.route(&v_drained, &req(3 + 128)), 1);
        // ...and stays remapped afterwards.
        let v_back = views(&[(0, 0, 0.0), (1, 9, 0.0)]);
        assert_eq!(r.route(&v_back, &req(3 + 192)), 1);
    }

    fn treq(id: usize, tenant: u16) -> Request {
        Request {
            id,
            arrival: 0.0,
            prompt_len: 100,
            output_len: 10,
            tenant,
            prefix: 0,
            shared_len: 0,
        }
    }

    fn spec(weight: f64, quota: usize) -> TenantSpec {
        TenantSpec { weight, admission_quota: quota, ..TenantSpec::default() }
    }

    #[test]
    fn wfq_serves_backlogs_in_weight_proportion() {
        // Tenant 0 weight 2, tenant 1 weight 1: over a saturated backlog the
        // dispatch order must interleave 2:1.
        let mut g = TenantGate::new(WfqCfg::new(vec![spec(2.0, usize::MAX), spec(1.0, usize::MAX)]));
        for i in 0..6 {
            g.push(treq(i, 0));
        }
        for i in 6..9 {
            g.push(treq(i, 1));
        }
        let order: Vec<u16> = std::iter::from_fn(|| g.pop_next()).map(|r| r.tenant).collect();
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
        assert!(!g.backlogged());
        assert_eq!(g.inflight_total(), 9, "pops charge in-flight slots");
    }

    #[test]
    fn wfq_tie_breaks_by_tenant_index() {
        // Equal weights, same stamp sequence: lower tenant index wins ties.
        let mut g = TenantGate::new(WfqCfg::uniform(2));
        g.push(treq(0, 1));
        g.push(treq(1, 0));
        assert_eq!(g.pop_next().unwrap().tenant, 0);
        assert_eq!(g.pop_next().unwrap().tenant, 1);
    }

    #[test]
    fn quota_holds_tenant_back_until_completion() {
        let mut g = TenantGate::new(WfqCfg::new(vec![spec(1.0, 1), spec(1.0, usize::MAX)]));
        g.push(treq(0, 0));
        g.push(treq(1, 0));
        g.push(treq(2, 1));
        assert_eq!(g.pop_next().unwrap().id, 0);
        // Tenant 0 at quota: its second request is skipped, tenant 1 runs.
        assert_eq!(g.pop_next().unwrap().id, 2);
        assert!(g.pop_next().is_none(), "only tenant 0 queued, and it is at quota");
        assert_eq!(g.queued_for(0), 1);
        assert_eq!(g.inflight_for(0), 1);
        g.on_complete(0);
        assert_eq!(g.pop_next().unwrap().id, 1, "completion frees the quota slot");
    }

    #[test]
    fn capacity_caps_total_inflight() {
        let mut g = TenantGate::new(WfqCfg::uniform(2).with_capacity(2));
        for i in 0..4 {
            g.push(treq(i, (i % 2) as u16));
        }
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_none(), "global capacity reached");
        assert_eq!(g.queued(), 2);
        g.on_complete(0);
        assert!(g.pop_next().is_some());
        assert!(g.pop_next().is_none());
    }

    #[test]
    fn out_of_range_labels_clamp_to_last_tenant() {
        let mut g = TenantGate::new(WfqCfg::uniform(2));
        g.push(treq(0, 9));
        assert_eq!(g.queued_for(1), 1, "label 9 folds into the last tenant");
        let r = g.pop_next().unwrap();
        assert_eq!(r.tenant, 9, "the request itself keeps its label");
        g.on_complete(9);
        assert_eq!(g.inflight_for(1), 0);
    }
}
