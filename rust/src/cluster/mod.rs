//! Cluster serving layer: N engine replicas co-simulated in one
//! virtual-time loop.
//!
//! The intra-GPU work (partitioning, phase scheduling) lives in
//! [`crate::engine`]; this module asks the production questions one layer
//! up, in the spirit of DistServe/DynaServe-style engine-level serving:
//!
//! * a [`Router`] with pluggable policies dispatches every arrival to
//!   exactly one active replica ([`router::RoutingPolicy`]);
//! * an optional [`Autoscaler`] adds replicas or drains them, driven by the
//!   calibrated cost model's capacity prediction plus live per-replica KV
//!   watermarks, under an explicit hysteresis window
//!   ([`autoscaler::AutoscalerCfg`]);
//! * fleet metrics are aggregated by *merging* per-replica run metrics and
//!   latency histograms ([`crate::metrics::RunMetrics::merge`],
//!   [`crate::metrics::Histogram::merge`]).
//!
//! ## Event-queue co-simulation (§Perf)
//!
//! [`Cluster::run`] is an event-driven loop over a binary min-heap keyed by
//! each replica's next internal event time, so one virtual event costs
//! O(log R) instead of the O(R) full-fleet scan of the historical loop
//! (retained verbatim as [`Cluster::run_reference`] for differential
//! testing and the before/after benchmark). Invariants:
//!
//! * **Key authority.** `key_of[i]` holds replica `i`'s authoritative next
//!   event time (`NaN` = none). Heap entries are *hints*: an entry whose
//!   integer key does not match `f64_total_key(key_of[i])`, or whose
//!   replica has retired, is stale and is lazily dropped at pop time.
//!   Entries are never removed eagerly; a replica may have several stale
//!   entries but at most one live entry.
//! * **Key refresh.** A replica's next event can only change when it is
//!   stepped or injected into, so keys are refreshed exactly once per
//!   (replica, processed event) — after the step — and nowhere else.
//! * **Monotonicity.** Every key pushed after a step at time `t` is > `t`,
//!   and arrivals/ticks are consumed in order, so processed event times
//!   are nondecreasing (property-tested in `tests/prop_cluster.rs`).
//! * **Priming.** New replicas (initial fleet and autoscaler-spawned)
//!   carry no event key (fresh engines expose no events) but are queued in
//!   a pending-first-step list, drained into the step set at the next
//!   processed event — exactly when the reference loop first steps them,
//!   which pins the engines' trajectory-accounting start time. The list
//!   never feeds the next-event minimum, so a fresh replica can neither
//!   pull the fleet clock backward nor conjure a spurious event.
//! * **Equivalence.** A replica that is *not* stepped at a foreign event
//!   cannot change observable state (pending, KV usage, completions), so
//!   skipping it is behavior-preserving; `tests/golden_digest.rs` asserts
//!   `RunMetrics` equivalence (structural identity, virtual times within
//!   1 ns — see [`crate::metrics::RunMetrics::deviation`]) against
//!   [`Cluster::run_reference`] across engines, fleet sizes, policies,
//!   and autoscale configs.
//!
//! Alongside the event queue, the loop maintains the fleet pending count
//! and in-service/active counts incrementally (the reference loop re-sums
//! them every event) and reuses one `ReplicaView` buffer for routing.
//!
//! ## Sharded parallel execution (§Perf)
//!
//! [`Cluster::run_parallel`] (in [`parallel`]) shards the fleet across
//! worker threads and advances each shard independently between
//! interaction boundaries (arrivals and autoscaler ticks), synchronizing
//! only there. The *equivalence* invariant above is what makes this exact
//! rather than approximate: between boundaries no replica can observe
//! another, so per-shard execution reproduces the sequential trajectory
//! bit for bit and [`ClusterMetrics::digest`] is identical for any thread
//! count and any synchronization window. Streaming workloads (requests
//! from an iterator instead of a materialized trace) enter through the
//! [`Arrivals`] abstraction and [`Cluster::run_parallel_stream`].

pub mod autoscaler;
pub mod parallel;
pub mod prefixcache;
pub mod replica;
pub mod router;

pub use autoscaler::{Autoscaler, AutoscalerCfg, FleetObs, ScaleObjective};
pub use parallel::{
    plan_rebalance, Arrivals, ParallelCfg, SliceArrivals, StealCfg, StreamArrivals,
};
pub use prefixcache::{PrefixCacheCfg, PrefixState, PrefixStats, PrefixStore, TierCfg};
pub use replica::{Replica, ReplicaState};
pub use router::{ReplicaView, Router, RoutingPolicy, TenantGate, WfqCfg};

use prefixcache::PrefixHit;

use crate::costmodel::calibrate;
use crate::engine::common::ArrivalFeed;
use crate::engine::{Engine, EngineCfg, EngineKind};
use crate::metrics::{Histogram, RunMetrics, Summary, TenantSummary};
use crate::trace::{EventKind, Sampler, Tracer, FLEET};
use crate::util::f64_total_key;
use crate::workload::{Request, TenantSpec};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Fleet configuration.
#[derive(Debug, Clone)]
pub struct ClusterCfg {
    pub kind: EngineKind,
    pub engine: EngineCfg,
    /// Initial replica count (clamped into the autoscaler's bounds when
    /// autoscaling is enabled).
    pub replicas: usize,
    pub policy: RoutingPolicy,
    pub autoscale: Option<AutoscalerCfg>,
    /// Multi-tenant admission: a weighted-fair-queueing gate with
    /// per-tenant quotas in front of the router (see
    /// [`router::TenantGate`]). `None` keeps the single-queue fast path
    /// untouched — every loop, sequential and parallel, is byte-for-byte
    /// the pre-tenant code when this is off.
    pub wfq: Option<WfqCfg>,
    /// Fleet prefix-cache tier (see [`prefixcache`]). `None` disables the
    /// machinery entirely — engines keep their private prefix models and
    /// every loop is byte-for-byte the pre-prefix code — unless the policy
    /// is [`RoutingPolicy::PrefixAware`], which auto-fills the default
    /// config (the policy is meaningless without a tier to read).
    pub prefix: Option<PrefixCacheCfg>,
}

impl ClusterCfg {
    pub fn new(
        kind: EngineKind,
        engine: EngineCfg,
        replicas: usize,
        policy: RoutingPolicy,
    ) -> Self {
        assert!(replicas >= 1, "a cluster needs at least one replica");
        ClusterCfg { kind, engine, replicas, policy, autoscale: None, wfq: None, prefix: None }
    }

    /// The prefix tier this config runs with: explicit, auto-filled for
    /// [`RoutingPolicy::PrefixAware`], or none.
    pub fn prefix_cfg(&self) -> Option<PrefixCacheCfg> {
        self.prefix.or_else(|| {
            (self.policy == RoutingPolicy::PrefixAware).then(PrefixCacheCfg::default)
        })
    }
}

/// One applied scale action.
#[derive(Debug, Clone, Copy)]
pub struct ScaleEvent {
    pub time: f64,
    pub from: usize,
    pub to: usize,
}

/// Per-replica accounting surfaced after a run.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStats {
    pub id: usize,
    pub routed: usize,
    pub completed: usize,
    pub started_at: f64,
    /// Retirement time; `None` for replicas alive at the end of the run.
    pub retired_at: Option<f64>,
}

/// Fleet-level result: merged run metrics, merged latency histograms, and
/// the scaling/routing trail.
pub struct ClusterMetrics {
    /// Per-request metrics merged across every replica.
    pub fleet: RunMetrics,
    pub replicas: Vec<ReplicaStats>,
    pub scale_events: Vec<ScaleEvent>,
    /// Hysteresis-suppressed scale proposals.
    pub suppressed_scales: usize,
    /// Integral of in-service replica count over virtual time — the cost
    /// side of the autoscaling trade-off.
    pub replica_seconds: f64,
    pub peak_replicas: usize,
    /// Virtual-time events the co-simulation loop processed (arrivals,
    /// replica completions, autoscaler ticks) — divided by wall time, this
    /// is the events/sec figure in `BENCH_hotpath.json`.
    pub events: usize,
    /// TTFT / TBT distributions, merged from per-replica histograms.
    pub ttft_hist: Histogram,
    pub tbt_hist: Histogram,
    /// Replica migrations applied by the parallel loop's shard scheduler
    /// (always 0 for the sequential loops and with stealing disabled).
    pub rebalances: usize,
    /// Engine steps executed per worker shard over the whole run — the
    /// balance evidence behind the `BENCH_hotpath.json` skew sweep. Empty
    /// for the sequential loops.
    pub shard_steps: Vec<u64>,
    /// Fleet prefix-cache counters (all zero when the tier is disabled).
    /// A deterministic function of the routed sequence, so they are folded
    /// into the digest — all three loops must agree on every field.
    pub prefix: PrefixStats,
}

impl ClusterMetrics {
    pub fn summary(&self) -> Summary {
        self.fleet.summary()
    }

    /// Behavioral digest of a fleet run: FNV-1a over the per-request
    /// [`RunMetrics::digest`] plus the fleet-level surface — peak size,
    /// scale trail (1 ns-quantized times), suppressed proposals, and the
    /// per-replica lifecycle/accounting tuples. This is the equality the
    /// parallel loop is held to: `tests/golden_digest.rs` and
    /// `tests/prop_cluster.rs` assert [`Cluster::run_parallel`] matches
    /// [`Cluster::run`] digest-for-digest across thread counts and window
    /// sizes.
    ///
    /// Four fields are deliberately excluded: `events` (the loops count
    /// different things — iterations vs. rounds plus per-shard steps),
    /// `replica_seconds` (the parallel loop computes it analytically, so
    /// it differs from the sequential running sum by float-summation
    /// noise; the golden tests bound that difference at 1e-6 instead),
    /// and `rebalances` / `shard_steps` (where work *ran* is scheduling
    /// metadata, not behavior — excluding them is precisely what lets the
    /// golden tests assert that work stealing changes the digest not at
    /// all).
    pub fn digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(FNV_PRIME);
            }
        }
        /// Quantize a virtual time to integer nanoseconds.
        fn q(x: f64) -> u64 {
            (x * 1e9).round() as i64 as u64
        }
        let mut h = FNV_OFFSET;
        mix(&mut h, self.fleet.digest());
        mix(&mut h, self.peak_replicas as u64);
        mix(&mut h, self.suppressed_scales as u64);
        mix(&mut h, self.scale_events.len() as u64);
        for e in &self.scale_events {
            mix(&mut h, q(e.time));
            mix(&mut h, e.from as u64);
            mix(&mut h, e.to as u64);
        }
        mix(&mut h, self.replicas.len() as u64);
        for r in &self.replicas {
            mix(&mut h, r.id as u64);
            mix(&mut h, r.routed as u64);
            mix(&mut h, r.completed as u64);
            mix(&mut h, q(r.started_at));
            mix(&mut h, r.retired_at.map_or(u64::MAX, q));
        }
        mix(&mut h, self.ttft_hist.count());
        mix(&mut h, self.tbt_hist.count());
        mix(&mut h, self.prefix.lookups);
        mix(&mut h, self.prefix.local_hits);
        mix(&mut h, self.prefix.tier_hits);
        mix(&mut h, self.prefix.misses);
        mix(&mut h, self.prefix.evictions);
        mix(&mut h, self.prefix.tokens_saved);
        h
    }

    /// Fraction of *offered* requests (completed + timed out) that finished
    /// within both per-request SLOs.
    pub fn slo_attainment(&self, ttft_slo: f64, norm_slo: f64) -> f64 {
        let total = self.fleet.records.len() + self.fleet.timeouts;
        if total == 0 {
            return 1.0;
        }
        let ok = self
            .fleet
            .records
            .iter()
            .filter(|r| r.ttft() <= ttft_slo && r.normalized_latency() <= norm_slo)
            .count();
        ok as f64 / total as f64
    }

    /// Per-tenant completion / SLO-attainment / goodput rows (see
    /// [`RunMetrics::tenant_report`]). `specs` is the same table handed to
    /// [`WfqCfg`]; pass `&[]` for single-tenant runs.
    pub fn tenant_report(&self, specs: &[TenantSpec]) -> Vec<TenantSummary> {
        self.fleet.tenant_report(specs)
    }

    /// DistServe-style fleet goodput: completed requests that met their
    /// tenant's SLOs per unit virtual time ([`RunMetrics::goodput`]).
    pub fn goodput(&self, specs: &[TenantSpec]) -> f64 {
        self.fleet.goodput(specs)
    }

    /// Goodput per replica-second — the objective the goodput-per-cost
    /// autoscaler mode optimizes for, reported for observability.
    pub fn goodput_per_cost(&self, specs: &[TenantSpec]) -> f64 {
        if self.replica_seconds <= 0.0 {
            return 0.0;
        }
        // goodput is slo-ok/span, so multiplying the span back recovers the
        // raw slo-ok count; dividing by replica-seconds prices it in cost.
        self.goodput(specs) * self.fleet.span() / self.replica_seconds
    }
}

/// Staleness predicate shared by every heap inspection: a popped/peeked
/// entry `(k, i)` is live iff it still matches replica i's authoritative
/// key (`key_of[i]`, `NaN` = no event) and the replica is still in
/// service. Anything else is a lazily-dropped leftover.
fn entry_live(key_of: &[f64], replicas: &[Replica], k: u64, i: usize) -> bool {
    i < key_of.len()
        && !key_of[i].is_nan()
        && f64_total_key(key_of[i]) == k
        && replicas[i].in_service()
}

/// Register newly created replicas (indices `key_of.len()..n`): no event
/// key yet (fresh engines expose none), but queued in `primed` so each
/// one's first step lands on the next global event after its creation —
/// matching when the reference loop first steps it, which pins the
/// engines' trajectory-accounting start time. Crucially the primed list
/// does NOT feed the next-event minimum: a fresh replica must never pull
/// the fleet clock backward or conjure an event of its own.
fn prime_new_replicas(key_of: &mut Vec<f64>, primed: &mut Vec<usize>, n: usize) {
    while key_of.len() < n {
        primed.push(key_of.len());
        key_of.push(f64::NAN);
    }
}

fn mean_lengths(trace: &[Request]) -> (f64, f64) {
    if trace.is_empty() {
        return (1.0, 1.0);
    }
    let n = trace.len() as f64;
    let p: usize = trace.iter().map(|r| r.plen()).sum();
    let o: usize = trace.iter().map(|r| r.olen()).sum();
    (p as f64 / n, o as f64 / n)
}

/// A replica fleet plus its router; one instance per run.
pub struct Cluster {
    pub cfg: ClusterCfg,
    pub replicas: Vec<Replica>,
    pub router: Router,
    /// Fleet prefix-cache state (see [`prefixcache`]); rebuilt per run from
    /// [`ClusterCfg::prefix_cfg`]. `None` = machinery off.
    pub prefix: Option<PrefixState>,
    /// When set, [`Cluster::run`] records every processed event time into
    /// [`Cluster::event_times`] (property tests assert monotonicity).
    pub record_event_times: bool,
    pub event_times: Vec<f64>,
    /// Trace handle shared by the fleet loop, router hooks, autoscaler
    /// hooks, and (via [`crate::engine::Engine::set_tracer`]) every replica
    /// engine. Disabled by default — see [`crate::trace`].
    pub tracer: Tracer,
    /// Largest event-heap length observed during the last [`Cluster::run`]
    /// (stale hints included) — the quantity the compaction bound caps.
    pub heap_peak: usize,
    /// Stale-entry compactions performed during the last [`Cluster::run`].
    pub heap_compactions: usize,
}

impl Cluster {
    pub fn new(cfg: ClusterCfg) -> Self {
        let policy = cfg.policy;
        Cluster {
            cfg,
            replicas: Vec::new(),
            router: Router::new(policy),
            prefix: None,
            record_event_times: false,
            event_times: Vec::new(),
            tracer: Tracer::default(),
            heap_peak: 0,
            heap_compactions: 0,
        }
    }

    /// Attach the cluster tracer to every freshly built replica and emit
    /// its `ReplicaStart`. Shared by both loops and [`Cluster::rescale`].
    fn trace_replica_start(&mut self, idx: usize, now: f64) {
        let rep = &mut self.replicas[idx];
        rep.eng.set_tracer(self.tracer.for_replica(rep.id as u32));
        self.tracer.emit_for(rep.id as u32, now, EventKind::ReplicaStart);
    }

    /// Emit one `Sample` per in-service replica for every sampling grid
    /// point crossed since the previous event (no-op unless the tracer has
    /// both a sink and a sampling interval). Purely observational: adds no
    /// loop events, so digests and event counters match untraced runs.
    fn trace_samples(&self, sampler: &mut Option<Sampler>, t: f64) {
        let Some(s) = sampler.as_mut() else { return };
        s.due(t, |ts| {
            for rep in self.replicas.iter().filter(|r| r.in_service()) {
                let snap = rep.eng.snapshot();
                self.tracer.emit_for(
                    rep.id as u32,
                    ts,
                    EventKind::Sample {
                        kv_usage: snap.kv_usage,
                        waiting: snap.waiting,
                        running: snap.running,
                        pending: rep.eng.pending(),
                        sm_prefill: snap.sm_prefill,
                        inflight: snap.inflight,
                    },
                );
            }
        });
    }

    /// Emit the fleet-level `Arrival` + `Route` pair for one dispatch.
    fn trace_route(&self, r: &Request, target: usize, views: &[ReplicaView], t: f64) {
        if !self.tracer.enabled() {
            return;
        }
        self.tracer.emit_for(FLEET, r.arrival, EventKind::Arrival { req: r.id });
        self.trace_route_only(r, target, views, t);
    }

    /// The `Route` half of [`Cluster::trace_route`]; the WFQ path emits
    /// `Arrival` at enqueue time and `TenantAdmit` + `Route` at dispatch.
    fn trace_route_only(&self, r: &Request, target: usize, views: &[ReplicaView], t: f64) {
        let v = views.iter().find(|v| v.index as usize == target);
        self.tracer.emit_for(
            FLEET,
            t,
            EventKind::Route {
                req: r.id,
                target,
                policy: self.router.policy.name(),
                pending: v.map_or(0, |v| v.pending as usize),
                kv_usage: v.map_or(0.0, |v| v.kv_usage),
            },
        );
    }

    /// Fleet-level `Arrival` for a request entering the WFQ gate.
    fn trace_arrival(&self, r: &Request) {
        if self.tracer.enabled() {
            self.tracer.emit_for(FLEET, r.arrival, EventKind::Arrival { req: r.id });
        }
    }

    /// `TenantAdmit` + `Route` for a gate dispatch at time `t`.
    fn trace_admit(&self, r: &Request, target: usize, views: &[ReplicaView], t: f64) {
        if !self.tracer.enabled() {
            return;
        }
        self.tracer.emit_for(
            FLEET,
            t,
            EventKind::TenantAdmit { req: r.id, tenant: r.tid() },
        );
        self.trace_route_only(r, target, views, t);
    }

    /// `TenantThrottle` for a request the gate held back at time `t`.
    fn trace_throttle(&self, req: usize, tenant: u16, queued: usize, t: f64) {
        if self.tracer.enabled() {
            self.tracer.emit_for(
                FLEET,
                t,
                EventKind::TenantThrottle { req, tenant: tenant as usize, queued },
            );
        }
    }

    /// Commit one routed arrival against the prefix tier: classify +
    /// account + admit into the target's store, emit the typed prefix
    /// events when tracing (observational only — no loop events, no state
    /// the untraced run lacks), and return the effective prompt to pin on
    /// the engine. `None` (machinery off) means the engine keeps its own
    /// prefix model. An associated fn over split borrows so the loops can
    /// hold `&mut self.replicas[target]` around the call site.
    fn prefix_admit(
        prefix: &mut Option<PrefixState>,
        tracer: &Tracer,
        views: &[ReplicaView],
        r: &Request,
        target: usize,
        t: f64,
    ) -> Option<usize> {
        let ps = prefix.as_mut()?;
        let kv = views
            .iter()
            .find(|v| v.index as usize == target)
            .map_or(0.0, |v| v.kv_usage);
        let ev0 = ps.stats.evictions;
        let (eff, hit) = ps.admit(target, r, kv);
        if tracer.enabled() {
            let saved = r.plen().saturating_sub(eff);
            match hit {
                PrefixHit::Local => tracer.emit_for(
                    FLEET,
                    t,
                    EventKind::PrefixHit { req: r.id, replica: target, saved },
                ),
                PrefixHit::Tier => tracer.emit_for(
                    FLEET,
                    t,
                    EventKind::PrefixFetch { req: r.id, replica: target, saved },
                ),
                PrefixHit::Miss => {
                    tracer.emit_for(FLEET, t, EventKind::PrefixMiss { req: r.id, replica: target })
                }
                PrefixHit::Cold => {}
            }
            let evicted = (ps.stats.evictions - ev0) as usize;
            if evicted > 0 {
                tracer.emit_for(FLEET, t, EventKind::PrefixEvict { replica: target, evicted });
            }
        }
        Some(eff)
    }

    fn active_views(&self) -> Vec<ReplicaView> {
        self.replicas.iter().filter(|r| r.is_active()).map(|r| r.view()).collect()
    }

    fn active_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_active()).count()
    }

    /// Build the autoscaler (if configured) for a fresh run.
    fn build_scaler(&self, trace: &[Request]) -> Option<Autoscaler> {
        self.cfg.autoscale.map(|acfg| {
            let cost = calibrate(&self.cfg.engine.gpu);
            let (mp, mo) = mean_lengths(trace);
            Autoscaler::new(
                acfg,
                autoscaler::predict_replica_rate(&cost, &self.cfg.engine, mp, mo),
            )
        })
    }

    /// Co-simulate the fleet over a time-sorted trace with the O(log R)
    /// event-queue loop (see the module docs for the queue invariants).
    pub fn run(&mut self, trace: &[Request]) -> ClusterMetrics {
        let cfg = self.cfg.clone();
        let n0 = match &cfg.autoscale {
            Some(a) => cfg.replicas.clamp(a.min_replicas, a.max_replicas),
            None => cfg.replicas,
        };
        self.replicas = (0..n0).map(|i| Replica::new(i, cfg.kind, &cfg.engine, 0.0)).collect();
        self.router = Router::new(cfg.policy);
        self.prefix = cfg.prefix_cfg().map(PrefixState::new);
        self.event_times.clear();
        self.heap_peak = 0;
        self.heap_compactions = 0;
        for i in 0..n0 {
            self.trace_replica_start(i, 0.0);
        }
        let mut sampler = Sampler::new(&self.tracer);
        let mut scaler = self.build_scaler(trace);
        let mut next_tick = scaler.as_ref().map(|s| s.cfg.interval);

        let mut feed = ArrivalFeed::new(trace);
        let mut fleet = RunMetrics::default();
        let mut ttft_hist = Histogram::new();
        let mut tbt_hist = Histogram::new();
        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut replica_seconds = 0.0f64;
        let mut peak_replicas = n0;
        let mut last_t = 0.0f64;
        let mut arrivals_since_tick = 0usize;
        let mut next_id = n0;
        let mut events = 0usize;

        // Event-queue state. `key_of[i]` is replica i's authoritative next
        // event time (NaN = none); heap entries are lazily-invalidated
        // hints; `live_events` counts in-service replicas with a key.
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        let mut key_of: Vec<f64> = Vec::new();
        let mut live_events = 0usize;
        // Replicas awaiting their first step (stepped at the next event).
        let mut primed: Vec<usize> = Vec::new();
        // Incremental fleet counters (the reference loop re-sums these).
        let mut pending_total = 0usize;
        let mut in_service = n0;
        let mut active_cnt = n0;
        // Reusable per-event scratch.
        let mut stepped: Vec<usize> = Vec::new();
        let mut views_buf: Vec<ReplicaView> = Vec::new();
        let mut kv_buf: Vec<f64> = Vec::new();

        // Multi-tenant WFQ admission gate (`None` → untagged fast path,
        // byte-for-byte the pre-tenant loop). `wfq_ready_at` is the gate's
        // pseudo-event: completions at `t` freed quota/capacity slots while
        // arrivals were still queued, so the next iteration re-enters the
        // dispatch loop at the same virtual instant — pure virtual-time
        // state, never wall clock, so all three loops replay it exactly.
        let mut gate = cfg.wfq.clone().map(TenantGate::new);
        let mut wfq_ready_at: Option<f64> = None;
        let mut held: Vec<(usize, u16)> = Vec::new();

        prime_new_replicas(&mut key_of, &mut primed, self.replicas.len());

        loop {
            if feed.exhausted()
                && pending_total == 0
                && gate.as_ref().map_or(true, |g| g.queued() == 0)
            {
                break;
            }

            // Earliest live replica event (skim stale heap entries).
            let heap_min = loop {
                match heap.peek() {
                    None => break None,
                    Some(&Reverse((k, i))) => {
                        if entry_live(&key_of, &self.replicas, k, i) {
                            break Some(key_of[i]);
                        }
                        heap.pop();
                    }
                }
            };

            // Fleet-wide next event: earliest arrival, earliest replica
            // event, or the next autoscaler tick.
            let mut t = f64::INFINITY;
            if let Some(a) = feed.peek_time() {
                t = t.min(a);
            }
            if let Some(h) = heap_min {
                t = t.min(h);
            }
            if let Some(tick) = next_tick {
                t = t.min(tick);
            }
            if let Some(w) = wfq_ready_at {
                t = t.min(w);
            }
            if !t.is_finite() {
                t = self.replicas.iter().map(|r| r.eng.now()).fold(last_t, f64::max);
            }
            if t > cfg.engine.max_virtual_time {
                break;
            }
            if wfq_ready_at.is_some_and(|w| w <= t) {
                wfq_ready_at = None;
            }
            self.trace_samples(&mut sampler, t);

            // Replica-seconds accrue for every in-service replica.
            replica_seconds += in_service as f64 * (t - last_t).max(0.0);
            last_t = t;
            events += 1;
            if self.record_event_times {
                self.event_times.push(t);
            }

            stepped.clear();

            match gate.as_mut() {
                // Route arrivals due at t. Views are rebuilt per arrival
                // (into the reused buffer) so load-aware policies see
                // same-instant dispatches.
                None => {
                    for r in feed.pop_until(t) {
                        views_buf.clear();
                        views_buf.extend(
                            self.replicas.iter().filter(|x| x.is_active()).map(|x| x.view()),
                        );
                        let target = self.router.route_with(&views_buf, r, self.prefix.as_ref());
                        self.trace_route(r, target, &views_buf, t);
                        let eff = Self::prefix_admit(
                            &mut self.prefix,
                            &self.tracer,
                            &views_buf,
                            r,
                            target,
                            t,
                        );
                        // Replicas are never removed from the vec (only
                        // retired in place), so fleet position == replica id.
                        let rep = &mut self.replicas[target];
                        debug_assert_eq!(rep.id, target);
                        match eff {
                            Some(e) => rep.eng.inject_effective(*r, Some(e)),
                            None => rep.eng.inject(*r),
                        }
                        rep.routed += 1;
                        pending_total += 1;
                        arrivals_since_tick += 1;
                        stepped.push(target);
                    }
                }
                // Multi-tenant path: arrivals enter the WFQ gate, which
                // decides dispatch order (virtual-time fair queueing) and
                // admission (per-tenant quota + global capacity). The
                // dispatch loop also runs when a completion re-armed the
                // gate at this instant with no new arrivals.
                Some(g) => {
                    held.clear();
                    for r in feed.pop_until(t) {
                        self.trace_arrival(r);
                        g.push(*r);
                        arrivals_since_tick += 1;
                        held.push((r.id, r.tenant));
                    }
                    while let Some(r) = g.pop_next() {
                        views_buf.clear();
                        views_buf.extend(
                            self.replicas.iter().filter(|x| x.is_active()).map(|x| x.view()),
                        );
                        let target = self.router.route_with(&views_buf, &r, self.prefix.as_ref());
                        self.trace_admit(&r, target, &views_buf, t);
                        let eff = Self::prefix_admit(
                            &mut self.prefix,
                            &self.tracer,
                            &views_buf,
                            &r,
                            target,
                            t,
                        );
                        let rep = &mut self.replicas[target];
                        debug_assert_eq!(rep.id, target);
                        match eff {
                            Some(e) => rep.eng.inject_effective(r, Some(e)),
                            None => rep.eng.inject(r),
                        }
                        rep.routed += 1;
                        pending_total += 1;
                        stepped.push(target);
                        held.retain(|&(id, _)| id != r.id);
                    }
                    for &(id, tenant) in &held {
                        self.trace_throttle(id, tenant, g.queued_for(tenant), t);
                    }
                }
            }

            // Pop every replica whose event is due at t.
            while let Some(&Reverse((k, i))) = heap.peek() {
                if !entry_live(&key_of, &self.replicas, k, i) {
                    heap.pop();
                    continue;
                }
                if key_of[i] <= t {
                    heap.pop();
                    key_of[i] = f64::NAN;
                    live_events -= 1;
                    stepped.push(i);
                } else {
                    break;
                }
            }

            // Replicas spawned since the previous event take their first
            // step now (the reference loop steps every replica every event).
            stepped.append(&mut primed);

            // Step the affected replicas to t in replica order (matching the
            // reference loop's full-fleet iteration order), then refresh
            // their event keys.
            stepped.sort_unstable();
            stepped.dedup();
            let mut drained_any = false;
            let mut gate_freed = false;
            for &i in &stepped {
                let rep = &mut self.replicas[i];
                if !rep.in_service() {
                    continue;
                }
                let out = rep.eng.step(t);
                pending_total -= out.completed;
                if let Some(g) = gate.as_mut() {
                    // Diff the engine's record log to learn which tenants
                    // just released in-flight slots (O(new completions);
                    // the cursor is never advanced when the gate is off).
                    let n = rep.eng.records().len();
                    if n > rep.records_seen {
                        for rec in &rep.eng.records()[rep.records_seen..] {
                            g.on_complete(rec.tenant);
                        }
                        rep.records_seen = n;
                        gate_freed = true;
                    }
                }
                match rep.eng.next_event() {
                    Some(e) => {
                        if key_of[i].is_nan() {
                            key_of[i] = e;
                            live_events += 1;
                            heap.push(Reverse((f64_total_key(e), i)));
                        } else if key_of[i] != e {
                            key_of[i] = e;
                            heap.push(Reverse((f64_total_key(e), i)));
                        }
                    }
                    None => {
                        if !key_of[i].is_nan() {
                            key_of[i] = f64::NAN;
                            live_events -= 1;
                        }
                    }
                }
                if rep.drained() {
                    drained_any = true;
                }
            }

            // Completions freed gate slots while arrivals are still held:
            // re-enter the dispatch loop at this same virtual instant.
            if gate_freed && gate.as_ref().is_some_and(|g| g.backlogged()) {
                wfq_ready_at = Some(t);
            }

            // Autoscaler tick: observe the post-step fleet, maybe act.
            if let (Some(s), Some(tick)) = (scaler.as_mut(), next_tick) {
                if t + 1e-12 >= tick {
                    views_buf.clear();
                    views_buf.extend(
                        self.replicas.iter().filter(|x| x.is_active()).map(|x| x.view()),
                    );
                    kv_buf.clear();
                    kv_buf.extend(views_buf.iter().map(|v| v.kv_usage));
                    let obs = FleetObs {
                        now: t,
                        arrival_rate: arrivals_since_tick as f64 / s.cfg.interval,
                        active_replicas: views_buf.len(),
                        total_pending: pending_total,
                        mean_kv: crate::util::mean(&kv_buf),
                        max_kv: kv_buf.iter().fold(0.0f64, |a, &b| a.max(b)),
                    };
                    if let Some(target) = s.decide(&obs) {
                        let from = views_buf.len();
                        self.tracer.emit_for(FLEET, t, EventKind::Scale { from, to: target });
                        self.rescale(target, t, &mut next_id, &cfg);
                        scale_events.push(ScaleEvent { time: t, from, to: target });
                        // Scale actions are rare: recount the fleet and
                        // prime any freshly spawned replicas.
                        prime_new_replicas(&mut key_of, &mut primed, self.replicas.len());
                        in_service = self.replicas.iter().filter(|r| r.in_service()).count();
                        active_cnt = self.active_count();
                        drained_any = true; // a drained-empty replica may retire now
                    }
                    next_tick = Some(tick + s.cfg.interval);
                    arrivals_since_tick = 0;
                }
            }

            // Retire drained replicas, merging their metrics into the pool.
            // (Only reachable right after a step or scale-down, so the scan
            // runs on a vanishing fraction of events.)
            if drained_any {
                for i in 0..self.replicas.len() {
                    if self.replicas[i].drained() {
                        // A replica drained by a scale action (rather than
                        // by its own step) syncs to t first, so trajectory
                        // accounting ends at the same instant as in the
                        // reference loop.
                        if self.replicas[i].eng.now() < t {
                            self.replicas[i].eng.step(t);
                        }
                        if !key_of[i].is_nan() {
                            key_of[i] = f64::NAN;
                            live_events -= 1;
                        }
                        let id = self.replicas[i].id as u32;
                        self.tracer.emit_for(id, t, EventKind::ReplicaRetire);
                        let m = self.replicas[i].retire(t);
                        // Dead session pins fall through to JSQ-and-repin
                        // anyway, so purging them changes no decision; it
                        // just keeps the pin table tombstone-free.
                        self.router.purge_replica(i);
                        ttft_hist.merge(&m.ttft_histogram());
                        tbt_hist.merge(&m.tbt_histogram());
                        fleet.merge(m);
                        in_service -= 1;
                    }
                }
            }

            peak_replicas = peak_replicas.max(active_cnt);

            // Bound stale-hint growth. Key refreshes push a new entry
            // without removing the old one, so under autoscaler churn the
            // heap can hold many dead hints per live key; once stale
            // entries outnumber live ones 2:1 (+ a small constant so tiny
            // fleets still exercise the path), rebuild from the
            // authoritative keys. O(live) rebuild amortized against the
            // ≥ 2·live stale pops it saves, so the loop stays
            // O(events·log R) with the heap capped at ~3·live entries.
            self.heap_peak = self.heap_peak.max(heap.len());
            if heap.len() > 2 * live_events + 16 {
                heap.clear();
                for (i, &k) in key_of.iter().enumerate() {
                    if !k.is_nan() && self.replicas[i].in_service() {
                        heap.push(Reverse((f64_total_key(k), i)));
                    }
                }
                debug_assert_eq!(heap.len(), live_events);
                self.heap_compactions += 1;
            }

            if live_events == 0 && feed.exhausted() && pending_total > 0 {
                // Nothing schedulable fleet-wide and nothing will arrive.
                break;
            }
            if live_events == 0
                && feed.exhausted()
                && pending_total == 0
                && wfq_ready_at.is_none()
                && gate.as_ref().is_some_and(|g| g.queued() > 0)
            {
                // Gate wedged: a zero-quota / zero-capacity config can hold
                // requests forever with nothing in flight to free a slot.
                // Held requests count as timeouts like any other
                // never-completed request.
                break;
            }
        }

        // Collect the survivors, syncing each engine to the loop's final
        // event time (the reference loop stepped every replica there).
        for rep in self.replicas.iter_mut() {
            if rep.in_service() {
                if rep.eng.now() < last_t {
                    rep.eng.step(last_t);
                }
                rep.state = ReplicaState::Draining; // permit retire() bookkeeping
                let m = rep.retire(last_t);
                rep.retired_at = None; // still in service at end of run
                ttft_hist.merge(&m.ttft_histogram());
                tbt_hist.merge(&m.tbt_histogram());
                fleet.merge(m);
            }
        }
        fleet.timeouts = trace.len() - fleet.records.len();

        let replicas = self
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                id: r.id,
                routed: r.routed as usize,
                completed: r.eng.completed(),
                started_at: r.started_at,
                retired_at: r.retired_at,
            })
            .collect();

        ClusterMetrics {
            fleet,
            replicas,
            scale_events,
            suppressed_scales: scaler.as_ref().map_or(0, |s| s.suppressed),
            replica_seconds,
            peak_replicas,
            events,
            ttft_hist,
            tbt_hist,
            rebalances: 0,
            shard_steps: Vec::new(),
            prefix: self.prefix.as_ref().map_or_else(PrefixStats::default, |p| p.stats),
        }
    }

    /// The historical O(R)-per-event co-simulation loop: every iteration
    /// re-sums fleet pending, scans every replica for the minimum next
    /// event, and steps the whole fleet. Retained as the behavioral
    /// reference for [`Cluster::run`] — `tests/golden_digest.rs` asserts
    /// both produce equivalent metrics (structural identity, times within
    /// 1 ns) — and as the baseline side of the `BENCH_hotpath.json` fleet
    /// macro-benchmark.
    pub fn run_reference(&mut self, trace: &[Request]) -> ClusterMetrics {
        let cfg = self.cfg.clone();
        let n0 = match &cfg.autoscale {
            Some(a) => cfg.replicas.clamp(a.min_replicas, a.max_replicas),
            None => cfg.replicas,
        };
        self.replicas = (0..n0).map(|i| Replica::new(i, cfg.kind, &cfg.engine, 0.0)).collect();
        self.router = Router::new(cfg.policy);
        self.prefix = cfg.prefix_cfg().map(PrefixState::new);
        for i in 0..n0 {
            self.trace_replica_start(i, 0.0);
        }
        let mut sampler = Sampler::new(&self.tracer);
        let mut scaler = self.build_scaler(trace);
        let mut next_tick = scaler.as_ref().map(|s| s.cfg.interval);

        let mut feed = ArrivalFeed::new(trace);
        let mut fleet = RunMetrics::default();
        let mut ttft_hist = Histogram::new();
        let mut tbt_hist = Histogram::new();
        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut replica_seconds = 0.0f64;
        let mut peak_replicas = n0;
        let mut last_t = 0.0f64;
        let mut arrivals_since_tick = 0usize;
        let mut next_id = n0;
        let mut events = 0usize;

        // WFQ gate state, mirroring Cluster::run — the reference loop must
        // make identical admission decisions at identical virtual times.
        let mut gate = cfg.wfq.clone().map(TenantGate::new);
        let mut wfq_ready_at: Option<f64> = None;
        let mut held: Vec<(usize, u16)> = Vec::new();

        loop {
            let pending: usize = self.replicas.iter().map(|r| r.eng.pending()).sum();
            if feed.exhausted()
                && pending == 0
                && gate.as_ref().map_or(true, |g| g.queued() == 0)
            {
                break;
            }

            // Fleet-wide next event: earliest arrival, any in-service
            // replica's internal event, or the next autoscaler tick.
            let mut t = f64::INFINITY;
            if let Some(a) = feed.peek_time() {
                t = t.min(a);
            }
            for rep in self.replicas.iter_mut().filter(|r| r.in_service()) {
                if let Some(e) = rep.eng.next_event() {
                    t = t.min(e);
                }
            }
            if let Some(tick) = next_tick {
                t = t.min(tick);
            }
            if let Some(w) = wfq_ready_at {
                t = t.min(w);
            }
            if !t.is_finite() {
                t = self.replicas.iter().map(|r| r.eng.now()).fold(last_t, f64::max);
            }
            if t > cfg.engine.max_virtual_time {
                break;
            }
            if wfq_ready_at.is_some_and(|w| w <= t) {
                wfq_ready_at = None;
            }
            self.trace_samples(&mut sampler, t);

            // Replica-seconds accrue for every in-service replica.
            let in_service = self.replicas.iter().filter(|r| r.in_service()).count();
            replica_seconds += in_service as f64 * (t - last_t).max(0.0);
            last_t = t;
            events += 1;

            match gate.as_mut() {
                // Route arrivals due at t. Views are rebuilt per arrival so
                // load-aware policies see same-instant dispatches.
                None => {
                    for r in feed.pop_until(t) {
                        let views = self.active_views();
                        let target = self.router.route_with(&views, r, self.prefix.as_ref());
                        self.trace_route(r, target, &views, t);
                        let eff = Self::prefix_admit(
                            &mut self.prefix,
                            &self.tracer,
                            &views,
                            r,
                            target,
                            t,
                        );
                        // Replicas are never removed from the vec (only
                        // retired in place), so fleet position == replica id.
                        let rep = &mut self.replicas[target];
                        debug_assert_eq!(rep.id, target);
                        match eff {
                            Some(e) => rep.eng.inject_effective(*r, Some(e)),
                            None => rep.eng.inject(*r),
                        }
                        rep.routed += 1;
                        arrivals_since_tick += 1;
                    }
                }
                // Multi-tenant path: identical gate protocol to
                // Cluster::run — enqueue, WFQ dispatch, throttle trail.
                Some(g) => {
                    held.clear();
                    for r in feed.pop_until(t) {
                        self.trace_arrival(r);
                        g.push(*r);
                        arrivals_since_tick += 1;
                        held.push((r.id, r.tenant));
                    }
                    while let Some(r) = g.pop_next() {
                        let views = self.active_views();
                        let target = self.router.route_with(&views, &r, self.prefix.as_ref());
                        self.trace_admit(&r, target, &views, t);
                        let eff = Self::prefix_admit(
                            &mut self.prefix,
                            &self.tracer,
                            &views,
                            &r,
                            target,
                            t,
                        );
                        let rep = &mut self.replicas[target];
                        debug_assert_eq!(rep.id, target);
                        match eff {
                            Some(e) => rep.eng.inject_effective(r, Some(e)),
                            None => rep.eng.inject(r),
                        }
                        rep.routed += 1;
                        held.retain(|&(id, _)| id != r.id);
                    }
                    for &(id, tenant) in &held {
                        self.trace_throttle(id, tenant, g.queued_for(tenant), t);
                    }
                }
            }

            // Step every in-service replica to the global event time (never
            // past any replica's own next event, by construction of t).
            let mut any_busy = false;
            let mut gate_freed = false;
            for rep in self.replicas.iter_mut().filter(|r| r.in_service()) {
                let out = rep.eng.step(t);
                any_busy |= out.busy;
                if let Some(g) = gate.as_mut() {
                    let n = rep.eng.records().len();
                    if n > rep.records_seen {
                        for rec in &rep.eng.records()[rep.records_seen..] {
                            g.on_complete(rec.tenant);
                        }
                        rep.records_seen = n;
                        gate_freed = true;
                    }
                }
            }

            // Completions freed gate slots while arrivals are still held:
            // re-enter the dispatch loop at this same virtual instant.
            if gate_freed && gate.as_ref().is_some_and(|g| g.backlogged()) {
                wfq_ready_at = Some(t);
            }

            // Autoscaler tick: observe the post-step fleet, maybe act.
            if let (Some(s), Some(tick)) = (scaler.as_mut(), next_tick) {
                if t + 1e-12 >= tick {
                    let views = self.active_views();
                    let kvs: Vec<f64> = views.iter().map(|v| v.kv_usage).collect();
                    let obs = FleetObs {
                        now: t,
                        arrival_rate: arrivals_since_tick as f64 / s.cfg.interval,
                        active_replicas: views.len(),
                        total_pending: self.replicas.iter().map(|r| r.eng.pending()).sum(),
                        mean_kv: crate::util::mean(&kvs),
                        max_kv: kvs.iter().fold(0.0f64, |a, &b| a.max(b)),
                    };
                    if let Some(target) = s.decide(&obs) {
                        let from = views.len();
                        self.tracer.emit_for(FLEET, t, EventKind::Scale { from, to: target });
                        self.rescale(target, t, &mut next_id, &cfg);
                        scale_events.push(ScaleEvent { time: t, from, to: target });
                    }
                    next_tick = Some(tick + s.cfg.interval);
                    arrivals_since_tick = 0;
                }
            }

            // Retire drained replicas, merging their metrics into the pool.
            for rep in self.replicas.iter_mut() {
                if rep.drained() {
                    let id = rep.id;
                    self.tracer.emit_for(id as u32, t, EventKind::ReplicaRetire);
                    let m = rep.retire(t);
                    self.router.purge_replica(id);
                    ttft_hist.merge(&m.ttft_histogram());
                    tbt_hist.merge(&m.tbt_histogram());
                    fleet.merge(m);
                }
            }

            peak_replicas = peak_replicas.max(self.active_count());

            let pending_after: usize = self.replicas.iter().map(|r| r.eng.pending()).sum();
            if !any_busy && feed.exhausted() && pending_after > 0 {
                // Nothing schedulable fleet-wide and nothing will arrive.
                break;
            }
            if !any_busy
                && feed.exhausted()
                && pending_after == 0
                && wfq_ready_at.is_none()
                && gate.as_ref().is_some_and(|g| g.queued() > 0)
            {
                // Gate wedged (zero-quota/zero-capacity config) — mirror
                // the event-queue loop's bail-out; held requests time out.
                break;
            }
        }

        // Collect the survivors.
        for rep in self.replicas.iter_mut() {
            if rep.in_service() {
                rep.state = ReplicaState::Draining; // permit retire() bookkeeping
                let m = rep.retire(last_t);
                rep.retired_at = None; // still in service at end of run
                ttft_hist.merge(&m.ttft_histogram());
                tbt_hist.merge(&m.tbt_histogram());
                fleet.merge(m);
            }
        }
        fleet.timeouts = trace.len() - fleet.records.len();

        let replicas = self
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                id: r.id,
                routed: r.routed as usize,
                completed: r.eng.completed(),
                started_at: r.started_at,
                retired_at: r.retired_at,
            })
            .collect();

        ClusterMetrics {
            fleet,
            replicas,
            scale_events,
            suppressed_scales: scaler.as_ref().map_or(0, |s| s.suppressed),
            replica_seconds,
            peak_replicas,
            events,
            ttft_hist,
            tbt_hist,
            rebalances: 0,
            shard_steps: Vec::new(),
            prefix: self.prefix.as_ref().map_or_else(PrefixStats::default, |p| p.stats),
        }
    }

    /// Apply a scale decision: grow with fresh replicas, or drain the
    /// least-loaded actives (they retire once their admitted work finishes).
    fn rescale(&mut self, target: usize, now: f64, next_id: &mut usize, cfg: &ClusterCfg) {
        let active: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_active())
            .map(|(i, _)| i)
            .collect();
        if target > active.len() {
            for _ in active.len()..target {
                self.replicas.push(Replica::new(*next_id, cfg.kind, &cfg.engine, now));
                *next_id += 1;
                self.trace_replica_start(self.replicas.len() - 1, now);
            }
        } else {
            let mut by_load: Vec<(usize, usize)> =
                active.iter().map(|&i| (self.replicas[i].eng.pending(), i)).collect();
            by_load.sort_unstable();
            for &(_, i) in by_load.iter().take(active.len() - target) {
                self.replicas[i].drain();
                self.tracer.emit_for(self.replicas[i].id as u32, now, EventKind::ReplicaDrain);
            }
        }
    }
}

/// Convenience: build and run a cluster in one call.
pub fn run_cluster(cfg: &ClusterCfg, trace: &[Request]) -> ClusterMetrics {
    Cluster::new(cfg.clone()).run(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_engine;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn ecfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn single_replica_reproduces_run_engine() {
        // The acceptance bar for the stepping refactor: a 1-replica
        // round-robin cluster is the single-engine loop.
        let ecfg = ecfg();
        let trace = generate(Dataset::Mixed, 30, 3.0, 7);
        for kind in [EngineKind::Vllm, EngineKind::Nexus, EngineKind::FastServe] {
            let solo = run_engine(kind, &ecfg, &trace);
            let cc = ClusterCfg::new(kind, ecfg.clone(), 1, RoutingPolicy::RoundRobin);
            let fleet = run_cluster(&cc, &trace);
            let (a, b) = (solo.summary(), fleet.summary());
            assert_eq!(a.completed, b.completed, "{}", kind.name());
            assert!((a.mean_ttft - b.mean_ttft).abs() < 1e-12, "{}", kind.name());
            assert!((a.mean_tbt - b.mean_tbt).abs() < 1e-12, "{}", kind.name());
            assert!((a.p95_norm - b.p95_norm).abs() < 1e-12, "{}", kind.name());
            assert_eq!(solo.recomputes, fleet.fleet.recomputes);
            assert_eq!(solo.timeouts, fleet.fleet.timeouts);
            assert!((solo.makespan - fleet.fleet.makespan).abs() < 1e-9);
        }
    }

    #[test]
    fn fleet_completes_and_conserves_requests() {
        let trace = generate(Dataset::ShareGpt, 60, 8.0, 13);
        for &policy in RoutingPolicy::all() {
            let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(), 3, policy);
            let m = run_cluster(&cc, &trace);
            assert_eq!(
                m.fleet.records.len() + m.fleet.timeouts,
                60,
                "{} lost requests",
                policy.name()
            );
            let routed: usize = m.replicas.iter().map(|r| r.routed).sum();
            assert_eq!(routed, 60, "{} routed != offered", policy.name());
            assert_eq!(m.ttft_hist.count(), m.fleet.records.len() as u64);
            assert!(m.events > 0, "event counter must track loop iterations");
        }
    }

    #[test]
    fn more_replicas_cut_latency_under_load() {
        // Twice the fleet at the same offered rate must improve p95 TTFT.
        let trace = generate(Dataset::ShareGpt, 80, 10.0, 21);
        let one = run_cluster(
            &ClusterCfg::new(EngineKind::Nexus, ecfg(), 1, RoutingPolicy::JoinShortestQueue),
            &trace,
        );
        let four = run_cluster(
            &ClusterCfg::new(EngineKind::Nexus, ecfg(), 4, RoutingPolicy::JoinShortestQueue),
            &trace,
        );
        assert!(four.fleet.records.len() >= one.fleet.records.len());
        assert!(
            four.summary().p95_ttft < one.summary().p95_ttft,
            "4 replicas p95 {} must beat 1 replica {}",
            four.summary().p95_ttft,
            one.summary().p95_ttft
        );
    }

    #[test]
    fn autoscaler_scales_and_respects_bounds() {
        let acfg = AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 4,
            interval: 2.0,
            cooldown: 6.0,
            ..AutoscalerCfg::default()
        };
        let mut cc =
            ClusterCfg::new(EngineKind::Nexus, ecfg(), 1, RoutingPolicy::JoinShortestQueue);
        cc.autoscale = Some(acfg);
        let trace = generate(Dataset::ShareGpt, 120, 12.0, 5);
        let m = run_cluster(&cc, &trace);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 120);
        assert!(m.peak_replicas >= 1 && m.peak_replicas <= 4);
        for e in &m.scale_events {
            assert!(e.to >= 1 && e.to <= 4, "target out of bounds: {e:?}");
        }
        for w in m.scale_events.windows(2) {
            assert!(
                w[1].time - w[0].time >= acfg.cooldown - 1e-9,
                "scale actions inside the hysteresis window: {:?}",
                w
            );
        }
        assert!(m.replica_seconds > 0.0);
    }

    #[test]
    fn drain_loses_no_responses() {
        // Force aggressive downs-scaling and check every request completes.
        let acfg = AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 3,
            interval: 1.0,
            cooldown: 2.0,
            target_util: 0.9,
            ..AutoscalerCfg::default()
        };
        let mut cc = ClusterCfg::new(EngineKind::Vllm, ecfg(), 3, RoutingPolicy::RoundRobin);
        cc.autoscale = Some(acfg);
        // A front-loaded burst followed by a trickle → the fleet shrinks
        // while the burst's decodes are still in flight.
        let mut trace = generate(Dataset::ShareGpt, 40, 20.0, 3);
        let tail = generate(Dataset::ShareGpt, 20, 0.4, 4);
        let t0 = trace.last().unwrap().arrival;
        for (i, mut r) in tail.into_iter().enumerate() {
            r.id = 40 + i;
            r.arrival += t0;
            trace.push(r);
        }
        let m = run_cluster(&cc, &trace);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 60, "responses lost in drain");
    }

    #[test]
    fn heap_stays_bounded_under_autoscale_churn() {
        // Regression: key refreshes leave stale hints behind, and before
        // compaction the heap could grow far past the live-replica count
        // under autoscaler churn. The bound is the compaction trigger
        // (2·live + 16) plus one round of growth before the next check.
        let acfg = AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 6,
            interval: 1.0,
            cooldown: 2.0,
            target_util: 0.9,
            ..AutoscalerCfg::default()
        };
        let mut cc =
            ClusterCfg::new(EngineKind::Nexus, ecfg(), 2, RoutingPolicy::JoinShortestQueue);
        cc.autoscale = Some(acfg);
        let trace = generate(Dataset::ShareGpt, 200, 25.0, 11);
        let mut c = Cluster::new(cc.clone());
        let m = c.run(&trace);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 200);
        let total_replicas = m.replicas.len(); // every replica ever spawned
        assert!(
            c.heap_peak <= 3 * total_replicas + 32,
            "event heap grew unbounded: peak {} with {} replicas ever live",
            c.heap_peak,
            total_replicas
        );
        // Compaction must not change behavior: digest-match the reference.
        let r = Cluster::new(cc).run_reference(&trace);
        assert_eq!(m.fleet.records.len(), r.fleet.records.len());
        let dev = m.fleet.deviation(&r.fleet).expect("structural mismatch vs reference");
        assert!(dev <= 1e-9, "compaction changed the trajectory: deviation {dev}");
    }

    #[test]
    fn event_loop_matches_reference_loop() {
        // The heap loop and the O(R)-scan reference loop must agree on the
        // full metric surface (the exhaustive digest comparison lives in
        // tests/golden_digest.rs).
        let trace = generate(Dataset::Mixed, 50, 6.0, 31);
        for replicas in [1usize, 3] {
            let policy = RoutingPolicy::JoinShortestQueue;
            let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(), replicas, policy);
            let a = Cluster::new(cc.clone()).run(&trace);
            let b = Cluster::new(cc).run_reference(&trace);
            assert_eq!(a.fleet.records.len(), b.fleet.records.len());
            assert_eq!(a.fleet.timeouts, b.fleet.timeouts);
            assert_eq!(a.fleet.recomputes, b.fleet.recomputes);
            let (sa, sb) = (a.summary(), b.summary());
            assert!((sa.mean_ttft - sb.mean_ttft).abs() < 1e-9, "x{replicas} ttft");
            assert!((sa.mean_tbt - sb.mean_tbt).abs() < 1e-9, "x{replicas} tbt");
            assert!((a.replica_seconds - b.replica_seconds).abs() < 1e-6);
            assert_eq!(a.peak_replicas, b.peak_replicas);
        }
    }
}
