//! Cluster serving layer: N engine replicas co-simulated in one
//! virtual-time loop.
//!
//! The intra-GPU work (partitioning, phase scheduling) lives in
//! [`crate::engine`]; this module asks the production questions one layer
//! up, in the spirit of DistServe/DynaServe-style engine-level serving:
//!
//! * a [`Router`] with pluggable policies dispatches every arrival to
//!   exactly one active replica ([`router::RoutingPolicy`]);
//! * an optional [`Autoscaler`] adds replicas or drains them, driven by the
//!   calibrated cost model's capacity prediction plus live per-replica KV
//!   watermarks, under an explicit hysteresis window
//!   ([`autoscaler::AutoscalerCfg`]);
//! * fleet metrics are aggregated by *merging* per-replica run metrics and
//!   latency histograms ([`crate::metrics::RunMetrics::merge`],
//!   [`crate::metrics::Histogram::merge`]).
//!
//! The co-simulation steps every in-service replica to the fleet-wide
//! minimum next event (arrival, any replica's completion/transfer/retry, or
//! an autoscaler tick), so no replica ever overshoots its own events and a
//! single-replica cluster reproduces the single-engine loop exactly.

pub mod autoscaler;
pub mod replica;
pub mod router;

pub use autoscaler::{Autoscaler, AutoscalerCfg, FleetObs};
pub use replica::{Replica, ReplicaState};
pub use router::{ReplicaView, Router, RoutingPolicy};

use crate::costmodel::calibrate;
use crate::engine::common::ArrivalFeed;
use crate::engine::{Engine, EngineCfg, EngineKind};
use crate::metrics::{Histogram, RunMetrics, Summary};
use crate::workload::Request;

/// Fleet configuration.
#[derive(Debug, Clone)]
pub struct ClusterCfg {
    pub kind: EngineKind,
    pub engine: EngineCfg,
    /// Initial replica count (clamped into the autoscaler's bounds when
    /// autoscaling is enabled).
    pub replicas: usize,
    pub policy: RoutingPolicy,
    pub autoscale: Option<AutoscalerCfg>,
}

impl ClusterCfg {
    pub fn new(
        kind: EngineKind,
        engine: EngineCfg,
        replicas: usize,
        policy: RoutingPolicy,
    ) -> Self {
        assert!(replicas >= 1, "a cluster needs at least one replica");
        ClusterCfg { kind, engine, replicas, policy, autoscale: None }
    }
}

/// One applied scale action.
#[derive(Debug, Clone, Copy)]
pub struct ScaleEvent {
    pub time: f64,
    pub from: usize,
    pub to: usize,
}

/// Per-replica accounting surfaced after a run.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStats {
    pub id: usize,
    pub routed: usize,
    pub completed: usize,
    pub started_at: f64,
    /// Retirement time; `None` for replicas alive at the end of the run.
    pub retired_at: Option<f64>,
}

/// Fleet-level result: merged run metrics, merged latency histograms, and
/// the scaling/routing trail.
pub struct ClusterMetrics {
    /// Per-request metrics merged across every replica.
    pub fleet: RunMetrics,
    pub replicas: Vec<ReplicaStats>,
    pub scale_events: Vec<ScaleEvent>,
    /// Hysteresis-suppressed scale proposals.
    pub suppressed_scales: usize,
    /// Integral of in-service replica count over virtual time — the cost
    /// side of the autoscaling trade-off.
    pub replica_seconds: f64,
    pub peak_replicas: usize,
    /// TTFT / TBT distributions, merged from per-replica histograms.
    pub ttft_hist: Histogram,
    pub tbt_hist: Histogram,
}

impl ClusterMetrics {
    pub fn summary(&self) -> Summary {
        self.fleet.summary()
    }

    /// Fraction of *offered* requests (completed + timed out) that finished
    /// within both per-request SLOs.
    pub fn slo_attainment(&self, ttft_slo: f64, norm_slo: f64) -> f64 {
        let total = self.fleet.records.len() + self.fleet.timeouts;
        if total == 0 {
            return 1.0;
        }
        let ok = self
            .fleet
            .records
            .iter()
            .filter(|r| r.ttft() <= ttft_slo && r.normalized_latency() <= norm_slo)
            .count();
        ok as f64 / total as f64
    }
}

fn mean_lengths(trace: &[Request]) -> (f64, f64) {
    if trace.is_empty() {
        return (1.0, 1.0);
    }
    let n = trace.len() as f64;
    let p: usize = trace.iter().map(|r| r.prompt_len).sum();
    let o: usize = trace.iter().map(|r| r.output_len).sum();
    (p as f64 / n, o as f64 / n)
}

/// A replica fleet plus its router; one instance per run.
pub struct Cluster {
    pub cfg: ClusterCfg,
    pub replicas: Vec<Replica>,
    pub router: Router,
}

impl Cluster {
    pub fn new(cfg: ClusterCfg) -> Self {
        let policy = cfg.policy;
        Cluster { cfg, replicas: Vec::new(), router: Router::new(policy) }
    }

    fn active_views(&self) -> Vec<ReplicaView> {
        self.replicas.iter().filter(|r| r.is_active()).map(|r| r.view()).collect()
    }

    fn active_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_active()).count()
    }

    /// Co-simulate the fleet over a time-sorted trace.
    pub fn run(&mut self, trace: &[Request]) -> ClusterMetrics {
        let cfg = self.cfg.clone();
        let n0 = match &cfg.autoscale {
            Some(a) => cfg.replicas.clamp(a.min_replicas, a.max_replicas),
            None => cfg.replicas,
        };
        self.replicas = (0..n0).map(|i| Replica::new(i, cfg.kind, &cfg.engine, 0.0)).collect();
        self.router = Router::new(cfg.policy);
        let mut scaler = cfg.autoscale.map(|acfg| {
            let cost = calibrate(&cfg.engine.gpu);
            let (mp, mo) = mean_lengths(trace);
            Autoscaler::new(acfg, autoscaler::predict_replica_rate(&cost, &cfg.engine, mp, mo))
        });
        let mut next_tick = scaler.as_ref().map(|s| s.cfg.interval);

        let mut feed = ArrivalFeed::new(trace);
        let mut fleet = RunMetrics::default();
        let mut ttft_hist = Histogram::new();
        let mut tbt_hist = Histogram::new();
        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut replica_seconds = 0.0f64;
        let mut peak_replicas = n0;
        let mut last_t = 0.0f64;
        let mut arrivals_since_tick = 0usize;
        let mut next_id = n0;

        loop {
            let pending: usize = self.replicas.iter().map(|r| r.eng.pending()).sum();
            if feed.exhausted() && pending == 0 {
                break;
            }

            // Fleet-wide next event: earliest arrival, any in-service
            // replica's internal event, or the next autoscaler tick.
            let mut t = f64::INFINITY;
            if let Some(a) = feed.peek_time() {
                t = t.min(a);
            }
            for rep in self.replicas.iter_mut().filter(|r| r.in_service()) {
                if let Some(e) = rep.eng.next_event() {
                    t = t.min(e);
                }
            }
            if let Some(tick) = next_tick {
                t = t.min(tick);
            }
            if !t.is_finite() {
                t = self.replicas.iter().map(|r| r.eng.now()).fold(last_t, f64::max);
            }
            if t > cfg.engine.max_virtual_time {
                break;
            }

            // Replica-seconds accrue for every in-service replica.
            let in_service = self.replicas.iter().filter(|r| r.in_service()).count();
            replica_seconds += in_service as f64 * (t - last_t).max(0.0);
            last_t = t;

            // Route arrivals due at t. Views are rebuilt per arrival so
            // load-aware policies see same-instant dispatches.
            for r in feed.pop_until(t) {
                let views = self.active_views();
                let target = self.router.route(&views, r);
                // Replicas are never removed from the vec (only retired in
                // place), so fleet position == replica id.
                let rep = &mut self.replicas[target];
                debug_assert_eq!(rep.id, target);
                rep.eng.inject(*r);
                rep.routed += 1;
                arrivals_since_tick += 1;
            }

            // Step every in-service replica to the global event time (never
            // past any replica's own next event, by construction of t).
            let mut any_busy = false;
            for rep in self.replicas.iter_mut().filter(|r| r.in_service()) {
                let out = rep.eng.step(t);
                any_busy |= out.busy;
            }

            // Autoscaler tick: observe the post-step fleet, maybe act.
            if let (Some(s), Some(tick)) = (scaler.as_mut(), next_tick) {
                if t + 1e-12 >= tick {
                    let views = self.active_views();
                    let kvs: Vec<f64> = views.iter().map(|v| v.kv_usage).collect();
                    let obs = FleetObs {
                        now: t,
                        arrival_rate: arrivals_since_tick as f64 / s.cfg.interval,
                        active_replicas: views.len(),
                        total_pending: self.replicas.iter().map(|r| r.eng.pending()).sum(),
                        mean_kv: crate::util::mean(&kvs),
                        max_kv: kvs.iter().fold(0.0f64, |a, &b| a.max(b)),
                    };
                    if let Some(target) = s.decide(&obs) {
                        let from = views.len();
                        self.rescale(target, t, &mut next_id, &cfg);
                        scale_events.push(ScaleEvent { time: t, from, to: target });
                    }
                    next_tick = Some(tick + s.cfg.interval);
                    arrivals_since_tick = 0;
                }
            }

            // Retire drained replicas, merging their metrics into the pool.
            for rep in self.replicas.iter_mut() {
                if rep.drained() {
                    let m = rep.retire(t);
                    ttft_hist.merge(&m.ttft_histogram());
                    tbt_hist.merge(&m.tbt_histogram());
                    fleet.merge(m);
                }
            }

            peak_replicas = peak_replicas.max(self.active_count());

            let pending_after: usize = self.replicas.iter().map(|r| r.eng.pending()).sum();
            if !any_busy && feed.exhausted() && pending_after > 0 {
                // Nothing schedulable fleet-wide and nothing will arrive.
                break;
            }
        }

        // Collect the survivors.
        for rep in self.replicas.iter_mut() {
            if rep.in_service() {
                rep.state = ReplicaState::Draining; // permit retire() bookkeeping
                let m = rep.retire(last_t);
                rep.retired_at = None; // still in service at end of run
                ttft_hist.merge(&m.ttft_histogram());
                tbt_hist.merge(&m.tbt_histogram());
                fleet.merge(m);
            }
        }
        fleet.timeouts = trace.len() - fleet.records.len();

        let replicas = self
            .replicas
            .iter()
            .map(|r| ReplicaStats {
                id: r.id,
                routed: r.routed,
                completed: r.eng.completed(),
                started_at: r.started_at,
                retired_at: r.retired_at,
            })
            .collect();

        ClusterMetrics {
            fleet,
            replicas,
            scale_events,
            suppressed_scales: scaler.as_ref().map_or(0, |s| s.suppressed),
            replica_seconds,
            peak_replicas,
            ttft_hist,
            tbt_hist,
        }
    }

    /// Apply a scale decision: grow with fresh replicas, or drain the
    /// least-loaded actives (they retire once their admitted work finishes).
    fn rescale(&mut self, target: usize, now: f64, next_id: &mut usize, cfg: &ClusterCfg) {
        let active: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_active())
            .map(|(i, _)| i)
            .collect();
        if target > active.len() {
            for _ in active.len()..target {
                self.replicas.push(Replica::new(*next_id, cfg.kind, &cfg.engine, now));
                *next_id += 1;
            }
        } else {
            let mut by_load: Vec<(usize, usize)> =
                active.iter().map(|&i| (self.replicas[i].eng.pending(), i)).collect();
            by_load.sort_unstable();
            for &(_, i) in by_load.iter().take(active.len() - target) {
                self.replicas[i].drain();
            }
        }
    }
}

/// Convenience: build and run a cluster in one call.
pub fn run_cluster(cfg: &ClusterCfg, trace: &[Request]) -> ClusterMetrics {
    Cluster::new(cfg.clone()).run(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_engine;
    use crate::model::ModelConfig;
    use crate::workload::{generate, Dataset};

    fn ecfg() -> EngineCfg {
        EngineCfg::new(ModelConfig::qwen3b(), 42)
    }

    #[test]
    fn single_replica_reproduces_run_engine() {
        // The acceptance bar for the stepping refactor: a 1-replica
        // round-robin cluster is the single-engine loop.
        let ecfg = ecfg();
        let trace = generate(Dataset::Mixed, 30, 3.0, 7);
        for kind in [EngineKind::Vllm, EngineKind::Nexus, EngineKind::FastServe] {
            let solo = run_engine(kind, &ecfg, &trace);
            let cc = ClusterCfg::new(kind, ecfg.clone(), 1, RoutingPolicy::RoundRobin);
            let fleet = run_cluster(&cc, &trace);
            let (a, b) = (solo.summary(), fleet.summary());
            assert_eq!(a.completed, b.completed, "{}", kind.name());
            assert!((a.mean_ttft - b.mean_ttft).abs() < 1e-12, "{}", kind.name());
            assert!((a.mean_tbt - b.mean_tbt).abs() < 1e-12, "{}", kind.name());
            assert!((a.p95_norm - b.p95_norm).abs() < 1e-12, "{}", kind.name());
            assert_eq!(solo.recomputes, fleet.fleet.recomputes);
            assert_eq!(solo.timeouts, fleet.fleet.timeouts);
            assert!((solo.makespan - fleet.fleet.makespan).abs() < 1e-9);
        }
    }

    #[test]
    fn fleet_completes_and_conserves_requests() {
        let trace = generate(Dataset::ShareGpt, 60, 8.0, 13);
        for &policy in RoutingPolicy::all() {
            let cc = ClusterCfg::new(EngineKind::Nexus, ecfg(), 3, policy);
            let m = run_cluster(&cc, &trace);
            assert_eq!(
                m.fleet.records.len() + m.fleet.timeouts,
                60,
                "{} lost requests",
                policy.name()
            );
            let routed: usize = m.replicas.iter().map(|r| r.routed).sum();
            assert_eq!(routed, 60, "{} routed != offered", policy.name());
            assert_eq!(m.ttft_hist.count(), m.fleet.records.len() as u64);
        }
    }

    #[test]
    fn more_replicas_cut_latency_under_load() {
        // Twice the fleet at the same offered rate must improve p95 TTFT.
        let trace = generate(Dataset::ShareGpt, 80, 10.0, 21);
        let one = run_cluster(
            &ClusterCfg::new(EngineKind::Nexus, ecfg(), 1, RoutingPolicy::JoinShortestQueue),
            &trace,
        );
        let four = run_cluster(
            &ClusterCfg::new(EngineKind::Nexus, ecfg(), 4, RoutingPolicy::JoinShortestQueue),
            &trace,
        );
        assert!(four.fleet.records.len() >= one.fleet.records.len());
        assert!(
            four.summary().p95_ttft < one.summary().p95_ttft,
            "4 replicas p95 {} must beat 1 replica {}",
            four.summary().p95_ttft,
            one.summary().p95_ttft
        );
    }

    #[test]
    fn autoscaler_scales_and_respects_bounds() {
        let acfg = AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 4,
            interval: 2.0,
            cooldown: 6.0,
            ..AutoscalerCfg::default()
        };
        let mut cc =
            ClusterCfg::new(EngineKind::Nexus, ecfg(), 1, RoutingPolicy::JoinShortestQueue);
        cc.autoscale = Some(acfg);
        let trace = generate(Dataset::ShareGpt, 120, 12.0, 5);
        let m = run_cluster(&cc, &trace);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 120);
        assert!(m.peak_replicas >= 1 && m.peak_replicas <= 4);
        for e in &m.scale_events {
            assert!(e.to >= 1 && e.to <= 4, "target out of bounds: {e:?}");
        }
        for w in m.scale_events.windows(2) {
            assert!(
                w[1].time - w[0].time >= acfg.cooldown - 1e-9,
                "scale actions inside the hysteresis window: {:?}",
                w
            );
        }
        assert!(m.replica_seconds > 0.0);
    }

    #[test]
    fn drain_loses_no_responses() {
        // Force aggressive downs-scaling and check every request completes.
        let acfg = AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 3,
            interval: 1.0,
            cooldown: 2.0,
            target_util: 0.9,
            ..AutoscalerCfg::default()
        };
        let mut cc = ClusterCfg::new(EngineKind::Vllm, ecfg(), 3, RoutingPolicy::RoundRobin);
        cc.autoscale = Some(acfg);
        // A front-loaded burst followed by a trickle → the fleet shrinks
        // while the burst's decodes are still in flight.
        let mut trace = generate(Dataset::ShareGpt, 40, 20.0, 3);
        let tail = generate(Dataset::ShareGpt, 20, 0.4, 4);
        let t0 = trace.last().unwrap().arrival;
        for (i, mut r) in tail.into_iter().enumerate() {
            r.id = 40 + i;
            r.arrival += t0;
            trace.push(r);
        }
        let m = run_cluster(&cc, &trace);
        assert_eq!(m.fleet.records.len() + m.fleet.timeouts, 60, "responses lost in drain");
    }
}
