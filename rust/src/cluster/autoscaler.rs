//! Cost-model-driven fleet autoscaling with hysteresis.
//!
//! The fleet analogue of [`crate::partition::PartitionController`]: where
//! the partition controller moves SMs between phases inside one GPU, the
//! autoscaler moves whole replicas in and out of the fleet. Both are
//! proactive (decisions come from the calibrated analytical cost model, not
//! from reacting to SLO violations after the fact) and both damp
//! oscillation with an explicit hysteresis mechanism — δ-suppression there,
//! a cooldown window here.
//!
//! The capacity estimate asks the Eq. 5–9 cost model what one replica can
//! sustain under a 50/50 SM split: the per-request prefill time (chunked,
//! causal attention) and per-token decode time bound the replica's service
//! rate by its slower pipeline stage. Demand over predicted capacity,
//! corrected by live KV watermarks (the same `KV_u` signal Nexus's mode
//! switch uses), yields the target replica count.

use crate::costmodel::CostModel;
use crate::engine::common::chunk_attn_pairs;
use crate::engine::EngineCfg;

/// What the autoscaler optimizes for when sizing the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScaleObjective {
    /// Track demand at `target_util` of predicted per-replica capacity
    /// (the original behavior).
    #[default]
    Utilization,
    /// DistServe-style goodput per cost: pay for a marginal replica only
    /// once demand actually claims a `goodput_margin` fraction of it.
    /// With demand d (in replica-rate units) and margin m, the target is
    /// the smallest n with `d < n + m` — i.e. replica n+1 is added only
    /// when the fleet would otherwise run its last replica past m of its
    /// full (not utilization-derated) predicted rate. Maximizes
    /// goodput-per-replica-second instead of tracking a utilization
    /// set-point; compare via [`ClusterMetrics::goodput_per_cost`].
    ///
    /// [`ClusterMetrics::goodput_per_cost`]: crate::cluster::ClusterMetrics::goodput_per_cost
    GoodputPerCost,
}

/// Autoscaler parameters.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalerCfg {
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Evaluation interval (virtual seconds between ticks).
    pub interval: f64,
    /// Hysteresis window: minimum virtual time between *applied* scale
    /// actions. Proposals inside the window are suppressed, not queued.
    pub cooldown: f64,
    /// Target utilization of predicted per-replica capacity (< 1 leaves
    /// headroom for bursts).
    pub target_util: f64,
    /// Fleet-max KV usage above which a replica is added regardless of the
    /// demand estimate (memory-pressure relief, cf. `KV_switch`).
    pub kv_high: f64,
    /// Fleet-mean KV usage below which scale-down becomes permissible.
    pub kv_low: f64,
    /// Scale-down is vetoed while any replica holds more than this many
    /// unfinished requests (drain would just migrate the backlog).
    pub backlog_per_replica: usize,
    /// EWMA weight on the newest arrival-rate sample.
    pub ewma: f64,
    /// What the sizing formula optimizes (see [`ScaleObjective`]).
    pub objective: ScaleObjective,
    /// [`ScaleObjective::GoodputPerCost`] only: fraction of the marginal
    /// replica's full predicted rate that demand must claim before the
    /// replica is worth paying for. Ignored under `Utilization`.
    pub goodput_margin: f64,
}

impl Default for AutoscalerCfg {
    fn default() -> Self {
        AutoscalerCfg {
            min_replicas: 1,
            max_replicas: 8,
            interval: 5.0,
            cooldown: 20.0,
            target_util: 0.75,
            kv_high: 0.85,
            kv_low: 0.45,
            backlog_per_replica: 8,
            ewma: 0.5,
            objective: ScaleObjective::Utilization,
            goodput_margin: 0.5,
        }
    }
}

/// Fleet state snapshot handed to the autoscaler at each tick.
#[derive(Debug, Clone, Copy)]
pub struct FleetObs {
    pub now: f64,
    /// Arrivals per second observed since the previous tick.
    pub arrival_rate: f64,
    /// Replicas currently accepting traffic.
    pub active_replicas: usize,
    /// Admitted-but-unfinished requests across the fleet.
    pub total_pending: usize,
    /// Mean / max live KV usage across in-service replicas.
    pub mean_kv: f64,
    pub max_kv: f64,
}

/// Predict the request rate (req/s) one replica sustains for requests of
/// the given mean shape, from the calibrated cost model at a 50/50 split.
pub fn predict_replica_rate(
    cost: &CostModel,
    ecfg: &EngineCfg,
    mean_prompt: f64,
    mean_output: f64,
) -> f64 {
    // Prefill: the whole prompt in chunk-sized pieces (Eq. 5 per chunk).
    let prompt = mean_prompt.round().max(1.0) as usize;
    let mut prefill_t = 0.0;
    let mut done = 0usize;
    while done < prompt {
        let take = ecfg.chunk_size.min(prompt - done);
        let finishing = usize::from(done + take >= prompt);
        let ops = ecfg.model.prefill_ops(
            take,
            chunk_attn_pairs(done, take),
            (done + take) as f64,
            finishing,
        );
        prefill_t += cost.prefill(&ops, 0.5).total;
        done += take;
    }
    // Decode: per-token latency amortized over a reference batch (Eq. 6).
    let batch = 16usize;
    let ctx = batch as f64 * (mean_prompt + 0.5 * mean_output);
    let per_iter = cost.decode(&ecfg.model.decode_ops(batch, ctx), 0.5, None);
    let decode_t = mean_output.max(1.0) * per_iter / batch as f64;
    // Phases run concurrently on disjoint SM partitions: a replica's
    // steady-state rate is bounded by its slower pipeline stage.
    1.0 / prefill_t.max(decode_t).max(1e-9)
}

/// Proactive replica-count controller.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscalerCfg,
    /// Cost-model-predicted sustainable rate of one replica (req/s).
    pub replica_rate: f64,
    rate_ewma: f64,
    ticks: usize,
    last_action: f64,
    /// Applied / hysteresis-suppressed scale proposals (Fig.-8-style
    /// stability accounting at fleet granularity).
    pub applied: usize,
    pub suppressed: usize,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerCfg, replica_rate: f64) -> Self {
        assert!(cfg.min_replicas >= 1 && cfg.max_replicas >= cfg.min_replicas);
        Autoscaler {
            cfg,
            replica_rate,
            rate_ewma: 0.0,
            ticks: 0,
            last_action: f64::NEG_INFINITY,
            applied: 0,
            suppressed: 0,
        }
    }

    /// One tick: returns `Some(target)` when a scale action should be
    /// applied now, `None` when the fleet is already sized or the proposal
    /// fell inside the hysteresis window.
    pub fn decide(&mut self, obs: &FleetObs) -> Option<usize> {
        self.rate_ewma = if self.ticks == 0 {
            obs.arrival_rate
        } else {
            self.cfg.ewma * obs.arrival_rate + (1.0 - self.cfg.ewma) * self.rate_ewma
        };
        self.ticks += 1;

        let demand = match self.cfg.objective {
            ScaleObjective::Utilization => {
                let capacity = (self.cfg.target_util * self.replica_rate).max(1e-9);
                (self.rate_ewma / capacity).ceil() as usize
            }
            ScaleObjective::GoodputPerCost => {
                // Smallest n with d < n + m (see `ScaleObjective`): the
                // marginal replica must earn its cost in claimed capacity.
                let d = self.rate_ewma / self.replica_rate.max(1e-9);
                ((d - self.cfg.goodput_margin).floor() as i64 + 1).max(1) as usize
            }
        };
        let mut target = demand.clamp(self.cfg.min_replicas, self.cfg.max_replicas);

        // KV-pressure relief: grow even when the demand estimate disagrees.
        if obs.max_kv > self.cfg.kv_high {
            target = target.max((obs.active_replicas + 1).min(self.cfg.max_replicas));
        }
        // Scale-down veto: never shed capacity while memory or queues are
        // still loaded — the work would just pile onto the survivors.
        if target < obs.active_replicas
            && (obs.mean_kv > self.cfg.kv_low
                || obs.total_pending
                    > self.cfg.backlog_per_replica * obs.active_replicas)
        {
            target = obs.active_replicas;
        }

        if target == obs.active_replicas {
            return None; // sized correctly: not an action, no hysteresis charge
        }
        if obs.now - self.last_action < self.cfg.cooldown {
            self.suppressed += 1;
            return None;
        }
        self.last_action = obs.now;
        self.applied += 1;
        Some(target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::calibrate;
    use crate::gpusim::GpuSpec;
    use crate::model::ModelConfig;

    fn scaler(cfg: AutoscalerCfg) -> Autoscaler {
        Autoscaler::new(cfg, 4.0) // 4 req/s per replica
    }

    fn obs(now: f64, rate: f64, active: usize) -> FleetObs {
        FleetObs {
            now,
            arrival_rate: rate,
            active_replicas: active,
            total_pending: 0,
            mean_kv: 0.1,
            max_kv: 0.2,
        }
    }

    #[test]
    fn capacity_prediction_is_positive_and_length_sensitive() {
        let cost = calibrate(&GpuSpec::l20());
        let ecfg = EngineCfg::new(ModelConfig::qwen3b(), 1);
        let short = predict_replica_rate(&cost, &ecfg, 400.0, 100.0);
        let long = predict_replica_rate(&cost, &ecfg, 6000.0, 200.0);
        assert!(short.is_finite() && short > 0.0);
        assert!(long > 0.0 && long < short, "long prompts must lower capacity");
    }

    #[test]
    fn scales_up_under_demand() {
        let mut a = scaler(AutoscalerCfg::default());
        // 10 req/s against 0.75 × 4 = 3 req/s per replica → 4 replicas.
        assert_eq!(a.decide(&obs(100.0, 10.0, 1)), Some(4));
        assert_eq!(a.applied, 1);
    }

    #[test]
    fn cooldown_suppresses_flapping() {
        let mut a = scaler(AutoscalerCfg { cooldown: 30.0, ..AutoscalerCfg::default() });
        assert_eq!(a.decide(&obs(10.0, 10.0, 1)), Some(4));
        // Rate collapses immediately; the down-scale sits in the window.
        assert_eq!(a.decide(&obs(15.0, 0.0, 4)), None);
        assert!(a.suppressed >= 1);
        // Past the window (and past the EWMA memory), shedding is allowed.
        for i in 0..10 {
            a.decide(&obs(20.0 + i as f64, 0.0, 4));
        }
        let d = a.decide(&obs(45.0, 0.0, 4));
        assert_eq!(d, Some(1), "cold fleet must shrink to min after cooldown");
    }

    #[test]
    fn bounds_are_respected() {
        let cfg = AutoscalerCfg { min_replicas: 2, max_replicas: 3, ..AutoscalerCfg::default() };
        let mut a = scaler(cfg);
        assert_eq!(a.decide(&obs(0.0, 1000.0, 2)), Some(3), "clamped to max");
        let mut b = scaler(cfg);
        let d = b.decide(&obs(0.0, 0.0, 3));
        assert_eq!(d, Some(2), "clamped to min");
    }

    #[test]
    fn kv_pressure_forces_growth() {
        let mut a = scaler(AutoscalerCfg::default());
        let o = FleetObs {
            now: 50.0,
            arrival_rate: 0.5, // demand alone says 1 replica
            active_replicas: 2,
            total_pending: 0,
            mean_kv: 0.9,
            max_kv: 0.95,
        };
        assert_eq!(a.decide(&o), Some(3), "watermark breach must add a replica");
    }

    #[test]
    fn goodput_objective_sizes_leaner_than_utilization() {
        let gcfg = AutoscalerCfg {
            objective: ScaleObjective::GoodputPerCost,
            goodput_margin: 0.5,
            ..AutoscalerCfg::default()
        };
        // 10 req/s at 4 req/s per replica → d = 2.5. Utilization mode asks
        // for ceil(2.5 / 0.75) = 4; goodput-per-cost pays for the third
        // replica only because d = 2.5 ≥ 2 + 0.5 — exactly at the margin.
        let mut u = scaler(AutoscalerCfg::default());
        assert_eq!(u.decide(&obs(100.0, 10.0, 1)), Some(4));
        let mut g = scaler(gcfg);
        assert_eq!(g.decide(&obs(100.0, 10.0, 1)), Some(3), "margin-priced sizing");
        // Just below the margin (d = 2.25 < 2.5): the marginal replica is
        // not worth its cost, so the fleet stays at two.
        let mut h = scaler(gcfg);
        assert_eq!(h.decide(&obs(100.0, 9.0, 1)), Some(2));
    }

    #[test]
    fn goodput_objective_keeps_min_fleet_when_idle() {
        let gcfg = AutoscalerCfg {
            objective: ScaleObjective::GoodputPerCost,
            min_replicas: 1,
            ..AutoscalerCfg::default()
        };
        let mut g = scaler(gcfg);
        // Zero demand: d − m is negative, target still clamps to one.
        assert_eq!(g.decide(&obs(0.0, 0.0, 2)), Some(1));
    }

    #[test]
    fn goodput_objective_respects_kv_relief_and_veto() {
        let gcfg = AutoscalerCfg {
            objective: ScaleObjective::GoodputPerCost,
            ..AutoscalerCfg::default()
        };
        // KV pressure overrides the lean sizing, exactly as in
        // utilization mode.
        let mut g = scaler(gcfg);
        let hot = FleetObs {
            now: 50.0,
            arrival_rate: 0.5,
            active_replicas: 2,
            total_pending: 0,
            mean_kv: 0.9,
            max_kv: 0.95,
        };
        assert_eq!(g.decide(&hot), Some(3));
        // Backlog vetoes shrink under either objective.
        let mut h = scaler(gcfg);
        let loaded = FleetObs {
            now: 50.0,
            arrival_rate: 0.0,
            active_replicas: 4,
            total_pending: 100,
            mean_kv: 0.1,
            max_kv: 0.2,
        };
        assert_eq!(h.decide(&loaded), None);
    }

    #[test]
    fn backlog_vetoes_scale_down() {
        let mut a = scaler(AutoscalerCfg::default());
        let o = FleetObs {
            now: 50.0,
            arrival_rate: 0.0,
            active_replicas: 4,
            total_pending: 100,
            mean_kv: 0.1,
            max_kv: 0.2,
        };
        assert_eq!(a.decide(&o), None, "backlogged fleet must not shrink");
    }
}
