//! One engine replica inside the fleet: an [`Engine`] instance plus the
//! lifecycle and accounting the cluster loop needs around it.

use super::router::ReplicaView;
use crate::engine::{build_engine, Engine, EngineCfg, EngineKind};
use crate::metrics::RunMetrics;

/// Replica lifecycle. Draining replicas finish their admitted requests but
/// receive no new traffic; retired replicas have handed their metrics over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    Active,
    Draining,
    Retired,
}

/// An engine instance plus fleet bookkeeping.
pub struct Replica {
    pub id: usize,
    pub eng: Box<dyn Engine>,
    pub state: ReplicaState,
    /// Requests the router dispatched here (`u32`: ≪ 2³² per replica).
    pub routed: u32,
    /// Virtual time the replica joined the fleet.
    pub started_at: f64,
    /// Virtual time it fully drained (retired), if it has.
    pub retired_at: Option<f64>,
    /// Engine steps taken in the current parallel-loop round; the shard
    /// scheduler's load signal (see [`crate::cluster::parallel`]). Lives on
    /// the replica so the counter migrates with it — purely observational,
    /// never read by the engine. Unused (zero) in the sequential loop.
    pub round_steps: u32,
    /// How many completion records the cluster loop has already observed
    /// via [`Engine::records`]. The WFQ gate diffs `records()[records_seen..]`
    /// after each step to learn which tenants released in-flight slots.
    /// Zero cost when multi-tenancy is off (the cursor is simply never
    /// advanced). Reset on retire: `take_metrics` drains the record vec.
    pub records_seen: usize,
}

impl Replica {
    pub fn new(id: usize, kind: EngineKind, cfg: &EngineCfg, now: f64) -> Self {
        Replica {
            id,
            eng: build_engine(kind, cfg),
            state: ReplicaState::Active,
            routed: 0,
            started_at: now,
            retired_at: None,
            round_steps: 0,
            records_seen: 0,
        }
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        self.state == ReplicaState::Active
    }

    /// Consuming capacity (and replica-seconds): active or draining.
    /// Retired replicas' stale event-queue entries are lazily dropped by
    /// the cluster loop (see the invariants in [`crate::cluster`]).
    #[inline]
    pub fn in_service(&self) -> bool {
        self.state != ReplicaState::Retired
    }

    /// Routing snapshot (callers filter to active replicas). Called once
    /// per active replica per arrival on the routing hot path — both
    /// accessors are O(1) counter/ratio reads, no engine scan.
    #[inline]
    pub fn view(&self) -> ReplicaView {
        ReplicaView {
            index: self.id as u32,
            pending: self.eng.pending() as u32,
            kv_usage: self.eng.kv_usage(),
        }
    }

    /// Stop accepting traffic; the cluster retires the replica once its
    /// admitted requests finish.
    pub fn drain(&mut self) {
        if self.state == ReplicaState::Active {
            self.state = ReplicaState::Draining;
        }
    }

    /// True when a draining replica has finished every admitted request.
    pub fn drained(&self) -> bool {
        self.state == ReplicaState::Draining && self.eng.pending() == 0
    }

    /// Retire the replica, handing over its run metrics.
    pub fn retire(&mut self, now: f64) -> RunMetrics {
        debug_assert!(self.state != ReplicaState::Retired, "double retire");
        self.state = ReplicaState::Retired;
        self.retired_at = Some(now);
        self.records_seen = 0;
        self.eng.take_metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::workload::Request;

    #[test]
    fn lifecycle_and_view() {
        let cfg = EngineCfg::new(ModelConfig::qwen3b(), 1);
        let mut rep = Replica::new(3, EngineKind::Vllm, &cfg, 0.0);
        assert!(rep.is_active() && rep.in_service());
        assert_eq!(rep.view().index, 3);
        assert_eq!(rep.view().pending, 0);
        rep.eng.inject(Request { id: 0, arrival: 0.0, prompt_len: 64, output_len: 2, tenant: 0, prefix: 0, shared_len: 0 });
        assert_eq!(rep.view().pending, 1);
        rep.drain();
        assert!(!rep.is_active() && rep.in_service());
        assert!(!rep.drained(), "pending work blocks retirement");
        // Drive the request to completion, then retire.
        let mut t = 0.0;
        let mut guard = 0;
        loop {
            rep.eng.step(t);
            if rep.eng.pending() == 0 {
                break;
            }
            t = rep.eng.next_event().expect("work in flight");
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(rep.drained());
        let m = rep.retire(t);
        assert_eq!(m.records.len(), 1);
        assert_eq!(rep.state, ReplicaState::Retired);
        assert_eq!(rep.retired_at, Some(t));
    }
}
