//! `nexus` — CLI entrypoint for the serving system and its experiments.
//!
//! Subcommands:
//!
//! ```text
//! nexus compare    --dataset mixed --model llama8b --n 200 --rate 3.0
//! nexus serve      --engine nexus --dataset ldc --model qwen3b --n 100 --rate 2.5
//! nexus cluster    --engine nexus --replicas 4 --policy jsq [--bursty] [--autoscale]
//!                  [--threads N] [--window S] [--steal-threshold R] [--balance-interval S]
//!                  (sharded loop; same results for any N/S/R)
//!                  [--tenants N | --tenant-weights a,b,..] [--wfq] [--tenant-quota Q]
//!                  [--wfq-capacity C] [--ttft-slo S] [--tbt-slo S]
//!                  [--objective goodput|utilization] [--goodput-margin M]
//!                  (multi-tenant WFQ front + per-tenant SLO/goodput report)
//!                  [--policy prefix] [--prefix-capacity TOKENS]
//!                  [--tier nvlink|rdma|tcp|none] [--tier-bw B/s] [--tier-lat S]
//!                  (fleet prefix-cache tier + prefix-aware routing)
//! nexus throughput --engine vllm --dataset arxiv --model qwen3b --n 150
//! nexus offline    --dataset ldc --model qwen3b --n 100
//! nexus calibrate  [--model qwen3b]
//! nexus trace      --engine nexus --replicas 16 --bursty --out trace.json
//! nexus live       [--artifacts DIR] [--requests 16] [--rate 4.0]   (pjrt feature)
//! ```
//!
//! `serve` and `cluster` also accept `--trace-out FILE` (Chrome/Perfetto
//! trace) and `--trace-events FILE` (JSONL event log); `trace` is the
//! dedicated export subcommand (fleet run, Chrome trace to `--out`).
//!
//! `live` is the real-compute path: it loads the AOT artifacts (tiny model)
//! through PJRT and serves actual token traffic; everything else runs on
//! the calibrated L20 substrate.

use nexus::cluster::{
    AutoscalerCfg, PrefixCacheCfg, RoutingPolicy, ScaleObjective, StealCfg, TierCfg, WfqCfg,
};
use nexus::coordinator::{
    offline_makespan, sustainable_throughput, ClusterExperiment, Experiment, SloSpec,
};
use nexus::costmodel::calibrate;
use nexus::engine::{run_engine_traced, EngineKind};
use nexus::gpusim::GpuSpec;
use nexus::metrics::{RunMetrics, Summary};
use nexus::model::{ModelConfig, OpClass};
use nexus::trace::{attribute, chrome_trace, to_jsonl, Tracer};
use nexus::util::cli::Args;
use nexus::util::fmt::{dur, Table};
use nexus::workload::{self, BurstyCfg, Dataset, TenantMix, TenantSpec};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args),
        "compare" => cmd_compare(&args),
        "cluster" => cmd_cluster(&args),
        "throughput" => cmd_throughput(&args),
        "offline" => cmd_offline(&args),
        "calibrate" => cmd_calibrate(&args),
        "trace" => cmd_trace(&args),
        "live" => cmd_live(&args),
        _ => {
            print!("{}", include_str!("usage.txt"));
            println!("routing policies (cluster --policy):");
            for p in RoutingPolicy::all() {
                println!("  {:<12} {}", p.name(), p.describe());
            }
        }
    }
}

fn experiment(args: &Args) -> Experiment {
    let model = ModelConfig::by_name(&args.get_or("model", "qwen3b"))
        .unwrap_or_else(|| panic!("unknown --model (qwen3b|llama8b|qwen14b|tiny)"));
    let dataset = Dataset::by_name(&args.get_or("dataset", "sharegpt"))
        .unwrap_or_else(|| panic!("unknown --dataset (ldc|arxiv|sharegpt|mixed)"));
    let mut exp = Experiment::new(
        model,
        dataset,
        args.get_usize("n", 100),
        args.get_f64("rate", 2.5),
    );
    exp.seed = args.get_u64("seed", 42);
    exp
}

fn summary_row(name: &str, s: &Summary) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{}", s.completed),
        dur(s.mean_ttft),
        dur(s.p95_ttft),
        dur(s.mean_tbt),
        dur(s.p95_tbt),
        dur(s.mean_norm),
        dur(s.p95_norm),
        format!("{:.2}", s.throughput_rps),
    ]
}

const HDR: [&str; 9] =
    ["engine", "done", "TTFT", "TTFT95", "TBT", "TBT95", "norm", "norm95", "req/s"];

/// Recording tracer when `--trace-out` / `--trace-events` is given
/// (sampling every `--sample-interval` virtual seconds, default 1.0);
/// otherwise the zero-cost disabled tracer.
fn tracer_from(args: &Args) -> Tracer {
    if args.get("trace-out").is_some() || args.get("trace-events").is_some() {
        Tracer::recording().with_sampling(args.get_f64("sample-interval", 1.0))
    } else {
        Tracer::default()
    }
}

/// Drain a recording tracer: print the per-phase latency attribution and
/// write the Chrome/Perfetto trace and/or JSONL event log.
fn export_trace(args: &Args, tracer: &Tracer, metrics: &RunMetrics) {
    if !tracer.enabled() {
        return;
    }
    let events = tracer.take();
    println!("{}", attribute(&events, metrics));
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, chrome_trace(&events).to_string()).expect("writing trace");
        eprintln!(
            "wrote {} events to {path} — open it at https://ui.perfetto.dev or chrome://tracing",
            events.len()
        );
    }
    if let Some(path) = args.get("trace-events") {
        std::fs::write(path, to_jsonl(&events)).expect("writing event log");
        eprintln!("wrote {} events to {path} (JSONL)", events.len());
    }
}

fn cmd_serve(args: &Args) {
    let exp = experiment(args);
    let kind = EngineKind::by_name(&args.get_or("engine", "nexus"))
        .unwrap_or_else(|| panic!("unknown --engine"));
    eprintln!(
        "running {} on {} / {} ({} reqs @ {} req/s)...",
        kind.name(),
        exp.model.name,
        exp.dataset.name(),
        exp.n_requests,
        exp.rate
    );
    let tracer = tracer_from(args);
    let m = run_engine_traced(kind, &exp.cfg(), &exp.trace(), &tracer);
    let s = m.summary();
    let mut t = Table::new("serving summary", &HDR);
    t.row(&summary_row(kind.name(), &s));
    t.print();
    println!(
        "repartitions: {} applied, {} suppressed | swaps {} | recomputes {} | timeouts {}",
        m.repartitions, m.suppressed_repartitions, m.swaps, m.recomputes, m.timeouts
    );
    let b = m.breakdown();
    println!(
        "per-token breakdown: sched {} | queue {} | exec {}",
        dur(b.sched),
        dur(b.queue),
        dur(b.exec)
    );
    export_trace(args, &tracer, &m);
}

fn cmd_compare(args: &Args) {
    let exp = experiment(args);
    let mut t = Table::new(
        &format!(
            "{} / {} — {} reqs @ {} req/s",
            exp.model.name,
            exp.dataset.name(),
            exp.n_requests,
            exp.rate
        ),
        &HDR,
    );
    for &kind in EngineKind::all() {
        eprintln!("running {}...", kind.name());
        let s = exp.run(kind).summary();
        t.row(&summary_row(kind.name(), &s));
    }
    t.print();
}

/// Shared `cluster` / `trace` argument parsing: fleet shape, engine kind,
/// routing policy, optional bursty arrivals and autoscaling.
fn cluster_experiment(args: &Args) -> (ClusterExperiment, EngineKind) {
    let base = experiment(args);
    let kind = EngineKind::by_name(&args.get_or("engine", "nexus"))
        .unwrap_or_else(|| panic!("unknown --engine"));
    let policy = RoutingPolicy::by_name(&args.get_or("policy", "jsq")).unwrap_or_else(|| {
        let names: Vec<&str> = RoutingPolicy::all().iter().map(|p| p.name()).collect();
        panic!("unknown --policy (one of: {})", names.join("|"))
    });
    let replicas = args.get_usize("replicas", 4);
    let mut exp = ClusterExperiment::new(base, replicas, policy);
    if args.is_set("bursty") {
        exp.bursty = Some(BurstyCfg {
            base_rate: exp.base.rate,
            burst_shape: args.get_f64("burst-shape", 0.5),
            ..BurstyCfg::default()
        });
    }
    if args.is_set("autoscale") {
        let objective = match args.get_or("objective", "utilization").as_str() {
            "utilization" => ScaleObjective::Utilization,
            "goodput" => ScaleObjective::GoodputPerCost,
            o => panic!("unknown --objective '{o}' (utilization|goodput)"),
        };
        exp.autoscale = Some(AutoscalerCfg {
            min_replicas: args.get_usize("min", 1),
            max_replicas: args.get_usize("max", replicas.max(2) * 2),
            objective,
            goodput_margin: args.get_f64("goodput-margin", 0.5),
            ..AutoscalerCfg::default()
        });
    }
    // Multi-tenant serving: `--tenants`/`--tenant-weights` label the
    // workload; `--wfq` adds the weighted-fair admission front on top.
    let weights: Option<Vec<f64>> = args.get("tenant-weights").map(|s| {
        s.split(',')
            .map(|w| {
                w.trim().parse::<f64>().unwrap_or_else(|_| {
                    panic!("--tenant-weights expects comma-separated numbers, got '{w}'")
                })
            })
            .collect()
    });
    let n_tenants = weights.as_ref().map_or_else(|| args.get_usize("tenants", 0), Vec::len);
    if n_tenants > 0 {
        assert!(n_tenants <= u16::MAX as usize + 1, "too many --tenants");
        exp.tenant_mix = Some(TenantMix::uniform(n_tenants));
        if args.is_set("wfq") {
            let mut specs = vec![TenantSpec::default(); n_tenants];
            if let Some(ws) = &weights {
                for (s, &w) in specs.iter_mut().zip(ws) {
                    assert!(w > 0.0, "--tenant-weights must be positive");
                    s.weight = w;
                }
            }
            let quota = args.get_usize("tenant-quota", usize::MAX);
            let ttft = args.get_f64("ttft-slo", TenantSpec::default().ttft_slo);
            let tbt = args.get_f64("tbt-slo", TenantSpec::default().tbt_slo);
            for s in specs.iter_mut() {
                s.admission_quota = quota;
                s.ttft_slo = ttft;
                s.tbt_slo = tbt;
            }
            exp.wfq = Some(
                WfqCfg::new(specs).with_capacity(args.get_usize("wfq-capacity", usize::MAX)),
            );
        }
    } else {
        assert!(
            !args.is_set("wfq"),
            "--wfq needs a tenant table: pass --tenants N or --tenant-weights a,b,..."
        );
    }
    // Fleet prefix cache: `--policy prefix` enables the default config;
    // any prefix flag enables the machinery under other policies too
    // (resident prefixes still shorten prefill, routing just ignores them).
    let prefix_flags = args.get("prefix-capacity").is_some()
        || args.get("tier").is_some()
        || args.get("tier-bw").is_some()
        || args.get("tier-lat").is_some();
    if policy == RoutingPolicy::PrefixAware || prefix_flags {
        let dflt = PrefixCacheCfg::default();
        let mut tier = match args.get_or("tier", "rdma").as_str() {
            "none" | "off" => None,
            name => Some(
                TierCfg::by_name(name)
                    .unwrap_or_else(|| panic!("unknown --tier '{name}' (nvlink|rdma|tcp|none)")),
            ),
        };
        if let Some(t) = &mut tier {
            t.bw = args.get_f64("tier-bw", t.bw);
            t.lat = args.get_f64("tier-lat", t.lat);
        }
        exp.prefix = Some(PrefixCacheCfg {
            capacity: args.get_usize("prefix-capacity", dflt.capacity),
            tier,
            ..dflt
        });
    }
    exp.threads = args.get_usize("threads", 1);
    assert!(exp.threads >= 1, "--threads must be >= 1");
    exp.window = args.get_f64("window", 0.0);
    assert!(exp.window >= 0.0, "--window must be >= 0");
    let st = args.get_f64("steal-threshold", 0.0);
    if st > 0.0 {
        assert!(st > 1.0, "--steal-threshold must be > 1 (it is a load ratio)");
        let interval = args.get_f64("balance-interval", 1.0);
        assert!(interval > 0.0, "--balance-interval must be > 0");
        exp.steal = Some(StealCfg { threshold: st, interval });
    }
    (exp, kind)
}

fn cmd_cluster(args: &Args) {
    let (exp, kind) = cluster_experiment(args);
    let replicas = exp.replicas;
    let policy = exp.policy;
    eprintln!(
        "running {} x{} [{}] on {} / {} ({} reqs @ {} req/s{}{}{}{})...",
        kind.name(),
        replicas,
        policy.name(),
        exp.base.model.name,
        exp.base.dataset.name(),
        exp.base.n_requests,
        exp.base.rate,
        if exp.bursty.is_some() { ", bursty" } else { "" },
        if exp.autoscale.is_some() { ", autoscaled" } else { "" },
        if exp.threads > 1 { format!(", {} threads", exp.threads) } else { String::new() },
        if exp.steal.is_some() { ", stealing" } else { "" },
    );
    let tracer = tracer_from(args);
    let m = exp.run_traced(kind, &tracer);
    let mut t = Table::new("fleet summary", &HDR);
    t.row(&summary_row(&format!("{} x{}", kind.name(), replicas), &m.summary()));
    t.print();
    println!(
        "replicas: peak {} | replica-seconds {:.1} | scale events {} ({} suppressed) | timeouts {}",
        m.peak_replicas,
        m.replica_seconds,
        m.scale_events.len(),
        m.suppressed_scales,
        m.fleet.timeouts
    );
    if exp.steal.is_some() {
        eprintln!(
            "shards: {} rebalance moves | per-shard steps {:?}",
            m.rebalances, m.shard_steps
        );
    }
    let mut rt = Table::new("per-replica", &["replica", "routed", "completed", "lifetime"]);
    for r in &m.replicas {
        let end = r.retired_at.map_or("end".to_string(), |at| format!("{at:.1}s"));
        rt.row(&[
            format!("{}", r.id),
            format!("{}", r.routed),
            format!("{}", r.completed),
            format!("{:.1}s..{}", r.started_at, end),
        ]);
    }
    rt.print();
    for e in &m.scale_events {
        println!("  scale @ {:>8.1}s: {} -> {}", e.time, e.from, e.to);
    }
    println!(
        "merged histograms: p50/p95/p99 TTFT {} / {} / {} | p95 TBT {}",
        dur(m.ttft_hist.quantile(0.50)),
        dur(m.ttft_hist.quantile(0.95)),
        dur(m.ttft_hist.quantile(0.99)),
        dur(m.tbt_hist.quantile(0.95)),
    );
    if m.prefix.lookups > 0 {
        println!(
            "prefix cache: hit rate {:.1}% ({} local, {} tier, {} miss) | {} prefill tokens saved | {} evictions",
            100.0 * m.prefix.hit_rate(),
            m.prefix.local_hits,
            m.prefix.tier_hits,
            m.prefix.misses,
            m.prefix.tokens_saved,
            m.prefix.evictions,
        );
    }
    if let Some(wfq) = &exp.wfq {
        let mut tt = Table::new(
            "per-tenant SLO",
            &["tenant", "weight", "done", "SLO-ok", "attainment", "goodput"],
        );
        for s in m.tenant_report(&wfq.tenants) {
            let weight = wfq
                .tenants
                .get(s.tenant)
                .map_or("-".to_string(), |t| format!("{:.2}", t.weight));
            tt.row(&[
                format!("{}", s.tenant),
                weight,
                format!("{}", s.completed),
                format!("{}", s.slo_ok),
                format!("{:.1}%", 100.0 * s.attainment),
                format!("{:.2} req/s", s.goodput),
            ]);
        }
        tt.print();
        println!(
            "fleet goodput {:.2} req/s | goodput/cost {:.3} req/s per replica",
            m.goodput(&wfq.tenants),
            m.goodput_per_cost(&wfq.tenants),
        );
    }
    export_trace(args, &tracer, &m.fleet);
}

fn cmd_throughput(args: &Args) {
    let exp = experiment(args);
    let kind = EngineKind::by_name(&args.get_or("engine", "nexus"))
        .unwrap_or_else(|| panic!("unknown --engine"));
    let slo = SloSpec {
        p95_norm: args.get_f64("slo-norm", 0.2),
        mean_ttft: args.get_f64("slo-ttft", 15.0),
    };
    let hi = args.get_f64("max-rate", 30.0);
    let thr = sustainable_throughput(kind, &exp, slo, 0.25, hi, 0.25);
    println!(
        "{} sustainable throughput on {}/{}: {:.2} req/s (SLO: p95 norm ≤ {}s, mean TTFT ≤ {}s)",
        kind.name(),
        exp.model.name,
        exp.dataset.name(),
        thr,
        slo.p95_norm,
        slo.mean_ttft
    );
}

fn cmd_offline(args: &Args) {
    let exp = experiment(args);
    let mut t = Table::new("offline makespan", &["engine", "makespan", "gpus"]);
    for &kind in EngineKind::all() {
        eprintln!("running {}...", kind.name());
        match offline_makespan(kind, &exp) {
            Some((mk, _)) => t.row(&[
                kind.name().to_string(),
                dur(mk),
                format!("{}", kind.gpus(&exp.model)),
            ]),
            None => t.row(&[kind.name().to_string(), "X (timeout)".into(), String::new()]),
        }
    }
    t.print();
}

fn cmd_calibrate(_args: &Args) {
    let gpu = GpuSpec::l20();
    let cm = calibrate(&gpu);
    let mut t = Table::new(
        &format!("calibrated Eq.-7 curves — {}", gpu.name),
        &["operator", "C_eff (TFLOP/s)", "R_sat", "lambda"],
    );
    for &class in OpClass::all() {
        if class == OpClass::Comm {
            continue;
        }
        let c = cm.curve(class);
        t.row(&[
            class.name().to_string(),
            format!("{:.1}", c.c_eff / 1e12),
            format!("{:.2}", c.r_sat),
            format!("{:.3}", c.lambda),
        ]);
    }
    t.print();
}

fn cmd_trace(args: &Args) {
    if args.is_set("workload") {
        // Dump the generated workload itself as JSON (the subcommand's
        // pre-telemetry behavior).
        let dataset = Dataset::by_name(&args.get_or("dataset", "sharegpt")).expect("dataset");
        let trace = workload::generate(
            dataset,
            args.get_usize("n", 500),
            args.get_f64("rate", 2.0),
            args.get_u64("seed", 42),
        );
        let json = workload::trace_to_json(&trace).to_string();
        match args.get("out") {
            Some(path) => {
                std::fs::write(path, &json).expect("writing trace");
                eprintln!("wrote {} requests to {path}", trace.len());
            }
            None => println!("{json}"),
        }
        return;
    }

    // Default: run a fleet with recording + sampling on and export a
    // Chrome/Perfetto trace (one track per replica, async spans per
    // request, counter tracks from the periodic samples).
    let (exp, kind) = cluster_experiment(args);
    eprintln!(
        "tracing {} x{} [{}] on {} / {} ({} reqs @ {} req/s{}{})...",
        kind.name(),
        exp.replicas,
        exp.policy.name(),
        exp.base.model.name,
        exp.base.dataset.name(),
        exp.base.n_requests,
        exp.base.rate,
        if exp.bursty.is_some() { ", bursty" } else { "" },
        if exp.autoscale.is_some() { ", autoscaled" } else { "" },
    );
    let tracer = Tracer::recording().with_sampling(args.get_f64("sample-interval", 1.0));
    let m = exp.run_traced(kind, &tracer);
    let events = tracer.take();
    let mut t = Table::new("fleet summary", &HDR);
    t.row(&summary_row(&format!("{} x{}", kind.name(), exp.replicas), &m.summary()));
    t.print();
    println!("{}", attribute(&events, &m.fleet));
    let out = args.get_or("out", "trace.json");
    std::fs::write(&out, chrome_trace(&events).to_string()).expect("writing trace");
    eprintln!(
        "wrote {} events to {out} — open it at https://ui.perfetto.dev or chrome://tracing",
        events.len()
    );
    if let Some(path) = args.get("trace-events") {
        std::fs::write(path, to_jsonl(&events)).expect("writing event log");
        eprintln!("wrote {} events to {path} (JSONL)", events.len());
    }
}

#[cfg(feature = "pjrt")]
fn cmd_live(args: &Args) {
    use nexus::server::{ServeRequest, Server, ServerCfg};
    use nexus::util::rng::Rng;

    let dir = std::path::PathBuf::from(args.get_or(
        "artifacts",
        nexus::runtime::Runtime::default_dir().to_str().unwrap(),
    ));
    let n = args.get_usize("requests", 16);
    let rate = args.get_f64("rate", 4.0);
    let seed = args.get_u64("seed", 42);
    eprintln!("loading artifacts from {} ...", dir.display());
    let mut server = Server::start(dir, ServerCfg::default()).expect("server start");
    server.wait_ready().expect("artifact load");

    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    for id in 0..n {
        let len = rng.range_usize(4, 48);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        let max_tokens = rng.range_usize(4, 24);
        server.submit(ServeRequest { id, prompt, max_tokens }).unwrap();
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
    }
    let mut ttfts = Vec::new();
    let mut gaps = Vec::new();
    let mut tokens = 0usize;
    for _ in 0..n {
        let r = server.recv().expect("response");
        ttfts.push(r.ttft);
        gaps.extend(r.gaps.iter().copied());
        tokens += r.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();
    println!(
        "live PJRT serving: {n} requests, {tokens} tokens in {:.2}s ({:.1} tok/s)",
        wall,
        tokens as f64 / wall
    );
    println!(
        "  mean TTFT {} | p95 TTFT {} | mean TBT {} | p95 TBT {}",
        dur(nexus::util::mean(&ttfts)),
        dur(nexus::util::percentile(&ttfts, 95.0)),
        dur(nexus::util::mean(&gaps)),
        dur(nexus::util::percentile(&gaps, 95.0)),
    );
}

#[cfg(not(feature = "pjrt"))]
fn cmd_live(_args: &Args) {
    eprintln!(
        "`nexus live` needs the real-compute PJRT path: declare the vendored \
         xla/anyhow crates in Cargo.toml (see the [features] comment there) \
         and rebuild with `cargo build --features pjrt`."
    );
    std::process::exit(2);
}
