//! Mini property-testing harness (proptest is not vendored in this image).
//!
//! Usage:
//! ```ignore
//! use nexus::testing::prop;
//! prop("shares sum to one", 200, |rng| {
//!     let x = rng.f64();
//!     if (x + (1.0 - x) - 1.0).abs() < 1e-12 { Ok(()) } else { Err(format!("x={x}")) }
//! });
//! ```
//!
//! Each case gets a deterministic per-case RNG derived from the run seed, so
//! failures are reproducible: the panic message prints the run seed, the
//! failing case index, and the property's own diagnostic. Override the seed
//! or case count via `NEXUS_PROP_SEED` / `NEXUS_PROP_CASES`.

use crate::util::rng::Rng;

/// Default seed; override with `NEXUS_PROP_SEED`.
const DEFAULT_SEED: u64 = 0x5EED_CAFE;

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Run `cases` random cases of property `f`. Panics on the first failure
/// with a reproducible (seed, case) pair.
pub fn prop<F>(name: &str, cases: usize, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let seed = env_u64("NEXUS_PROP_SEED").unwrap_or(DEFAULT_SEED);
    let cases = env_u64("NEXUS_PROP_CASES").map(|c| c as usize).unwrap_or(cases);
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = master.fork();
        if let Err(msg) = f(&mut case_rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (NEXUS_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Sized generators for common test inputs.
pub mod gen {
    use crate::util::rng::Rng;

    /// Integer in [lo, hi] with a bias toward the extremes (edge cases).
    pub fn int_biased(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        match rng.below(10) {
            0 => lo,
            1 => hi,
            _ => rng.range_usize(lo, hi),
        }
    }

    /// Vector of length in [0, max_len] with elements from `f`.
    pub fn vec_of<T>(rng: &mut Rng, max_len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let n = rng.range_usize(0, max_len);
        (0..n).map(|_| f(rng)).collect()
    }

    /// A fraction in (0, 1) avoiding exact endpoints.
    pub fn frac(rng: &mut Rng) -> f64 {
        rng.range_f64(0.01, 0.99)
    }
}

/// Assert two floats are relatively close; returns a property-style error.
pub fn close(got: f64, want: f64, rel_tol: f64, what: &str) -> Result<(), String> {
    let denom = want.abs().max(1e-12);
    let rel = (got - want).abs() / denom;
    if rel <= rel_tol {
        Ok(())
    } else {
        Err(format!("{what}: got {got}, want {want} (rel err {rel:.3} > {rel_tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use std::cell::Cell;
        let count = Cell::new(0usize);
        prop("always true", 50, |rng| {
            let _ = rng.f64();
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_seed() {
        prop("always false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.001, 0.01, "x").is_ok());
        assert!(close(1.0, 2.0, 0.01, "x").is_err());
        assert!(close(0.0, 0.0, 0.01, "zero").is_ok());
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..1000 {
            let x = gen::int_biased(&mut rng, 5, 10);
            assert!((5..=10).contains(&x));
            let f = gen::frac(&mut rng);
            assert!((0.0..1.0).contains(&f) && f > 0.0);
        }
        let v = gen::vec_of(&mut rng, 8, |r| r.below(100));
        assert!(v.len() <= 8);
    }
}
