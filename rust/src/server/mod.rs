//! Real-compute serving path: the Nexus scheduling policies driving the
//! PJRT runtime on the tiny model, with wall-clock metrics.
//!
//! Architecture (CPU adaptation of the paper's two-stream design): request
//! intake happens on arbitrary threads through an `mpsc` channel; a single
//! *executor thread* owns the PJRT runtime (its handles are not `Send`-safe
//! across concurrent use) and alternates between the two phases under the
//! Nexus policy — SPF-ordered prefill admission, FCFS decode batches, and a
//! phase-priority knob standing in for the SM split (on a CPU backend the
//! "partition" degenerates to interleaving priority; the real SM-partition
//! control system is exercised by the simulator engines).

use crate::runtime::Runtime;
use crate::sched::{spf_batch, PrefillItem};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

/// A request submitted to the live server.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

/// Completed request with wall-clock latency metrics.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: usize,
    pub tokens: Vec<i32>,
    /// Arrival → first token (s).
    pub ttft: f64,
    /// Inter-token gaps (s).
    pub gaps: Vec<f64>,
    pub e2e: f64,
}

enum Msg {
    Request(ServeRequest, Instant),
    Shutdown,
}

/// Handle to a running server; dropping it shuts the executor down.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    rx_out: mpsc::Receiver<ServeResponse>,
    rx_ready: Option<mpsc::Receiver<Result<(), String>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Scheduling policy for the executor loop.
#[derive(Debug, Clone, Copy)]
pub struct ServerCfg {
    /// SPF age-decay γ; negative disables SPF (FCFS prefill).
    pub gamma: f64,
    /// Decode steps run per prefill admission when both phases have work
    /// (the CPU stand-in for the SM split: higher favors decode/TBT).
    pub decode_bias: usize,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg { gamma: 15.0, decode_bias: 2 }
    }
}

struct LiveReq {
    req: ServeRequest,
    submitted: Instant,
    tokens: Vec<i32>,
    first_token: Option<Instant>,
    last_token: Instant,
    gaps: Vec<f64>,
    /// KV length (prompt + generated so far).
    pos: usize,
    /// Decode slot index while active.
    slot: usize,
}

impl Server {
    /// Start the executor thread over artifacts in `dir`.
    pub fn start(dir: std::path::PathBuf, cfg: ServerCfg) -> anyhow::Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (tx_out, rx_out) = mpsc::channel::<ServeResponse>();
        let (tx_ready, rx_ready) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("nexus-executor".into())
            .spawn(move || {
                // Runtime is created on the executor thread and never leaves it.
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = tx_ready.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let _ = tx_ready.send(Ok(()));
                executor_loop(rt, cfg, rx, tx_out);
            })?;
        Ok(Server { tx, rx_out, rx_ready: Some(rx_ready), handle: Some(handle) })
    }

    /// Block until the artifacts are loaded and compiled (so latency
    /// metrics exclude the one-time AOT-compile cost).
    pub fn wait_ready(&mut self) -> anyhow::Result<()> {
        if let Some(rx) = self.rx_ready.take() {
            match rx.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(anyhow::anyhow!("artifact load failed: {e}")),
                Err(_) => Err(anyhow::anyhow!("executor died before becoming ready")),
            }
        } else {
            Ok(())
        }
    }

    pub fn submit(&self, req: ServeRequest) -> anyhow::Result<()> {
        self.tx
            .send(Msg::Request(req, Instant::now()))
            .map_err(|_| anyhow::anyhow!("server executor is gone"))
    }

    /// Block until the next completed response (None once shut down).
    pub fn recv(&self) -> Option<ServeResponse> {
        self.rx_out.recv().ok()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn executor_loop(
    rt: Runtime,
    cfg: ServerCfg,
    rx: mpsc::Receiver<Msg>,
    tx_out: mpsc::Sender<ServeResponse>,
) {
    let dims = rt.dims;
    let b = dims.decode_batch;
    let mut waiting: VecDeque<LiveReq> = VecDeque::new();
    // Fixed decode slots (the AOT decode entry has static batch width B).
    let mut slots: Vec<Option<LiveReq>> = (0..b).map(|_| None).collect();
    let mut kv = vec![0.0f32; dims.batch_kv_elems()];
    let mut shutdown = false;
    let start = Instant::now();

    loop {
        // Drain the intake channel (block only when fully idle).
        let idle = waiting.is_empty() && slots.iter().all(Option::is_none);
        if idle && !shutdown {
            match rx.recv() {
                Ok(Msg::Request(r, at)) => waiting.push_back(new_live(r, at)),
                Ok(Msg::Shutdown) | Err(_) => shutdown = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(r, at)) => waiting.push_back(new_live(r, at)),
                Ok(Msg::Shutdown) => shutdown = true,
                Err(_) => break,
            }
        }
        if shutdown && waiting.is_empty() && slots.iter().all(Option::is_none) {
            return;
        }

        // Prefill admission: SPF (or FCFS) into free decode slots.
        if let Some(free) = slots.iter().position(Option::is_none) {
            if let Some(idx) = pick_prefill(&waiting, cfg, start) {
                let mut live = waiting.remove(idx).unwrap();
                match rt.prefill(&live.req.prompt) {
                    Ok(out) => {
                        let now = Instant::now();
                        let tok = Runtime::argmax(&out.logits);
                        live.tokens.push(tok);
                        live.first_token = Some(now);
                        live.last_token = now;
                        live.pos = live.req.prompt.len();
                        live.slot = free;
                        // Install this request's KV into its batch slot.
                        let per = dims.kv_elems();
                        kv[free * per..(free + 1) * per].copy_from_slice(&out.kv);
                        if live.tokens.len() >= live.req.max_tokens {
                            finish(&tx_out, live);
                        } else {
                            slots[free] = Some(live);
                        }
                    }
                    Err(e) => {
                        eprintln!("nexus server: prefill failed for {}: {e:#}", live.req.id);
                        finish(&tx_out, live);
                    }
                }
            }
        }

        // Decode: run `decode_bias` steps over the active batch.
        for _ in 0..cfg.decode_bias.max(1) {
            if slots.iter().all(Option::is_none) {
                break;
            }
            let mut tokens = vec![0i32; b];
            let mut pos = vec![0i32; b];
            for (i, s) in slots.iter().enumerate() {
                if let Some(live) = s {
                    tokens[i] = *live.tokens.last().unwrap();
                    pos[i] = live.pos as i32;
                }
            }
            match rt.decode(&tokens, &pos, &mut kv) {
                Ok(logits) => {
                    let now = Instant::now();
                    for (i, s) in slots.iter_mut().enumerate() {
                        let done = if let Some(live) = s.as_mut() {
                            let row = &logits[i * dims.vocab..(i + 1) * dims.vocab];
                            let tok = Runtime::argmax(row);
                            live.tokens.push(tok);
                            live.gaps.push(now.duration_since(live.last_token).as_secs_f64());
                            live.last_token = now;
                            live.pos += 1;
                            live.tokens.len() >= live.req.max_tokens
                                || live.pos >= dims.kv_cap
                        } else {
                            false
                        };
                        if done {
                            finish(&tx_out, s.take().unwrap());
                        }
                    }
                }
                Err(e) => {
                    eprintln!("nexus server: decode step failed: {e:#}");
                    for s in slots.iter_mut() {
                        if let Some(live) = s.take() {
                            finish(&tx_out, live);
                        }
                    }
                }
            }
        }
    }
}

fn new_live(req: ServeRequest, at: Instant) -> LiveReq {
    LiveReq {
        req,
        submitted: at,
        tokens: Vec::new(),
        first_token: None,
        last_token: at,
        gaps: Vec::new(),
        pos: 0,
        slot: 0,
    }
}

fn pick_prefill(waiting: &VecDeque<LiveReq>, cfg: ServerCfg, epoch: Instant) -> Option<usize> {
    if waiting.is_empty() {
        return None;
    }
    if cfg.gamma < 0.0 {
        return Some(0); // FCFS
    }
    let items: Vec<PrefillItem> = waiting
        .iter()
        .enumerate()
        .map(|(i, w)| PrefillItem {
            id: i,
            prompt_len: w.req.prompt.len(),
            prefilled: 0,
            arrival: w.submitted.duration_since(epoch).as_secs_f64(),
        })
        .collect();
    let now = epoch.elapsed().as_secs_f64();
    spf_batch(&items, now, usize::MAX, cfg.gamma).first().copied()
}

fn finish(tx: &mpsc::Sender<ServeResponse>, live: LiveReq) {
    let now = Instant::now();
    let first = live.first_token.unwrap_or(now);
    let _ = tx.send(ServeResponse {
        id: live.req.id,
        tokens: live.tokens,
        ttft: first.duration_since(live.submitted).as_secs_f64(),
        gaps: live.gaps,
        e2e: now.duration_since(live.submitted).as_secs_f64(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spf_pick_prefers_short_prompt() {
        let epoch = Instant::now();
        let mk = |len: usize| {
            new_live(ServeRequest { id: 0, prompt: vec![1; len], max_tokens: 4 }, epoch)
        };
        let waiting: VecDeque<LiveReq> = [mk(100), mk(5), mk(50)].into_iter().collect();
        let cfg = ServerCfg::default();
        assert_eq!(pick_prefill(&waiting, cfg, epoch), Some(1));
        let fcfs = ServerCfg { gamma: -1.0, ..cfg };
        assert_eq!(pick_prefill(&waiting, fcfs, epoch), Some(0));
        assert_eq!(pick_prefill(&VecDeque::new(), cfg, epoch), None);
    }
}
