//! §4.1 validation — cost-model prediction accuracy and greedy-search
//! convergence.
//!
//! (a) predicted vs substrate-measured iteration latency over random batch
//!     states (isolated per phase, across the SM grid): mean/p95 absolute
//!     relative error;
//! (b) Algorithm-1 convergence: cost-model queries per decision (paper:
//!     converges in 2–4 greedy iterations).
//!
//! `cargo bench --bench costmodel_accuracy`

use nexus::costmodel::calibrate;
use nexus::gpusim::{iteration_time_isolated, GpuSpec};
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::util::fmt::Table;
use nexus::util::rng::Rng;
use nexus::util::{mean, percentile};

fn main() {
    let gpu = GpuSpec::l20();
    let cost = calibrate(&gpu);
    let mut rng = Rng::new(2024);

    let mut t = Table::new(
        "cost-model accuracy vs substrate (isolated iterations, random states)",
        &["model", "phase", "mean |rel err|", "p95 |rel err|", "max"],
    );
    for model in [ModelConfig::qwen3b(), ModelConfig::llama8b()] {
        for prefill in [true, false] {
            let mut errs = Vec::new();
            for _ in 0..300 {
                let r = gpu.quantize(rng.range_f64(0.1, 1.0));
                let (truth, pred) = if prefill {
                    let chunk = rng.range_usize(64, 2048);
                    let kv = rng.range_f64(chunk as f64, 12000.0);
                    let ops = model.prefill_ops(chunk, chunk as f64 * kv, kv, 0);
                    (iteration_time_isolated(&gpu, &ops, r), cost.prefill(&ops, r).total)
                } else {
                    let batch = rng.range_usize(1, 256);
                    let ctx = rng.range_f64(64.0, 4000.0);
                    let ops = model.decode_ops(batch, batch as f64 * ctx);
                    (iteration_time_isolated(&gpu, &ops, r), cost.decode(&ops, r, None))
                };
                errs.push(((pred - truth) / truth).abs());
            }
            t.row(&[
                model.name.to_string(),
                if prefill { "prefill" } else { "decode" }.into(),
                format!("{:.1}%", 100.0 * mean(&errs)),
                format!("{:.1}%", 100.0 * percentile(&errs, 95.0)),
                format!("{:.1}%", 100.0 * errs.iter().cloned().fold(0.0, f64::max)),
            ]);
        }
    }
    t.print();
    println!("(target: <15-20% mean — enough to rank SM partitions)\n");

    // (b) greedy convergence.
    let model = ModelConfig::qwen3b();
    let mut queries_cold = Vec::new();
    let mut queries_warm = Vec::new();
    for _ in 0..200 {
        let chunk = rng.range_usize(64, 2048);
        let kv = rng.range_f64(chunk as f64, 10000.0);
        let pre = model.prefill_ops(chunk, chunk as f64 * kv, kv, 0);
        let dec = model.decode_ops(rng.range_usize(1, 128), rng.range_f64(1e3, 2e5));
        let st = BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: rng.f64() };
        let mut ctl = PartitionController::new(PartitionConfig::default());
        queries_cold.push(ctl.decide(&cost, &st).queries as f64);
        queries_warm.push(ctl.decide(&cost, &st).queries as f64);
    }
    let mut t = Table::new(
        "Algorithm-1 greedy search cost (cost-model queries per decision)",
        &["state", "mean", "p95", "max"],
    );
    for (name, q) in [("cold (fresh controller)", &queries_cold), ("warm (settled)", &queries_warm)] {
        t.row(&[
            name.to_string(),
            format!("{:.1}", mean(q)),
            format!("{:.0}", percentile(q, 95.0)),
            format!("{:.0}", q.iter().cloned().fold(0.0, f64::max)),
        ]);
    }
    t.print();
    println!("(each greedy *iteration* is a few queries; paper: converges in 2–4 iterations)");
}
