//! Figure 12 — breakdown of per-token latency into scheduling, queuing and
//! execution stages, for Long Data Collections (Qwen3B) and Mixed
//! (Llama8B).
//!
//! `cargo bench --bench fig12_breakdown`

use nexus::coordinator::Experiment;
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn main() {
    let n = std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(120);
    for (dataset, model, rate) in [
        (Dataset::LongData, ModelConfig::qwen3b(), 2.5),
        (Dataset::Mixed, ModelConfig::llama8b(), 2.5),
    ] {
        let exp = Experiment::new(model, dataset, n, rate);
        let mut t = Table::new(
            &format!(
                "Fig 12 — per-token latency breakdown: {} / {} @ {} req/s",
                dataset.name(),
                model.name,
                rate
            ),
            &["engine", "sched", "queue", "exec", "total", "queue share"],
        );
        let mut vllm_queue = None;
        for &kind in EngineKind::all() {
            let m = exp.run(kind);
            let b = m.breakdown();
            if kind == EngineKind::Vllm {
                vllm_queue = Some(b.queue);
            }
            t.row(&[
                kind.name().to_string(),
                dur(b.sched),
                dur(b.queue),
                dur(b.exec),
                dur(b.total()),
                format!("{:.0}%", 100.0 * b.queue / b.total().max(1e-12)),
            ]);
        }
        t.print();
        if let Some(vq) = vllm_queue {
            let nexus_q = exp.run(EngineKind::Nexus).breakdown().queue;
            println!("queue-time: Nexus {:.1}x lower than vLLM\n", vq / nexus_q.max(1e-12));
        }
    }
    println!(
        "(paper shape: scheduling negligible for all; queuing dominates under load and \
         Nexus cuts it 4–5x vs monolithic baselines; execution comparable)"
    );
}
