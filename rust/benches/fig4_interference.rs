//! Figure 4 — latency impact of mixed prefill–decode batches.
//!
//! (a) iteration time by batch type (prefill-only / decode-only / mixed)
//!     with counts, from a replayed vLLM-style chunked-prefill run;
//! (b) per-kernel-class latency: pure decode batch vs the same decode work
//!     inside a mixed batch (the decode kernels wait behind prefill ops on
//!     the shared stream — the interference mechanism).
//!
//! `cargo bench --bench fig4_interference`

use nexus::gpusim::{GpuSpec, Sim};
use nexus::model::{ModelConfig, OpClass, OpWork};
use nexus::util::fmt::{dur, Table};
use nexus::util::rng::Rng;
use nexus::workload::Dataset;

/// Iteration time of an op list run alone on a full-GPU stream.
fn iter_time(spec: GpuSpec, ops: &[OpWork]) -> f64 {
    let mut sim = Sim::new(spec, 1);
    sim.set_partition(0, 1.0);
    sim.submit(0, ops, 1);
    sim.drain().last().unwrap().time
}

fn main() {
    let spec = GpuSpec::l20();
    let model = ModelConfig::qwen3b();
    let mut rng = Rng::new(42);

    // Replay the §3 setup: LDC traffic (long prompts) at 2.5 req/s means
    // nearly every iteration carries a prefill chunk alongside the decodes.
    let n_iters = 2000;
    let mut stats: Vec<(f64, usize)> = vec![(0.0, 0); 3]; // prefill/decode/mixed
    let mut kernel_pure: Vec<(OpClass, f64)> = Vec::new();
    let mut kernel_mixed: Vec<(OpClass, f64)> = Vec::new();

    for i in 0..n_iters {
        // Decode side: continuous batch of 8–48 requests with LDC contexts.
        let batch = rng.range_usize(8, 48);
        let ctx: f64 = (0..batch)
            .map(|_| Dataset::LongData.sample(&mut rng).0 as f64)
            .sum();
        let dec_ops = model.decode_ops(batch, ctx);
        // Prefill side: a 512-token chunk of a long prompt ~94% of the time
        // (Fig. 4a's observed mix).
        let has_prefill = rng.chance(0.94);
        let decode_only = rng.chance(0.06);

        if has_prefill && !decode_only {
            // vLLM packs prefill chunks up to the shared 2048-token budget.
            let chunk = 2048 - batch;
            let kv_len = Dataset::LongData.sample(&mut rng).0 as f64;
            let pre_ops = model.prefill_ops(chunk, chunk as f64 * kv_len, kv_len, 0);
            let mut ops = dec_ops.clone();
            ops.extend(pre_ops.iter().copied());
            stats[2].0 += iter_time(spec, &ops);
            stats[2].1 += 1;
            if i < 50 {
                // Kernel-level: decode classes experience the whole
                // iteration as their effective latency (serialized batch).
                let t_mixed = iter_time(spec, &ops);
                for op in &dec_ops {
                    kernel_mixed.push((op.class, t_mixed));
                    kernel_pure.push((op.class, iter_time(spec, std::slice::from_ref(op))));
                }
                let _ = t_mixed;
            }
        } else if decode_only {
            stats[1].0 += iter_time(spec, &dec_ops);
            stats[1].1 += 1;
        } else {
            let kv_len = Dataset::LongData.sample(&mut rng).0 as f64;
            let pre_ops = model.prefill_ops(2048, 2048.0 * kv_len, kv_len, 0);
            stats[0].0 += iter_time(spec, &pre_ops);
            stats[0].1 += 1;
        }
    }

    let total: usize = stats.iter().map(|s| s.1).sum();
    let mut t = Table::new(
        "Fig 4a — iteration latency by batch type (paper: mixed ≈ 0.251s, decode 0.015s)",
        &["type", "avg time", "count", "%"],
    );
    for (i, name) in ["Prefill-only", "Decode-only", "Mixed"].iter().enumerate() {
        let (sum, cnt) = stats[i];
        t.row(&[
            name.to_string(),
            dur(if cnt > 0 { sum / cnt as f64 } else { 0.0 }),
            format!("{cnt}"),
            format!("{:.2}%", 100.0 * cnt as f64 / total as f64),
        ]);
    }
    t.print();
    let mixed_avg = stats[2].0 / stats[2].1.max(1) as f64;
    let dec_avg = stats[1].0 / stats[1].1.max(1) as f64;
    println!("mixed/decode slowdown: {:.1}x (paper: 8–10x)\n", mixed_avg / dec_avg);

    // (b) kernel-level inflation.
    let mut t = Table::new(
        "Fig 4b — decode kernel latency: pure vs co-executed with prefill",
        &["kernel", "pure", "in mixed batch", "inflation"],
    );
    for class in [OpClass::Qkv, OpClass::AttnDecode, OpClass::AttnLinear, OpClass::Ffn] {
        let avg = |xs: &[(OpClass, f64)]| {
            let v: Vec<f64> = xs.iter().filter(|(c, _)| *c == class).map(|&(_, t)| t).collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        let p = avg(&kernel_pure);
        let m = avg(&kernel_mixed);
        t.row(&[
            class.name().to_string(),
            dur(p),
            dur(m),
            format!("{:.1}x", m / p.max(1e-12)),
        ]);
    }
    t.print();
    println!("(paper: decode kernels inflate up to 10x inside mixed batches)");
}
