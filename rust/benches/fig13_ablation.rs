//! Figure 13 — ablation study on the Mixed workload (Llama3.1-8B, one L20,
//! memory-pressured): FCFS/static (PF-DF-Wo-SC), FCFS/dynamic (PF-DF-W-SC),
//! SPF/static (Nexus-Wo-SC), and full Nexus.
//!
//! `cargo bench --bench fig13_ablation`

use nexus::engine::{run_engine, EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::{generate, Dataset};

fn main() {
    let n = std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    let mut cfg = EngineCfg::new(ModelConfig::llama8b(), 42);
    // §6.5 operating point: memory becomes the bottleneck so the
    // KV-pressure mode switching engages.
    cfg.kv_blocks_override = Some(6_000);
    let trace = generate(Dataset::Mixed, n, 3.5, 42);

    let mut t = Table::new(
        &format!("Fig 13 — ablation on Mixed / llama8b ({} reqs @ 3.5 req/s, tight KV)", n),
        &[
            "variant", "TTFT", "TTFT95", "TBT", "TBT95", "norm", "repart", "mean r_p",
            "decode-mode %",
        ],
    );
    let mut rows: Vec<(EngineKind, f64, f64)> = Vec::new();
    for kind in [
        EngineKind::PfDfWoSc,
        EngineKind::PfDfWSc,
        EngineKind::NexusWoSc,
        EngineKind::Nexus,
    ] {
        let m = run_engine(kind, &cfg, &trace);
        let s = m.summary();
        rows.push((kind, s.mean_ttft, s.mean_tbt));
        t.row(&[
            kind.name().to_string(),
            dur(s.mean_ttft),
            dur(s.p95_ttft),
            dur(s.mean_tbt),
            dur(s.p95_tbt),
            dur(s.mean_norm),
            format!("{}", m.repartitions),
            format!("{:.2}", m.mean_rp),
            format!("{:.0}%", 100.0 * m.decode_mode_frac),
        ]);
    }
    t.print();
    let ttft = |k: EngineKind| rows.iter().find(|r| r.0 == k).unwrap().1;
    println!(
        "SPF effect:       TTFT {} → {} (-{:.0}%)   [paper: up to -90%]",
        dur(ttft(EngineKind::PfDfWoSc)),
        dur(ttft(EngineKind::NexusWoSc)),
        100.0 * (1.0 - ttft(EngineKind::NexusWoSc) / ttft(EngineKind::PfDfWoSc))
    );
    println!(
        "SM-change effect: TTFT {} → {} (-{:.0}%)   [paper: -23% over SPF-only]",
        dur(ttft(EngineKind::NexusWoSc)),
        dur(ttft(EngineKind::Nexus)),
        100.0 * (1.0 - ttft(EngineKind::Nexus) / ttft(EngineKind::NexusWoSc))
    );
    println!(
        "(divergence note: the paper reports TBT -26% for full Nexus; in this substrate \
         decode saturates at ~25-34% SMs so static 50/50 is already decode-optimal — \
         see EXPERIMENTS.md Fig 13)"
    );
}
